"""Static Table I certification report: counted-vs-modeled cost ratios
for every registered family x variant x s, straight from the analyzer's
``cost_ratio_rows`` (no solves, no devices — the jaxpr IS the
measurement). Writes ``results/perf/certified.json`` plus the usual CSV
rows, and runs the kernel safety pass so the artifact also records each
Pallas package's derived-vs-modeled VMEM footprint.

    PYTHONPATH=src python -m benchmarks.run --only certify [--smoke]

``--smoke`` trims the s grid to (1, 4) and skips the SparseOperand
traces — the CI-sized budget.
"""
import json
import os

from benchmarks.common import emit

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT_PATH = os.path.join(ROOT, "results", "perf", "certified.json")


def main(smoke: bool = False) -> None:
    from repro.analysis import check_costs, check_kernels, cost_ratio_rows
    from repro.analysis.costs import CERT_S_GRID
    from repro.core.types import FAMILIES

    s_grid = (1, 4) if smoke else CERT_S_GRID
    sparse = not smoke
    entries = []
    certified = True
    for name in sorted(FAMILIES):
        fam = FAMILIES[name]
        diags, _ = check_costs(fam, s_grid=s_grid, sparse=sparse)
        errors = [d for d in diags if d.severity == "error"]
        certified &= not errors
        for row in cost_ratio_rows(fam, s_grid=s_grid, sparse=sparse):
            entries.append({
                "family": row.family, "variant": row.variant,
                "s": row.s, "mu": row.mu,
                "counted_flops": row.flops,
                "model_flops": row.model_flops,
                "f_ratio": row.f_ratio,
                "counted_words": row.words,
                "model_words": row.model_words,
                "w_ratio": row.w_ratio,
                "messages": row.messages,
                "sparse_ratio": row.sparse_ratio,
            })
            nnz = "" if row.sparse_ratio is None \
                else f";nnz_ratio={row.sparse_ratio:.2f}"
            emit(f"certify/{row.family}/{row.variant}/s{row.s}", 0.0,
                 f"F_ratio={row.f_ratio:.2f};W_ratio={row.w_ratio:.2f};"
                 f"msgs={row.messages:.0f}{nnz};"
                 f"errors={len(errors)}")
    kdiags, kchecked = check_kernels()
    kernel_errors = [d for d in kdiags if d.severity == "error"]
    certified &= not kernel_errors
    for d in kdiags:
        if d.severity == "info":
            emit(f"certify/kernels/{d.where}", 0.0,
                 d.message.split(" — ")[0].replace(" ", "_"))
    emit("certify/ok", 0.0,
         f"certified={certified};rows={len(entries)};"
         f"kernel_packages={len(kchecked)};"
         f"kernel_errors={len(kernel_errors)}")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump({"smoke": smoke, "s_grid": list(s_grid),
                   "sparse": sparse, "certified": certified,
                   "rows": entries,
                   "kernel_packages": list(kchecked),
                   "kernel_diagnostics": [d.to_dict() for d in kdiags]},
                  fh, indent=1)
    print(f"# wrote {os.path.relpath(OUT_PATH, ROOT)}", flush=True)


if __name__ == "__main__":
    main()
