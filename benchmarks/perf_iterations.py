"""§Perf hillclimb harness: re-lower the three chosen cells with each
candidate change toggled, and report the roofline-term deltas. Runs in a
subprocess per configuration (512 placeholder devices + clean flag
state). Results feed EXPERIMENTS.md §Perf.

    PYTHONPATH=src python -m benchmarks.perf_iterations [--cell mixtral]
"""
import argparse
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "perf")

CELL_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
flags = json.loads(sys.argv[1])
import repro.models.layers as L
import repro.kernels.flash_attention.ops as fops
L.DECODE_GROUPED_GQA = flags.get("grouped_gqa", False)
L.MOE_BUF_2D = flags.get("moe_buf_2d", False)
fops.CHUNKED_BF16_PROBS = flags.get("bf16_probs", False)
if "moe_chunk" in flags:
    L.MOE_CHUNK_TOKENS = flags["moe_chunk"]
if "q_chunk" in flags:
    import repro.kernels.flash_attention.ops as _f
    _orig = _f.attention_chunked
    qc = flags["q_chunk"]
    def patched(q, k, v, **kw):
        kw["q_chunk"] = qc
        return _orig(q, k, v, **kw)
    _f.attention_chunked = patched
    # rebind in flash_attention's module namespace
from repro.launch import dryrun
opts = dryrun.DryrunOptions(remat=flags.get("remat", "full"))
r = dryrun.run_cell(flags["arch"], flags["shape"],
                    multi_pod=flags.get("multi_pod", False),
                    opts=opts, verbose=False)
keep = {k: r.get(k) for k in ("status", "memory", "roofline",
                              "per_device", "useful_ratio",
                              "useful_ratio_attn", "collective_counts",
                              "error")}
print("RESULT " + json.dumps(keep))
"""

SOLVER_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, re, jax
flags = json.loads(sys.argv[1])
from repro.core.distributed import lower_lasso_step
from repro.core.types import SolverConfig
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import collective_bytes_from_hlo, \
    cost_analysis_dict
mesh = make_production_mesh(multi_pod=flags.get("multi_pod", True))
axes = ("pod", "data") if flags.get("multi_pod", True) else "data"
H, s, mu = 64, flags.get("s", 16), flags.get("mu", 8)
cfg = SolverConfig(block_size=mu, iterations=H, s=s,
                   track_objective=False,
                   symmetric_gram=flags.get("sym_gram", False))
lowered = lower_lasso_step(cfg, mesh, m=131072, n=8192, axes=axes)
c = lowered.compile()
txt = c.as_text()
coll = collective_bytes_from_hlo(txt)
static = len(re.findall(r"= \S+ all-reduce\(", txt))
ca = cost_analysis_dict(c)
out = {"s": s, "static_allreduce": static, "trips": H // s,
       "runtime_msgs": static * (H // s),
       "coll_bytes_per_outer": coll["total"],
       "flops": ca.get("flops"), "bytes": ca.get("bytes accessed")}
print("RESULT " + json.dumps(out))
"""


SVM_SOLVER_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, re, jax
flags = json.loads(sys.argv[1])
from repro.core.distributed import lower_svm_step
from repro.core.types import SolverConfig
from repro.roofline.analysis import collective_bytes_from_hlo, \
    cost_analysis_dict
mesh = jax.make_mesh((512,), ("model",))
H, s, mu = 64, flags.get("s", 16), flags.get("mu", 8)
kernel = flags.get("kernel", "linear")
params = {"gamma": 0.1} if kernel == "rbf" else None
cfg = SolverConfig(block_size=mu, iterations=H, s=s,
                   track_objective=False)
lowered = lower_svm_step(cfg, mesh, m=8192, n=131072, axes="model",
                         kernel=kernel, kernel_params=params)
c = lowered.compile()
txt = c.as_text()
coll = collective_bytes_from_hlo(txt)
static = len(re.findall(r"= \S+ all-reduce\(", txt))
ca = cost_analysis_dict(c)
out = {"s": s, "mu": mu, "kernel": kernel, "static_allreduce": static,
       "trips": H // s, "runtime_msgs": static * (H // s),
       "coll_bytes_per_outer": coll["total"],
       "flops": ca.get("flops"), "bytes": ca.get("bytes accessed")}
print("RESULT " + json.dumps(out))
"""


def run_config(code: str, flags: dict, timeout=1500):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code, json.dumps(flags)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    for line in out.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    return {"status": "error", "error": out.stderr[-500:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)

    experiments = {
        # Cell A: worst useful ratio / collective-heavy MoE training.
        "mixtral_train": [
            ("baseline", CELL_CODE,
             {"arch": "mixtral-8x7b", "shape": "train_4k"}),
            ("moe_buf_2d", CELL_CODE,
             {"arch": "mixtral-8x7b", "shape": "train_4k",
              "moe_buf_2d": True}),
            ("moe_chunk_64k", CELL_CODE,
             {"arch": "mixtral-8x7b", "shape": "train_4k",
              "moe_chunk": 1 << 16}),
            ("moe_chunk_256k", CELL_CODE,
             {"arch": "mixtral-8x7b", "shape": "train_4k",
              "moe_chunk": 1 << 18}),
        ],
        # Cell B: collective-bound decode at 32k (split-KV resharding).
        "llama3_decode": [
            ("baseline", CELL_CODE,
             {"arch": "llama3-8b", "shape": "decode_32k",
              "multi_pod": True}),
            ("grouped_gqa", CELL_CODE,
             {"arch": "llama3-8b", "shape": "decode_32k",
              "multi_pod": True, "grouped_gqa": True}),
        ],
        # Cell C (paper-representative): the distributed SA solver itself.
        "sa_lasso": [
            ("s1_classical", SOLVER_CODE, {"s": 1, "multi_pod": True}),
            ("s16_paper", SOLVER_CODE, {"s": 16, "multi_pod": True}),
            ("s16_sym_gram", SOLVER_CODE,
             {"s": 16, "sym_gram": True, "multi_pod": True}),
            ("s64_paper", SOLVER_CODE, {"s": 64, "multi_pod": True}),
            ("s64_sym_gram", SOLVER_CODE,
             {"s": 64, "sym_gram": True, "multi_pod": True}),
        ],
        # Cell C2: the (kernel-)SVM SA solver — the kernel rows move the
        # (m, s*mu) cross block instead of the reduced Gram; ONE
        # all-reduce per outer iteration either way.
        "sa_svm": [
            ("s1_classical", SVM_SOLVER_CODE, {"s": 1}),
            ("s16_paper", SVM_SOLVER_CODE, {"s": 16}),
            ("s64_paper", SVM_SOLVER_CODE, {"s": 64}),
            ("s16_rbf", SVM_SOLVER_CODE, {"s": 16, "kernel": "rbf"}),
            ("s64_rbf", SVM_SOLVER_CODE, {"s": 64, "kernel": "rbf"}),
        ],
        # Memory-bound prefill: attention chunk size + bf16 probs.
        "tinyllama_prefill": [
            ("baseline", CELL_CODE,
             {"arch": "tinyllama-1.1b", "shape": "prefill_32k"}),
            ("bf16_probs", CELL_CODE,
             {"arch": "tinyllama-1.1b", "shape": "prefill_32k",
              "bf16_probs": True}),
        ],
    }

    names = args.only.split(",") if args.only else list(experiments)
    for name in names:
        results = {}
        for tag, code, flags in experiments[name]:
            print(f"[perf] {name}/{tag} ...", flush=True)
            r = run_config(code, flags)
            results[tag] = r
            if "roofline" in (r or {}):
                t = r["roofline"]
                print(f"    C={t['compute_s'] * 1e3:9.1f}ms "
                      f"M={t['memory_s'] * 1e3:9.1f}ms "
                      f"N={t['collective_s'] * 1e3:9.1f}ms "
                      f"mem={r['memory']['total_bytes'] / 1e9:6.2f}GB "
                      f"u={r.get('useful_ratio', 0):.3f}", flush=True)
            else:
                print(f"    {r}", flush=True)
        with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
