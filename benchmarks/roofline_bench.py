"""Roofline reporting: reads the dry-run result cache (results/dryrun/)
and emits the per-cell three-term table + the markdown used by
EXPERIMENTS.md §Roofline. Also benchmarks the Pallas kernels in interpret
mode against their refs (correctness-trend numbers, not TPU wall time).
"""
import glob
import json
import os

import numpy as np

from benchmarks.common import emit, timeit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun")
MD_OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                      "roofline.md")


def load_cells():
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def roofline_table():
    cells = load_cells()
    if not cells:
        emit("roofline/NO_RESULTS", 0.0,
             "run PYTHONPATH=src python -m repro.launch.dryrun first")
        return
    lines = ["| arch | shape | mesh | mem/dev GB | fits | compute ms | "
             "memory ms | collective ms | bound | MODEL/HLO | +attn |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in cells:
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"— | — | — | — | — | SKIP (full attention) | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | | | | | {r['error'][:40]} | | |")
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 "ERROR")
            continue
        m = r["memory"]
        if "roofline" not in r:      # multi-pod compile-only pass
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{m['total_bytes'] / 1e9:.1f} | {m['fits_hbm']} | "
                f"— | — | — | compile-only | — | — |")
            emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}", 0.0,
                 f"compile_ok;mem_gb={m['total_bytes'] / 1e9:.1f};"
                 f"fits={m['fits_hbm']}")
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{m['total_bytes'] / 1e9:.1f} | {m['fits_hbm']} | "
            f"{t['compute_s'] * 1e3:.1f} | {t['memory_s'] * 1e3:.1f} | "
            f"{t['collective_s'] * 1e3:.1f} | {t['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r.get('useful_ratio_attn', 0):.2f} |")
        emit(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
             t["bound_s"] * 1e6,
             f"bound={t['dominant']};compute_ms={t['compute_s'] * 1e3:.1f};"
             f"memory_ms={t['memory_s'] * 1e3:.1f};"
             f"collective_ms={t['collective_s'] * 1e3:.1f};"
             f"useful={r['useful_ratio']:.2f};fits={m['fits_hbm']}")
    os.makedirs(os.path.dirname(MD_OUT), exist_ok=True)
    with open(MD_OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    ok = [r for r in cells if r["status"] == "ok"]
    if ok:
        fits = sum(1 for r in ok if r["memory"]["fits_hbm"])
        emit("roofline/summary", 0.0,
             f"cells_ok={len(ok)};fits={fits};"
             f"skips={sum(1 for r in cells if r['status'] == 'skip')};"
             f"errors={sum(1 for r in cells if r['status'] == 'error')}")


def kernel_bench():
    import jax
    import jax.numpy as jnp
    from repro.kernels.gram.ops import gram_t
    from repro.kernels.gram.ref import gram_t_ref

    key = jax.random.key(0)
    x = jax.random.normal(key, (4096, 256), jnp.float32)
    y = jax.random.normal(jax.random.fold_in(key, 1), (4096, 258),
                          jnp.float32)
    us_ref, ref = timeit(lambda: gram_t_ref(x, y))
    emit("kernels/gram/xla_ref", us_ref, f"shape=4096x256x258")
    err = float(jnp.max(jnp.abs(
        gram_t(x, y, interpret=True) - ref)))
    emit("kernels/gram/pallas_interpret", 0.0,
         f"allclose_err={err:.2e}(validated; TPU wall-time N/A on CPU)")


def main():
    roofline_table()
    kernel_bench()


if __name__ == "__main__":
    main()
