"""Benchmark entry point — one section per paper table/figure plus the
roofline/dry-run report. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,table5,...]
                                           [--smoke]

``--smoke`` forwards ``smoke=True`` to every selected section that
accepts it (density, tuned) — the CI-sized budgets.
"""
import argparse
import inspect
import sys
import traceback

from benchmarks.common import header

SECTIONS = {}


def _register():
    from benchmarks import paper_lasso, paper_svm, certify, \
        collective_count, density_sweep, recovery, roofline_bench, \
        tuned_vs_default
    SECTIONS.update({
        "certify": certify.main,
        "density": density_sweep.main,
        "tuned": tuned_vs_default.main,
        "recovery": recovery.main,
        "fig2": paper_lasso.fig2_convergence,
        "table3": paper_lasso.table3_relative_error,
        "fig3": paper_lasso.fig3_runtime,
        "table1": paper_lasso.table1_costs,
        "fig4": paper_lasso.fig4_scaling,
        "fig5": paper_svm.fig5_duality_gap,
        "table5": paper_svm.table5_speedups,
        "blocked_svm": paper_svm.blocked_smu_sweep,
        "blocked_svm_model": paper_svm.blocked_model_speedups,
        "kernel_svm": paper_svm.kernel_smu_sweep,
        "kernel_svm_model": paper_svm.kernel_model_speedups,
        "collectives": collective_count.main,
        "roofline": roofline_bench.main,
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated section names")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized budgets for sections that support it")
    args = ap.parse_args()
    _register()
    names = args.only.split(",") if args.only else list(SECTIONS)
    header()
    failures = 0
    for name in names:
        try:
            fn = SECTIONS[name]
            if args.smoke and \
                    "smoke" in inspect.signature(fn).parameters:
                fn(smoke=True)
            else:
                fn()
        except Exception:
            failures += 1
            print(f"{name},0.00,SECTION_ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
