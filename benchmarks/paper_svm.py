"""Paper SVM artifacts: Fig. 5 (duality gap, SA == non-SA), Table V
(speedups at best s from the machine model), and the blocked-SVM
(s, mu) sweep for BDCD / SA-BDCD."""
import dataclasses

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (SVMProblem, SolverConfig, bdcd_svm, dcd_svm,
                        duality_gap, sa_bdcd_svm, sa_svm)
from repro.core.cost_model import (Machine, PAPER_DATASETS, best_s,
                                   svm_speedup)
from repro.data.sparse import make_svm_dataset

H = 512
S_BIG = 64       # paper Fig. 5 uses s=500; s=64 for CPU wall-time


def fig5_duality_gap():
    for ds in ("w1a-like", "duke-like", "rcv1-like", "gisette-like"):
        A, b = make_svm_dataset(ds, seed=0)
        for loss in ("l1", "l2"):
            prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
            cfg = SolverConfig(iterations=H)
            us, res = timeit(lambda: dcd_svm(prob, cfg), repeats=1)
            _, res_sa = timeit(
                lambda: sa_svm(prob, dataclasses.replace(cfg, s=S_BIG)),
                repeats=1)
            o1 = np.asarray(res.objective)
            o2 = np.asarray(res_sa.objective)
            dev = float(np.max(np.abs(o1 - o2)
                               / np.maximum(np.abs(o1), 1e-9)))
            gap = float(duality_gap(prob, res.x, res.aux["alpha"]))
            gap_sa = float(duality_gap(prob, res_sa.x,
                                       res_sa.aux["alpha"]))
            emit(f"fig5/{ds}/svm-{loss}", us / H,
                 f"gap={gap:.4g};gap_sa={gap_sa:.4g};"
                 f"sa_traj_dev={dev:.2e}")


def table5_speedups():
    """Table V: predicted SA-SVM-L1 speedups at the paper's processor
    counts (machine model; paper measured 1.4x/2.1x/4x)."""
    machine = Machine.cray_xc30()
    paper = {"rcv1.binary": (240, 1.4), "news20.binary": (576, 2.1),
             "gisette": (3072, 4.0)}
    for ds, (P, measured) in paper.items():
        dims = PAPER_DATASETS[ds]
        s_star, sp = best_s(dims, H=200_000, mu=1, P=P, machine=machine,
                            kind="svm")
        sp64 = svm_speedup(dims, 200_000, 64, P, machine)
        emit(f"table5/{ds}/P{P}", 0.0,
             f"model_best_s={s_star};model_speedup={sp:.2f};"
             f"model_speedup_s64={sp64:.2f};paper_measured={measured}")


def blocked_smu_sweep():
    """Blocked-SVM sweep over (s, mu): per-iteration wall time, SA == BDCD
    trajectory deviation, and final duality gap for both hinge losses.
    The SA-BDCD rows amortize ONE Allreduce over s block updates."""
    A, b = make_svm_dataset("w1a-like", seed=0)
    for loss in ("l1", "l2"):
        prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
        for mu in (1, 2, 4, 8):
            cfg = SolverConfig(block_size=mu, iterations=H)
            us, res = timeit(lambda: bdcd_svm(prob, cfg), repeats=1)
            o1 = np.asarray(res.objective)
            gap = float(duality_gap(prob, res.x, res.aux["alpha"]))
            emit(f"blocked/w1a-like/svm-{loss}/mu{mu}/s1", us / H,
                 f"dual={o1[-1]:.5f};gap={gap:.4g}")
            for s in (4, 16, 64):
                us_sa, res_sa = timeit(
                    lambda: sa_bdcd_svm(prob, dataclasses.replace(cfg, s=s)),
                    repeats=1)
                o2 = np.asarray(res_sa.objective)
                dev = float(np.max(np.abs(o1 - o2)
                                   / np.maximum(np.abs(o1), 1e-9)))
                emit(f"blocked/w1a-like/svm-{loss}/mu{mu}/s{s}", us_sa / H,
                     f"dual={o2[-1]:.5f};sa_traj_dev={dev:.2e}")


def blocked_model_speedups():
    """Machine-model speedups for SA-BDCD over the (s, mu) grid (Table V
    analogue for the blocked variant)."""
    machine = Machine.cray_xc30()
    for ds, P in (("rcv1.binary", 240), ("news20.binary", 576),
                  ("gisette", 3072)):
        dims = PAPER_DATASETS[ds]
        for mu in (1, 2, 4, 8):
            s_star, sp = best_s(dims, H=200_000, mu=mu, P=P,
                                machine=machine, kind="svm")
            emit(f"blocked_model/{ds}/P{P}/mu{mu}", 0.0,
                 f"model_best_s={s_star};model_speedup={sp:.2f}")


def main():
    fig5_duality_gap()
    table5_speedups()
    blocked_smu_sweep()
    blocked_model_speedups()


if __name__ == "__main__":
    main()
