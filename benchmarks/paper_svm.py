"""Paper SVM artifacts: Fig. 5 (duality gap, SA == non-SA), Table V
(speedups at best s from the machine model), the blocked-SVM (s, mu)
sweep for BDCD / SA-BDCD, and the kernel-SVM (s, mu, kernel) sweep for
K-BDCD / SA-K-BDCD (arXiv:2406.18001)."""
import dataclasses

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (SVMProblem, SolverConfig, bdcd_svm, dcd_svm,
                        duality_gap, kbdcd_svm, kernel_dual_objective,
                        sa_bdcd_svm, sa_kbdcd_svm, sa_svm)
from repro.core.cost_model import (Machine, PAPER_DATASETS, best_s,
                                   svm_speedup)
from repro.data.sparse import make_svm_dataset

H = 512
S_BIG = 64       # paper Fig. 5 uses s=500; s=64 for CPU wall-time


def fig5_duality_gap():
    for ds in ("w1a-like", "duke-like", "rcv1-like", "gisette-like"):
        A, b = make_svm_dataset(ds, seed=0)
        for loss in ("l1", "l2"):
            prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
            cfg = SolverConfig(iterations=H)
            us, res = timeit(lambda: dcd_svm(prob, cfg), repeats=1)
            _, res_sa = timeit(
                lambda: sa_svm(prob, dataclasses.replace(cfg, s=S_BIG)),
                repeats=1)
            o1 = np.asarray(res.objective)
            o2 = np.asarray(res_sa.objective)
            dev = float(np.max(np.abs(o1 - o2)
                               / np.maximum(np.abs(o1), 1e-9)))
            gap = float(duality_gap(prob, res.x, res.aux["alpha"]))
            gap_sa = float(duality_gap(prob, res_sa.x,
                                       res_sa.aux["alpha"]))
            emit(f"fig5/{ds}/svm-{loss}", us / H,
                 f"gap={gap:.4g};gap_sa={gap_sa:.4g};"
                 f"sa_traj_dev={dev:.2e}")


def table5_speedups():
    """Table V: predicted SA-SVM-L1 speedups at the paper's processor
    counts (machine model; paper measured 1.4x/2.1x/4x)."""
    machine = Machine.cray_xc30()
    paper = {"rcv1.binary": (240, 1.4), "news20.binary": (576, 2.1),
             "gisette": (3072, 4.0)}
    for ds, (P, measured) in paper.items():
        dims = PAPER_DATASETS[ds]
        s_star, sp = best_s(dims, H=200_000, mu=1, P=P, machine=machine,
                            kind="svm")
        sp64 = svm_speedup(dims, 200_000, 64, P, machine)
        emit(f"table5/{ds}/P{P}", 0.0,
             f"model_best_s={s_star};model_speedup={sp:.2f};"
             f"model_speedup_s64={sp64:.2f};paper_measured={measured}")


def blocked_smu_sweep():
    """Blocked-SVM sweep over (s, mu): per-iteration wall time, SA == BDCD
    trajectory deviation, and final duality gap for both hinge losses.
    The SA-BDCD rows amortize ONE Allreduce over s block updates."""
    A, b = make_svm_dataset("w1a-like", seed=0)
    for loss in ("l1", "l2"):
        prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
        for mu in (1, 2, 4, 8):
            cfg = SolverConfig(block_size=mu, iterations=H)
            us, res = timeit(lambda: bdcd_svm(prob, cfg), repeats=1)
            o1 = np.asarray(res.objective)
            gap = float(duality_gap(prob, res.x, res.aux["alpha"]))
            emit(f"blocked/w1a-like/svm-{loss}/mu{mu}/s1", us / H,
                 f"dual={o1[-1]:.5f};gap={gap:.4g}")
            for s in (4, 16, 64):
                us_sa, res_sa = timeit(
                    lambda: sa_bdcd_svm(prob, dataclasses.replace(cfg, s=s)),
                    repeats=1)
                o2 = np.asarray(res_sa.objective)
                dev = float(np.max(np.abs(o1 - o2)
                                   / np.maximum(np.abs(o1), 1e-9)))
                emit(f"blocked/w1a-like/svm-{loss}/mu{mu}/s{s}", us_sa / H,
                     f"dual={o2[-1]:.5f};sa_traj_dev={dev:.2e}")


KERNEL_GRID = (("linear", None), ("rbf", {"gamma": 0.1}),
               ("poly", {"degree": 3, "coef0": 1.0, "scale": 0.1}))


def kernel_smu_sweep():
    """Kernel-SVM sweep over kernel x (s, mu): per-iteration wall time,
    SA-K-BDCD == K-BDCD trajectory deviation, and the final dual vs the
    direct m x m quadratic form. One Allreduce per s inner iterations,
    kernelization applied post-reduction (no extra messages)."""
    A, b = make_svm_dataset("w1a-like", seed=0)
    H = 256
    for kern, params in KERNEL_GRID:
        prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2", kernel=kern,
                          kernel_params=params)
        for mu in (1, 4):
            cfg = SolverConfig(block_size=mu, iterations=H)
            us, res = timeit(lambda: kbdcd_svm(prob, cfg), repeats=1)
            o1 = np.asarray(res.objective)
            direct = float(kernel_dual_objective(prob, res.aux["alpha"]))
            emit(f"kernel/w1a-like/{kern}/mu{mu}/s1", us / H,
                 f"dual={o1[-1]:.5f};direct={direct:.5f}")
            for s in (8, 64):
                us_sa, res_sa = timeit(
                    lambda: sa_kbdcd_svm(prob,
                                         dataclasses.replace(cfg, s=s)),
                    repeats=1)
                o2 = np.asarray(res_sa.objective)
                dev = float(np.max(np.abs(o1 - o2)
                                   / np.maximum(np.abs(o1), 1e-9)))
                emit(f"kernel/w1a-like/{kern}/mu{mu}/s{s}", us_sa / H,
                     f"dual={o2[-1]:.5f};sa_traj_dev={dev:.2e};"
                     f"impl={res_sa.aux['inner_impl']}")


def kernel_model_speedups():
    """Machine-model speedups for SA-K-BDCD: the kernel path moves the
    (m, s*mu) cross block instead of the (s*mu, s*mu+1) Gram, so the
    best-s optimum shifts toward smaller s on bandwidth-bound machines."""
    machine = Machine.cray_xc30()
    for ds, P in (("rcv1.binary", 240), ("gisette", 3072)):
        dims = PAPER_DATASETS[ds]
        for kern in ("linear", "rbf"):
            for mu in (1, 8):
                s_star, sp = best_s(dims, H=200_000, mu=mu, P=P,
                                    machine=machine, kind="svm",
                                    kernel=kern)
                emit(f"kernel_model/{ds}/P{P}/{kern}/mu{mu}", 0.0,
                     f"model_best_s={s_star};model_speedup={sp:.2f}")


def blocked_model_speedups():
    """Machine-model speedups for SA-BDCD over the (s, mu) grid (Table V
    analogue for the blocked variant)."""
    machine = Machine.cray_xc30()
    for ds, P in (("rcv1.binary", 240), ("news20.binary", 576),
                  ("gisette", 3072)):
        dims = PAPER_DATASETS[ds]
        for mu in (1, 2, 4, 8):
            s_star, sp = best_s(dims, H=200_000, mu=mu, P=P,
                                machine=machine, kind="svm")
            emit(f"blocked_model/{ds}/P{P}/mu{mu}", 0.0,
                 f"model_best_s={s_star};model_speedup={sp:.2f}")


def main():
    fig5_duality_gap()
    table5_speedups()
    blocked_smu_sweep()
    blocked_model_speedups()
    kernel_smu_sweep()
    kernel_model_speedups()


if __name__ == "__main__":
    main()
