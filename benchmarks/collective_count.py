"""Structural verification of the SA claim on the compiled artifacts:
count collectives (static ops x scan trip counts) in the distributed
solver HLO for several s — for EVERY registered problem family (the
list comes from ``repro.api.FAMILIES``, so a newly registered family is
verified here with zero benchmark edits). This is the dry-run analogue
of the paper's latency measurements: runtime messages per solve =
static collectives x trips.

Runs in a subprocess with 8 placeholder devices (the bench process keeps
1 device).
"""
import os
import re
import subprocess
import sys

from benchmarks.common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re, jax
from repro.core import api
from repro.core.types import FAMILIES, SolverConfig
from repro.roofline.analysis import collective_bytes_from_hlo

H = 64
# representative shapes per partition layout: row-partitioned families
# shard data points, column-partitioned ones shard features.
SHAPES = {"row": (512, 128), "col": (256, 512)}
meshes = {}
for name in sorted(FAMILIES):
    fam = FAMILIES[name]
    axis = fam.default_axes if isinstance(fam.default_axes, str) \
        else fam.default_axes[0]
    if axis not in meshes:
        meshes[axis] = jax.make_mesh((8,), (axis,))
    m, n = SHAPES[fam.partition]
    for s in (1, 4, 16):
        cfg = SolverConfig(block_size=fam.bench_block_size, iterations=H,
                           s=s, track_objective=False)
        txt = api.lower_solve(name, cfg, meshes[axis], m=m, n=n,
                              axes=axis).compile().as_text()
        static = len(re.findall(r"= \S+ all-reduce\(", txt))
        trips = H // s
        bytes_ = collective_bytes_from_hlo(txt)["total"]
        print(f"{name.upper()} s={s} static={static} trips={trips} "
              f"runtime_msgs={static * trips} bytes_per_outer={bytes_}")
"""


def main():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        emit("collective_count/ERROR", 0.0, out.stderr[-300:].replace(
            "\n", " ")[:200])
        return
    rows = {}
    statics = {}
    kinds = []
    for line in out.stdout.splitlines():
        m = re.match(r"([A-Z]+) s=(\d+) static=(\d+) trips=(\d+) "
                     r"runtime_msgs=(\d+) bytes_per_outer=(\d+)", line)
        if m:
            kind, s, static, trips, msgs, bytes_ = m.groups()
            if kind not in kinds:
                kinds.append(kind)
            rows[(kind, int(s))] = int(msgs)
            statics[(kind, int(s))] = int(static)
            emit(f"collective_count/{kind.lower()}/s{s}", 0.0,
                 f"static={static};trips={trips};runtime_msgs={msgs};"
                 f"bytes_per_outer={bytes_}")
    for kind in kinds:
        if (kind, 1) in rows and (kind, 16) in rows:
            red = rows[(kind, 1)] / max(rows[(kind, 16)], 1)
            emit(f"collective_count/{kind.lower()}/reduction_s16", 0.0,
                 f"latency_reduction={red:.1f}x(expected~16x)")
    # the SA claim, structurally: ONE Allreduce per outer iteration,
    # for every registered family.
    if statics:
        worst = max(statics.values())
        emit("collective_count/one_allreduce_per_outer", 0.0,
             f"max_static={worst};families={len(kinds)};ok={worst == 1}")


if __name__ == "__main__":
    main()
