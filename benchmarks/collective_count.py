"""Structural verification of the SA claim — now via ``repro.analysis``.

Two complementary views, for EVERY registered problem family (the list
comes from the registry, so a newly registered family is verified here
with zero benchmark edits):

  * **static (in-process)** — ``repro.analysis.solver_collective_budget``
    walks the traced jaxpr and reports, per family x s: the in-loop
    collective counts by type, the all-reduce payload bytes per OUTER
    iteration, and runtime messages per solve (= in-loop all-reduces x
    outer trips). This is the dry-run analogue of the paper's latency
    measurements and needs no devices at all.
  * **compiled (subprocess, 8 placeholder devices)** — the post-SPMD
    HLO of the same lowering, parsed with
    ``repro.roofline.analysis.collective_stats_from_hlo``, cross-checks
    that XLA kept exactly the collectives the jaxpr promised (the bench
    process keeps 1 device; forcing devices needs XLA_FLAGS before jax
    imports, hence the subprocess).
"""
import os
import re
import subprocess
import sys

from benchmarks.common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

H = 64
S_VALUES = (1, 4, 16)

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core import api
from repro.core.types import FAMILIES, SolverConfig
from repro.roofline.analysis import collective_stats_from_hlo

H = 64
SHAPES = {"row": (512, 128), "col": (256, 512)}
meshes = {}
for name in sorted(FAMILIES):
    fam = FAMILIES[name]
    axis = fam.default_axes if isinstance(fam.default_axes, str) \
        else fam.default_axes[0]
    if axis not in meshes:
        meshes[axis] = jax.make_mesh((8,), (axis,))
    m, n = SHAPES[fam.partition]
    for s in (1, 4, 16):
        cfg = SolverConfig(block_size=fam.bench_block_size, iterations=H,
                           s=s, track_objective=False)
        txt = api.lower_solve(name, cfg, meshes[axis], m=m, n=n,
                              axes=axis).compile().as_text()
        stats = collective_stats_from_hlo(txt)
        others = stats.total_count - stats.counts["all-reduce"]
        print(f"{name.upper()} s={s} "
              f"compiled_allreduce={stats.counts['all-reduce']} "
              f"compiled_other={others}")
"""


def static_rows():
    """The jaxpr-level budget rows, in-process (1 device is enough: the
    trace is symbolic) — assembled by the analyzer's shared
    ``budget_rows`` helper, not re-derived here."""
    sys.path.insert(0, SRC)
    from repro.analysis import budget_rows
    return budget_rows(s_values=S_VALUES, iterations=H)


def main():
    rows = static_rows()
    kinds = sorted({name for name, _ in rows})
    for (name, s), row in sorted(rows.items()):
        emit(f"collective_count/{name}/s{s}", 0.0,
             f"static={row.allreduces_in_loop};"
             f"other_collectives={row.other_collectives};"
             f"trips={row.trips};runtime_msgs={row.runtime_messages};"
             f"bytes_per_outer={row.bytes_per_outer:.0f}")
    for name in kinds:
        red = rows[(name, 1)].runtime_messages \
            / max(rows[(name, 16)].runtime_messages, 1)
        emit(f"collective_count/{name}/reduction_s16", 0.0,
             f"latency_reduction={red:.1f}x(expected~16x)")
    # the SA claim, structurally: ONE in-loop Allreduce per outer
    # iteration and zero other collectives, for every registered family.
    worst = max(r.allreduces_in_loop for r in rows.values())
    extra = max(r.other_collectives for r in rows.values())
    emit("collective_count/one_allreduce_per_outer", 0.0,
         f"max_static={worst};max_other={extra};families={len(kinds)};"
         f"ok={worst == 1 and extra == 0}")

    # cross-check against the compiled 8-device artifacts.
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        emit("collective_count/compiled/ERROR", 0.0,
             out.stderr[-300:].replace("\n", " ")[:200])
        return
    agree = True
    for line in out.stdout.splitlines():
        m = re.match(r"([A-Z]+) s=(\d+) compiled_allreduce=(\d+) "
                     r"compiled_other=(\d+)", line)
        if m:
            kind, s, ar, other = m.groups()
            want = sum(rows[(kind.lower(), int(s))].budget.total.values())
            agree &= int(ar) + int(other) == want
            emit(f"collective_count/{kind.lower()}/s{s}/compiled", 0.0,
                 f"allreduce={ar};other={other};jaxpr_total={want}")
    emit("collective_count/compiled_matches_jaxpr", 0.0, f"ok={agree}")


if __name__ == "__main__":
    main()
