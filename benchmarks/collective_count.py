"""Structural verification of the SA claim on the compiled artifacts:
count collectives (static ops x scan trip counts) in the distributed
solver HLO for several s, and in the trainer for several microbatch
settings. This is the dry-run analogue of the paper's latency
measurements: runtime messages per solve = static collectives x trips.

Runs in a subprocess with 8 placeholder devices (the bench process keeps
1 device).
"""
import os
import re
import subprocess
import sys

from benchmarks.common import emit

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re, jax
from repro.core.distributed import lower_lasso_step, lower_svm_step
from repro.core.types import SolverConfig
from repro.roofline.analysis import collective_bytes_from_hlo

mesh = jax.make_mesh((8,), ("data",))
mesh_m = jax.make_mesh((8,), ("model",))
H = 64
for s in (1, 4, 16):
    cfg = SolverConfig(block_size=4, iterations=H, s=s,
                       track_objective=False)
    txt = lower_lasso_step(cfg, mesh, m=512, n=128).compile().as_text()
    static = len(re.findall(r"= \S+ all-reduce\(", txt))
    trips = H // s
    bytes_ = collective_bytes_from_hlo(txt)["total"]
    print(f"LASSO s={s} static={static} trips={trips} "
          f"runtime_msgs={static * trips} bytes_per_outer={bytes_}")
for s in (1, 4, 16):
    cfg = SolverConfig(block_size=1, iterations=H, s=s,
                       track_objective=False)
    txt = lower_svm_step(cfg, mesh_m, m=256, n=512).compile().as_text()
    static = len(re.findall(r"= \S+ all-reduce\(", txt))
    trips = H // s
    print(f"SVM s={s} static={static} trips={trips} "
          f"runtime_msgs={static * trips}")
# Kernel SVM (SA-K-BDCD): the rbf norms column rides the same fused
# Allreduce, so the kernelized solver must ALSO show exactly one static
# all-reduce per outer (s-step) iteration.
for s in (1, 4, 16):
    cfg = SolverConfig(block_size=2, iterations=H, s=s,
                       track_objective=False)
    txt = lower_svm_step(cfg, mesh_m, m=256, n=512, kernel="rbf",
                         kernel_params={"gamma": 0.1}).compile().as_text()
    static = len(re.findall(r"= \S+ all-reduce\(", txt))
    trips = H // s
    print(f"KSVM s={s} static={static} trips={trips} "
          f"runtime_msgs={static * trips}")
"""


def main():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", CODE], env=env,
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        emit("collective_count/ERROR", 0.0, out.stderr[-300:].replace(
            "\n", " ")[:200])
        return
    rows = {}
    statics = {}
    for line in out.stdout.splitlines():
        m = re.match(r"(LASSO|SVM|KSVM) s=(\d+) static=(\d+) trips=(\d+) "
                     r"runtime_msgs=(\d+)", line)
        if m:
            kind, s, static, trips, msgs = m.groups()
            rows[(kind, int(s))] = int(msgs)
            statics[(kind, int(s))] = int(static)
            emit(f"collective_count/{kind.lower()}/s{s}", 0.0,
                 f"static={static};trips={trips};runtime_msgs={msgs}")
    for kind in ("LASSO", "SVM", "KSVM"):
        if (kind, 1) in rows and (kind, 16) in rows:
            red = rows[(kind, 1)] / max(rows[(kind, 16)], 1)
            emit(f"collective_count/{kind.lower()}/reduction_s16", 0.0,
                 f"latency_reduction={red:.1f}x(expected~16x)")
    # the SA claim, structurally: ONE Allreduce per outer iteration.
    if statics:
        worst = max(statics.values())
        emit("collective_count/one_allreduce_per_outer", 0.0,
             f"max_static={worst};ok={worst == 1}")


if __name__ == "__main__":
    main()
