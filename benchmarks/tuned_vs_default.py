"""Tuned vs default: does closing the model -> measurement loop pay?

    PYTHONPATH=src python -m benchmarks.tuned_vs_default [--smoke]

For each synthetic regime x family, run the full ``repro.tune`` loop
with a FRESH calibration (no cache) and record into
``results/perf/tuned.json``:

* the calibration evidence — measured vs calibrated-model predicted
  seconds for every pilot-grid point (acceptance bar: every point
  within 2x);
* the head-to-head — the tuner-selected config vs the benchmark-default
  config (the (s, mu) the earlier benchmarks hardcode) at the full
  iteration budget (acceptance bar: tuned no slower than default).
  When the selection differs from the default, the reported times ARE
  the incumbent guard's own full-budget measurements (best-of-3 via
  ``measure_solve``) — a selection that loses that head-to-head is
  discarded in favor of the default before it is ever reported;
  ``repeats`` only applies to the fallback measurement when the tuner
  kept the default outright.

``--smoke`` shrinks the pilot/measure budgets for CI; the committed
json comes from a full run.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

from benchmarks.common import emit, header

from repro import tune as tune_mod
from repro.api import (LassoProblem, LogRegProblem, SolverConfig,
                       resolve_family)
from repro.data.sparse import make_lasso_dataset, make_svm_dataset
from repro.tune.calibrate import measure_solve, problem_dims
from repro.tune.select import predicted_solve_time

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "perf", "tuned.json")


def _lasso_problem(regime: str):
    A, b, lam_max = make_lasso_dataset(regime, seed=0)
    return LassoProblem(A=A, b=b, lam=0.1 * lam_max)


def _logreg_problem(regime: str):
    A, b = make_svm_dataset(regime, seed=0)
    return LogRegProblem(A=A, b=b, lam=1e-3)


# regime x family cases; the default (s, mu) mirrors what the earlier
# benchmarks hardcode (density_sweep / paper_lasso style defaults).
# Both regimes are the paper's sparse n >= m shapes, where the fused
# Gram/cross GEMMs behave like the model's flop term. (covtype-like,
# m >> n, is a known model limit: its s-fold flop growth is masked by
# s-fold BLAS efficiency growth, so no single gamma fits the s sweep —
# see DESIGN.md "Autotuning".)
CASES = (
    ("news20-like", "lasso", _lasso_problem),
    ("url-like", "lasso", _lasso_problem),
    ("news20-like", "logreg", _logreg_problem),
    ("url-like", "logreg", _logreg_problem),
)


def run_case(regime: str, family: str, make_problem, H: int,
             pilot_iters: int, repeats: int) -> dict:
    problem = make_problem(regime)
    fam = resolve_family(problem)
    default = SolverConfig(block_size=8, s=16, iterations=H,
                           accelerated=False, track_objective=False)
    res = tune_mod.tune(problem, default, family=fam, cache=False,
                        pilot_iters=pilot_iters, guard_iters=H)
    tuned = res.config

    same = (tuned.s, tuned.block_size, tuned.use_pallas,
            tuned.symmetric_gram) == \
           (default.s, default.block_size, default.use_pallas,
            default.symmetric_gram)
    if res.guard_times is not None:
        # the incumbent guard already measured this exact head-to-head
        # at the full H budget — reuse it instead of re-timing two
        # full solves (the dominant cost of this section).
        t_default = res.guard_times["incumbent_s"]
        t_tuned = t_default if same else res.guard_times["selected_s"]
    else:
        t_default = measure_solve(problem, fam, default,
                                  repeats=repeats)
        t_tuned = t_default if same \
            else measure_solve(problem, fam, tuned, repeats=repeats)

    dims = problem_dims(problem)
    kernel = getattr(problem, "kernel", "linear")
    row = {
        "regime": regime, "family": fam.name,
        "m": dims.m, "n": dims.n, "f": dims.f, "H": H,
        "machine": dataclasses.asdict(res.machine),
        "calibration": res.calibration.to_dict(),
        "calibration_max_ratio": res.calibration.max_ratio,
        "default": {"s": default.s, "mu": default.block_size},
        "tuned": {"s": tuned.s, "mu": tuned.block_size,
                  "use_pallas": tuned.use_pallas,
                  "symmetric_gram": tuned.symmetric_gram},
        "predicted_default_s": predicted_solve_time(
            fam, dims, default, res.machine, kernel=kernel),
        "predicted_tuned_s": predicted_solve_time(
            fam, dims, tuned, res.machine, kernel=kernel),
        "default_s": t_default, "tuned_s": t_tuned,
        "speedup": t_default / t_tuned,
    }
    emit(f"tuned/{regime}/{fam.name}", t_tuned * 1e6,
         f"default_us={t_default * 1e6:.0f};"
         f"speedup={row['speedup']:.2f};"
         f"s={tuned.s};mu={tuned.block_size};"
         f"calib_max_ratio={res.calibration.max_ratio:.2f}")
    return row


def main(smoke: bool = False):
    if smoke:
        H, pilot_iters, repeats = 48, 16, 2
    else:
        H, pilot_iters, repeats = 192, 48, 5
    rows = [run_case(regime, family, make, H, pilot_iters, repeats)
            for regime, family, make in CASES]
    worst_ratio = max(r["calibration_max_ratio"] for r in rows)
    min_speedup = min(r["speedup"] for r in rows)
    payload = {"cases": rows, "smoke": smoke,
               "worst_calibration_ratio": worst_ratio,
               "min_speedup": min_speedup}
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"wrote {os.path.normpath(OUT_PATH)} "
          f"(worst calibration ratio {worst_ratio:.2f}, "
          f"min tuned speedup {min_speedup:.2f})")
    # acceptance bars: strict for the full run (the committed json);
    # smoke mode measures sub-100ms solves best-of-2 on shared CI
    # runners, so it gates with noise headroom instead of flaking.
    ratio_bar, speedup_bar = (3.0, 0.85) if smoke else (2.0, 0.97)
    assert worst_ratio <= ratio_bar, \
        f"calibrated model off by >{ratio_bar}x on a pilot point: " \
        f"{worst_ratio}"
    assert min_speedup >= speedup_bar, \
        f"tuner-selected config measurably slower than default: " \
        f"{min_speedup}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small pilot/measure budgets (CI)")
    args = ap.parse_args()
    header()
    main(smoke=args.smoke)
