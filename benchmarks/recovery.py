"""Elastic recovery cost vs checkpoint interval. Writes
``results/perf/recovery.json`` plus the usual CSV rows.

    PYTHONPATH=src python -m benchmarks.recovery [--smoke]

For each checkpoint interval the same Lasso solve runs twice through
``repro.runtime.solve_elastic`` on a 4-device mesh: once undisturbed
(baseline) and once with one host killed mid-run. The recovery cost is

  * ``restore_seconds``        — checkpoint read + state rebuild alone;
  * ``overhead_seconds``       — disturbed minus baseline wall-clock:
                                 restore + smaller-mesh recompile + the
                                 rolled-back iterations replayed on 3
                                 hosts;
  * ``rolled_back_iterations`` — failure step minus resumed iteration:
                                 the work the failure destroyed. Grows
                                 with the interval — sparse checkpoints
                                 are cheap until a host dies.

Needs >= 2 devices; when the interpreter was started with a single
device (no XLA_FLAGS), the measurement re-execs itself in a subprocess
with 4 forced CPU devices (the flag must be set before jax imports).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

from benchmarks.common import emit

ROOT = os.path.join(os.path.dirname(__file__), "..")
OUT_PATH = os.path.join(ROOT, "results", "perf", "recovery.json")
_SUBPROC_FLAG = "_REPRO_RECOVERY_SUBPROC"


def _measure(smoke: bool) -> None:
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.api import LassoProblem, SolverConfig
    from repro.runtime import ElasticConfig, FailureInjector, solve_elastic

    n_dev = len(jax.devices())
    if n_dev < 2:
        raise RuntimeError(
            "recovery benchmark needs >= 2 devices; set XLA_FLAGS="
            "--xla_force_host_platform_device_count=4 before jax imports")

    rng = np.random.default_rng(3)
    m, n = (40, 64) if smoke else (120, 256)
    H = 24 if smoke else 96
    A = rng.standard_normal((m, n)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    lam = 0.1 * float(np.abs(A.T @ b).max())
    prob = LassoProblem(A=jnp.asarray(A), b=jnp.asarray(b), lam=lam)
    cfg = SolverConfig(block_size=4, s=2, iterations=H,
                       track_objective=False)
    intervals = (1, 4) if smoke else (1, 2, 4, 8)
    # one step BEFORE a boundary of the coarsest interval (step = -1 mod
    # max_seg_len), so the rolled-back work actually scales with the
    # interval: 1, 3, 7, 15 iterations here. H//2+1 would sit right
    # after a boundary common to EVERY interval and report 1 across the
    # board.
    max_seg = max(intervals) * cfg.s
    fail_step = (H // 2 // max_seg + 1) * max_seg - 1

    def run(ck_every, failures):
        with tempfile.TemporaryDirectory() as d:
            inj = FailureInjector(failures=dict(failures)) if failures \
                else None
            t0 = time.perf_counter()
            res = solve_elastic(
                prob, cfg,
                elastic=ElasticConfig(checkpoint_dir=d,
                                      checkpoint_every=ck_every,
                                      keep=4),
                injector=inj)
            jax.block_until_ready(res.x)
            return time.perf_counter() - t0, res.aux["elastic"]

    entries = []
    for ck in intervals:
        # warm the segment compiles for BOTH mesh sizes (4-host and the
        # post-failure 3-host) so the timed delta is restore + replay,
        # not jit compilation.
        run(ck, None)
        run(ck, {1: [1]})
        base_s, _ = run(ck, None)
        dist_s, report = run(ck, {fail_step: [1]})
        rec = report["recoveries"][0]
        rolled_back = fail_step - rec["resumed_iteration"]
        entry = {
            "checkpoint_every": ck,
            "failure_step": fail_step,
            "baseline_seconds": base_s,
            "disturbed_seconds": dist_s,
            "overhead_seconds": dist_s - base_s,
            "restore_seconds": rec["restore_seconds"],
            "resumed_iteration": rec["resumed_iteration"],
            "rolled_back_iterations": rolled_back,
            "n_hosts_final": rec["n_hosts"],
        }
        entries.append(entry)
        emit(f"recovery/ck{ck}", (dist_s - base_s) * 1e6,
             f"restore={rec['restore_seconds']:.4f}s "
             f"rolled_back={rolled_back}it "
             f"hosts={n_dev}->{rec['n_hosts']}")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump({"devices": n_dev, "iterations": H, "s": cfg.s,
                   "failure_step": fail_step, "smoke": smoke,
                   "sweep": entries}, fh, indent=1)
    print(f"# wrote {os.path.relpath(OUT_PATH, ROOT)}", flush=True)


def main(smoke: bool = False) -> None:
    import jax
    if len(jax.devices()) >= 2 or os.environ.get(_SUBPROC_FLAG) == "1":
        _measure(smoke)
        return
    # jax is already initialized single-device in this process; re-exec
    # with forced host devices (must precede jax import).
    env = dict(os.environ, XLA_FLAGS=
               "--xla_force_host_platform_device_count=4")
    env[_SUBPROC_FLAG] = "1"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, "-m", "benchmarks.recovery"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(cmd, env=env, cwd=os.path.abspath(ROOT),
                         capture_output=True, text=True)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-3000:])
        raise RuntimeError("recovery subprocess failed")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    main(smoke=args.smoke)
