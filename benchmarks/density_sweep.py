"""Density sweep: the sparse-operand execution path vs the dense path,
and the cost model's density parameter f validated against EXECUTED
flops. Writes ``results/perf/sparse.json`` plus the usual CSV rows.

    PYTHONPATH=src python -m benchmarks.density_sweep [--smoke]

Two sections:

* **sweep** — a fixed (m, n) Lasso shape at several densities: dense vs
  sparse (SA-BCD, objective tracking off so the timed work is the
  solver's data-dependent path), the executed sparse flops of the fused
  Gram/projection product (counted EXACTLY from the operand's per-column
  nnz and the solver's own block draws), and the cost model's
  data-dependent flop term H mu^2 s f m. The model carries no leading
  constant, so the validation is that executed / model is a CONSTANT
  across densities (the model's f tracks executed work linearly) — the
  per-density ratios land in the json.
* **news20-like** — the paper regime this repo's headline depends on
  (sparse, n >> m): end-to-end dense vs sparse wall-clock through
  ``repro.api.solve`` for Lasso and logreg; the acceptance bar is a
  measured sparse-path win (speedup > 1).

``--smoke`` shrinks shapes/iterations for CI and additionally runs the
blocked-ELL Pallas kernel in interpret mode against its jnp oracle
(the sparse path's kernel-level parity gate on CPU runners).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import emit, header, timeit

import jax
import jax.numpy as jnp

from repro import api
from repro.api import LassoProblem, LogRegProblem, SolverConfig
from repro.core import linalg
from repro.core.cost_model import ProblemDims, lasso_costs
from repro.core.types import SparseOperand
from repro.data.sparse import _sparse_matrix, make_lasso_dataset, \
    make_svm_dataset
from repro.kernels import spmm

OUT_PATH = os.path.join(os.path.dirname(__file__), "..", "results",
                        "perf", "sparse.json")


def _lasso_problem(rng, m, n, density):
    A = _sparse_matrix(rng, m, n, density)
    x_true = np.zeros(n, np.float32)
    x_true[:16] = rng.standard_normal(16).astype(np.float32)
    b = (A @ x_true + 0.1 * rng.standard_normal(m)).astype(np.float32)
    lam = 0.1 * float(np.abs(A.T @ b).max())
    return A, b, lam


def _executed_gram_flops(op: SparseOperand, cfg: SolverConfig) -> float:
    """EXACT multiply-add count of the sparse fused Gram/projection
    products the SA-BCD Lasso solve executes: replay the solver's own
    block draws (same key / fold_in ids) and charge each outer group
    2 * (group_cols + 1) * nnz(sampled columns) — each stored nonzero
    meets every column of [Y | r] once."""
    col_nnz = (np.asarray(op.col_vals) != 0).sum(axis=1)
    n = op.shape[1]
    key = jax.random.key(cfg.seed)
    draws = jax.vmap(
        lambda h: linalg.sample_block(jax.random.fold_in(key, h), n,
                                      cfg.block_size))(
        jnp.arange(1, cfg.iterations + 1))
    draws = np.asarray(draws)                       # (H, mu)
    full, rem = divmod(cfg.iterations, cfg.s)
    flops = 0.0
    for g in range(full + (1 if rem else 0)):
        s_grp = cfg.s if g < full else rem
        cols = draws[g * cfg.s:g * cfg.s + s_grp].reshape(-1)
        flops += 2.0 * (cols.size + 1) * float(col_nnz[cols].sum())
    return flops


def _solve_pair(A, op, b, problem_fn, cfg, repeats=3):
    """(dense_us, sparse_us) steady-state execution times for one
    problem through ``repro.api.solve``. Each path is jitted ONCE (the
    operand is a pytree, so it passes straight through jit) — the first
    ``timeit`` call is the compile warmup, the timed repeats measure the
    solve itself, which is what the SA trade-off is about."""
    def run(mat):
        fn = jax.jit(lambda a, bb: api.solve(problem_fn(a, bb), cfg).x)
        us, _ = timeit(
            lambda: jax.block_until_ready(fn(mat, jnp.asarray(b))),
            repeats=repeats)
        return us

    return run(jnp.asarray(A)), run(op)


def density_sweep(m=1024, n=4096, H=192, s=16, mu=8,
                  densities=(0.002, 0.01, 0.05, 0.2)):
    rng = np.random.default_rng(0)
    cfg = SolverConfig(block_size=mu, s=s, iterations=H,
                       accelerated=False, track_objective=False)
    rows = []
    for f in densities:
        A, b, lam = _lasso_problem(rng, m, n, f)
        op = SparseOperand.from_dense(A)
        us_d, us_s = _solve_pair(
            A, op, b, lambda a, bb: LassoProblem(A=a, b=bb, lam=lam), cfg)
        executed = _executed_gram_flops(op, cfg)
        dims = ProblemDims(m=m, n=n, f=op.nnz / (m * n))
        # the model's data-dependent term only (the H mu^3 subproblem
        # flops are density-independent and identical on both paths).
        model = lasso_costs(dims, H, mu, s, 1)["F"] - H * mu ** 3
        row = {"density": float(f), "m": m, "n": n, "nnz": op.nnz,
               "H": H, "s": s, "mu": mu,
               "dense_us": us_d, "sparse_us": us_s,
               "speedup": us_d / us_s,
               "executed_gram_flops": executed,
               "model_data_flops": model,
               "executed_over_model": executed / model}
        rows.append(row)
        emit(f"density/{f:g}", us_s,
             f"dense_us={us_d:.0f};speedup={row['speedup']:.2f};"
             f"exec_over_model={row['executed_over_model']:.3f}")
    return rows


def news20_like(H=192, s=16, mu=8, iterations_logreg=128):
    """End-to-end dense vs sparse on the news20-like regime (the paper's
    sparsest Lasso dataset shape: n >> m, f ~ 1e-3)."""
    out = {}
    cfg = SolverConfig(block_size=mu, s=s, iterations=H,
                       accelerated=False, track_objective=False)
    A, b, lam = make_lasso_dataset("news20-like", seed=0)
    opA, _, _ = make_lasso_dataset("news20-like", seed=0, as_operand=True)
    us_d, us_s = _solve_pair(
        A, opA, b, lambda a, bb: LassoProblem(A=a, b=bb, lam=lam), cfg)
    out["lasso"] = {"dense_us": us_d, "sparse_us": us_s,
                    "speedup": us_d / us_s}
    emit("news20-like/lasso", us_s,
         f"dense_us={us_d:.0f};speedup={us_d / us_s:.2f}")

    cfg_lr = SolverConfig(block_size=mu, s=s,
                          iterations=iterations_logreg,
                          track_objective=False)
    As, bs = make_svm_dataset("news20-like", seed=0)
    opS, _ = make_svm_dataset("news20-like", seed=0, as_operand=True)
    us_d, us_s = _solve_pair(
        As, opS, bs,
        lambda a, bb: LogRegProblem(A=a, b=bb, lam=1e-3), cfg_lr)
    out["logreg"] = {"dense_us": us_d, "sparse_us": us_s,
                     "speedup": us_d / us_s}
    emit("news20-like/logreg", us_s,
         f"dense_us={us_d:.0f};speedup={us_d / us_s:.2f}")
    return out


def interpret_parity():
    """Blocked-ELL Pallas kernel (interpret mode) vs the jnp oracle —
    the CI gate for the sparse hot path on CPU runners."""
    rng = np.random.default_rng(1)
    A = rng.standard_normal((96, 64)).astype(np.float32)
    A[rng.random(A.shape) < 0.9] = 0.0
    op = SparseOperand.from_dense(A)
    D = rng.standard_normal((64, 24)).astype(np.float32)
    ref = np.asarray(spmm.ell_spmm(op.row_vals, op.row_cols,
                                   op.row_blocks, jnp.asarray(D),
                                   ell_block=op.ell_block))
    pal = np.asarray(spmm.ell_spmm(op.row_vals, op.row_cols,
                                   op.row_blocks, jnp.asarray(D),
                                   ell_block=op.ell_block,
                                   interpret=True))
    err = float(np.max(np.abs(ref - pal)))
    emit("interpret_parity/ell_spmm", 0.0, f"max_err={err:.2e}")
    assert err < 1e-4, f"pallas interpret parity failed: {err}"
    assert np.allclose(ref, A @ D, atol=1e-4)
    return err


def main(smoke: bool = False):
    if smoke:
        rows = density_sweep(m=192, n=384, H=48, s=8, mu=4,
                             densities=(0.01, 0.1))
        news = news20_like(H=48, s=8, mu=8, iterations_logreg=24)
        err = interpret_parity()
    else:
        rows = density_sweep()
        news = news20_like()
        err = interpret_parity()
    payload = {"sweep": rows, "news20-like": news,
               "interpret_parity_max_err": err,
               "smoke": smoke}
    with open(OUT_PATH, "w") as fh:
        json.dump(payload, fh, indent=1)
    print(f"wrote {os.path.normpath(OUT_PATH)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + interpret-mode parity (CI)")
    args = ap.parse_args()
    header()
    main(smoke=args.smoke)
