"""Paper Lasso artifacts: Fig. 2 (convergence vs iteration), Table III
(relative objective error), Fig. 3 (convergence vs modeled running time),
Fig. 4 / Table I (costs + strong-scaling speedups)."""
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import (LassoProblem, SolverConfig, acc_bcd_lasso,
                        bcd_lasso, sa_acc_bcd_lasso, sa_bcd_lasso)
from repro.core.cost_model import (Machine, PAPER_DATASETS, ProblemDims,
                                   best_s, lasso_costs, lasso_speedup,
                                   predicted_time)
from repro.data.sparse import SYNTHETIC_DATASETS, make_lasso_dataset

FIG2_DATASETS = ("news20-like", "covtype-like", "epsilon-like", "leu-like")
H = 384
S_BIG = 64           # paper uses s=1000; s=64 keeps CPU wall-time sane —
#                      the equivalence claim is s-independent (tests cover
#                      more values; f64 parity in test_sa_equivalence).


def _methods(mu):
    return [
        (f"CD(mu=1)", bcd_lasso, sa_bcd_lasso,
         SolverConfig(block_size=1, iterations=H, accelerated=False)),
        (f"accCD(mu=1)", acc_bcd_lasso, sa_acc_bcd_lasso,
         SolverConfig(block_size=1, iterations=H)),
        (f"BCD(mu={mu})", bcd_lasso, sa_bcd_lasso,
         SolverConfig(block_size=mu, iterations=H, accelerated=False)),
        (f"accBCD(mu={mu})", acc_bcd_lasso, sa_acc_bcd_lasso,
         SolverConfig(block_size=mu, iterations=H)),
    ]


def fig2_convergence():
    """Fig. 2: SA (s=S_BIG) vs classical trajectories per method/dataset;
    derived = final objective + max trajectory deviation."""
    import dataclasses
    for ds in FIG2_DATASETS:
        A, b, lam_max = make_lasso_dataset(ds, seed=0)
        prob = LassoProblem(A=A, b=b, lam=0.1 * lam_max)
        for name, base_fn, sa_fn, cfg in _methods(8):
            us, res = timeit(lambda: base_fn(prob, cfg), repeats=1)
            sa_cfg = dataclasses.replace(cfg, s=S_BIG)
            _, res_sa = timeit(lambda: sa_fn(prob, sa_cfg), repeats=1)
            o1 = np.asarray(res.objective)
            o2 = np.asarray(res_sa.objective)
            dev = float(np.max(np.abs(o1 - o2) / np.abs(o1)))
            emit(f"fig2/{ds}/{name}", us / H,
                 f"obj0={o1[0]:.4g};objH={o1[-1]:.4g};"
                 f"sa_traj_dev={dev:.2e};decreased={o1[-1] < o1[0]}")


def table3_relative_error():
    """Table III: |f_nonSA - f_SA| / f_nonSA at H, f32 in-process and f64
    in a subprocess (paper reports ~1e-16 in double precision)."""
    import dataclasses
    for ds in ("leu-like", "covtype-like", "news20-like"):
        A, b, lam_max = make_lasso_dataset(ds, seed=0)
        prob = LassoProblem(A=A, b=b, lam=0.1 * lam_max)
        for name, base_fn, sa_fn, cfg in _methods(8):
            r1 = base_fn(prob, cfg)
            r2 = sa_fn(prob, dataclasses.replace(cfg, s=S_BIG))
            rel = abs(float(r1.objective[-1]) - float(r2.objective[-1])) \
                / abs(float(r1.objective[-1]))
            emit(f"table3/{ds}/{name}", 0.0, f"rel_err_f32={rel:.3e}")
    # f64 parity (machine-epsilon scale, paper Table III)
    code = (
        "import jax; jax.config.update('jax_enable_x64', True)\n"
        "import numpy as np, jax.numpy as jnp, dataclasses\n"
        "from repro.core import LassoProblem, SolverConfig, "
        "acc_bcd_lasso, sa_acc_bcd_lasso\n"
        "from repro.data.sparse import make_lasso_dataset\n"
        "A, b, lm = make_lasso_dataset('leu-like', 0)\n"
        "p = LassoProblem(A=A, b=b, lam=0.1*lm)\n"
        "c = SolverConfig(block_size=8, iterations=128, dtype=jnp.float64)\n"
        "r1 = acc_bcd_lasso(p, c)\n"
        "r2 = sa_acc_bcd_lasso(p, dataclasses.replace(c, s=32))\n"
        "rel = abs(float(r1.objective[-1]) - float(r2.objective[-1])) "
        "/ abs(float(r1.objective[-1]))\n"
        "print(f'{rel:.3e}')\n")
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    rel = out.stdout.strip().splitlines()[-1] if out.returncode == 0 \
        else f"ERROR:{out.stderr[-200:]}"
    emit("table3/leu-like/accBCD-f64", 0.0, f"rel_err_f64={rel}")


def fig3_runtime():
    """Fig. 3: convergence vs modeled running time. Wall-clock measures
    the compute side on CPU; network time is modeled per collective
    (alpha-beta) for the Cray XC30 — SA trades s-fold fewer messages for
    s-fold larger ones, so modeled time favors SA exactly as Fig. 3."""
    machine = Machine.cray_xc30()
    P = 1024
    for ds in ("news20-like", "epsilon-like"):
        A, b, lam_max = make_lasso_dataset(ds, seed=0)
        prob = LassoProblem(A=A, b=b, lam=0.1 * lam_max)
        spec = SYNTHETIC_DATASETS[ds]
        dims = ProblemDims(m=spec.m, n=spec.n, f=spec.density)
        for s in (1, 16, S_BIG):
            cfg = SolverConfig(block_size=8, iterations=H, s=s)
            us, res = timeit(lambda: (sa_acc_bcd_lasso if s > 1
                                      else acc_bcd_lasso)(prob, cfg),
                             repeats=1)
            t_model = predicted_time(
                lasso_costs(dims, H, 8, s, P), machine)
            emit(f"fig3/{ds}/accBCD_s{s}", us / H,
                 f"objH={float(res.objective[-1]):.4g};"
                 f"modeled_time_s={t_model:.4f};"
                 f"modeled_speedup_vs_s1="
                 f"{lasso_speedup(dims, H, 8, s, P, machine):.2f}")


def table1_costs():
    """Table I: F/L/W/M for accBCD vs SA-accBCD (symbolic model
    evaluated); derived shows the s-scalings the paper derives."""
    dims = PAPER_DATASETS["news20"]
    for s in (1, 8, 64):
        c = lasso_costs(dims, H=1024, mu=8, s=s, P=1024)
        emit(f"table1/news20/s{s}", 0.0,
             f"F={c['F']:.3e};L={c['L']:.3e};W={c['W']:.3e};"
             f"M={c['M']:.3e}")
    c1 = lasso_costs(dims, 1024, 8, 1, 1024)
    c64 = lasso_costs(dims, 1024, 8, 64, 1024)
    emit("table1/news20/ratios", 0.0,
         f"L_ratio={c1['L'] / c64['L']:.1f}(=s);"
         f"W_ratio={c64['W'] / c1['W']:.1f}(=s)")


def fig4_scaling():
    """Fig. 4: strong scaling + speedup breakdown from the machine model
    at paper dataset dims (compute shrinks with P; latency term grows as
    log P -> SA's advantage grows with P, paper Fig. 4a-d)."""
    machine = Machine.cray_xc30()
    for ds in ("news20", "covtype", "url", "epsilon"):
        dims = PAPER_DATASETS[ds]
        for P in (192, 768, 3072, 12288):
            s_star, sp = best_s(dims, H=10_000, mu=1, P=P,
                                machine=machine)
            emit(f"fig4/{ds}/P{P}", 0.0,
                 f"best_s={s_star};speedup={sp:.2f}")


def main():
    fig2_convergence()
    table3_relative_error()
    fig3_runtime()
    table1_costs()
    fig4_scaling()


if __name__ == "__main__":
    main()
