"""Shared benchmark plumbing: CSV emission + dataset cache."""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_rows = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.2f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def header():
    print("name,us_per_call,derived", flush=True)


def timeit(fn, *args, repeats: int = 3):
    fn(*args)                       # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
    try:
        import jax
        jax.block_until_ready(out)
    except Exception:
        pass
    return (time.perf_counter() - t0) / repeats * 1e6, out
