"""Kernel SVM subsystem (K-BDCD / SA-K-BDCD, arXiv:2406.18001).

The kernelized solvers must (a) reproduce the linear (B)DCD iterates
exactly when kernel="linear", (b) keep the paper's central SA claim —
SA-K-BDCD == K-BDCD iterate-for-iterate — across the s x mu x kernel
sweep including forced index collisions and remainder iterations, and
(c) track the dual objective exactly against the direct m x m quadratic
form.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (KERNELS, SVMProblem, SolverConfig, bdcd_svm,
                        kbdcd_svm, kernel_dual_objective, sa_bdcd_svm,
                        sa_kbdcd_svm, solve_svm)

KERNEL_GRID = [("linear", None),
               ("rbf", {"gamma": 0.05}),
               ("poly", {"degree": 2, "coef0": 1.0, "scale": 0.1})]


def _kprob(svm_data, kern, params, loss="l2"):
    A, b = svm_data
    return SVMProblem(A=A, b=b, lam=1.0, loss=loss, kernel=kern,
                      kernel_params=params)


def test_kernel_registry():
    assert {"linear", "rbf", "poly"} <= set(KERNELS)
    assert KERNELS["rbf"].needs_norms
    assert not KERNELS["linear"].needs_norms
    with pytest.raises(ValueError, match="unknown kernel"):
        SVMProblem(A=np.zeros((2, 2)), b=np.ones(2), kernel="sigmoid")


@pytest.mark.parametrize("loss", ["l1", "l2"])
@pytest.mark.parametrize("mu", [1, 4])
def test_kbdcd_linear_matches_bdcd(svm_data, loss, mu):
    """kernel="linear" K-BDCD reproduces BDCD iterates: the maintained
    dual residual f equals Y x by definition."""
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
    cfg = SolverConfig(block_size=mu, iterations=48)
    base = bdcd_svm(prob, cfg)
    kern = kbdcd_svm(prob, cfg)
    np.testing.assert_allclose(np.asarray(kern.objective),
                               np.asarray(base.objective),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(kern.aux["alpha"]),
                               np.asarray(base.aux["alpha"]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(kern.x), np.asarray(base.x),
                               atol=1e-3)


_KBDCD_BASE_CACHE = {}


def _kbdcd_base(svm_data, kern, params, mu, H):
    key = (kern, mu, H)
    if key not in _KBDCD_BASE_CACHE:
        prob = _kprob(svm_data, kern, params)
        _KBDCD_BASE_CACHE[key] = kbdcd_svm(
            prob, SolverConfig(block_size=mu, iterations=H))
    return _KBDCD_BASE_CACHE[key]


@pytest.mark.parametrize("kern,params", KERNEL_GRID)
@pytest.mark.parametrize("mu", [1, 2, 4])
@pytest.mark.parametrize("s", [1, 4, 8])
def test_sa_kbdcd_trajectory_matches(svm_data, kern, params, mu, s):
    """SA-K-BDCD == K-BDCD across the full s x mu x kernel sweep."""
    prob = _kprob(svm_data, kern, params)
    H = 32
    base = _kbdcd_base(svm_data, kern, params, mu, H)
    sa = sa_kbdcd_svm(prob, SolverConfig(block_size=mu, iterations=H, s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.aux["alpha"]),
                               np.asarray(base.aux["alpha"]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.aux["f"]),
                               np.asarray(base.aux["f"]), atol=1e-3)
    assert o1[-1] < o1[0]          # dual objective decreases
    assert sa.aux["inner_impl"] == "ref"   # CPU: no pallas requested


@pytest.mark.parametrize("kern,params", KERNEL_GRID[1:])
def test_sa_kbdcd_collisions_within_group(kern, params):
    """Tiny m forces the same row index to repeat across the s blocks of
    one outer group (s*mu > m) — the kernel cross terms hold the raw
    k(a_i, a_i) at colliding positions, keeping SA-K-BDCD exact."""
    rng = np.random.default_rng(3)
    m, n = 10, 24
    A = rng.standard_normal((m, n)).astype(np.float32)
    b = np.sign(rng.standard_normal(m)).astype(np.float32)
    b[b == 0] = 1.0
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2", kernel=kern,
                      kernel_params=params)
    s, mu, H = 8, 2, 16
    base = kbdcd_svm(prob, SolverConfig(block_size=mu, iterations=H))
    sa = sa_kbdcd_svm(prob, SolverConfig(block_size=mu, iterations=H, s=s))
    np.testing.assert_allclose(np.asarray(sa.objective),
                               np.asarray(base.objective),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.aux["alpha"]),
                               np.asarray(base.aux["alpha"]), atol=1e-4)


@pytest.mark.parametrize("kern,params", KERNEL_GRID)
def test_kernel_incremental_dual_tracking_exact(svm_data, kern, params):
    """The per-iteration tracked dual (local scalars only) must equal the
    direct m x m quadratic-form evaluation, for both hinge losses."""
    for loss in ("l1", "l2"):
        prob = _kprob(svm_data, kern, params, loss=loss)
        res = kbdcd_svm(prob, SolverConfig(block_size=4, iterations=64))
        tracked = float(res.objective[-1])
        direct = float(kernel_dual_objective(prob, res.aux["alpha"]))
        assert abs(tracked - direct) < 1e-3 * max(1.0, abs(direct))


def test_kernel_alpha_box_constraints(svm_data):
    prob = _kprob(svm_data, "rbf", {"gamma": 0.05}, loss="l1")
    for solve in (lambda c: kbdcd_svm(prob, c),
                  lambda c: sa_kbdcd_svm(prob,
                                         dataclasses.replace(c, s=8))):
        res = solve(SolverConfig(block_size=4, iterations=96))
        alpha = np.asarray(res.aux["alpha"])
        assert np.all(alpha >= -1e-6)
        assert np.all(alpha <= prob.lam + 1e-6)   # nu = lam for L1
        assert np.any(alpha > 1e-4)               # nontrivial solution


def test_solve_svm_dispatches_on_kernel(svm_data):
    """solve_svm routes nonlinear kernels to the K-BDCD solvers (whose
    results carry the dual residual f) and linear ones to BDCD."""
    prob = _kprob(svm_data, "rbf", {"gamma": 0.05})
    res = solve_svm(prob, SolverConfig(block_size=2, iterations=16, s=4))
    assert "f" in res.aux and "inner_impl" in res.aux
    lin = solve_svm(SVMProblem(A=prob.A, b=prob.b, lam=1.0, loss="l2"),
                    SolverConfig(block_size=2, iterations=16))
    assert "f" not in lin.aux


# ---------------------------------------------------------------------------
# Remainder iterations (iterations % s != 0) — regression for the
# objs.reshape(H) crash: every SA solver must run the H mod s tail group.
# ---------------------------------------------------------------------------

def test_sa_bdcd_svm_remainder_iterations(svm_data):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l1")
    H, s = 10, 4
    base = bdcd_svm(prob, SolverConfig(block_size=2, iterations=H))
    cfg = SolverConfig(block_size=2, iterations=H, s=s)
    assert cfg.outer_iterations == 3        # 2 full groups + tail of 2
    sa = sa_bdcd_svm(prob, cfg)
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.aux["alpha"]),
                               np.asarray(base.aux["alpha"]), atol=1e-4)


def test_sa_kbdcd_svm_remainder_iterations(svm_data):
    prob = _kprob(svm_data, "rbf", {"gamma": 0.05})
    H, s = 10, 4
    base = kbdcd_svm(prob, SolverConfig(block_size=2, iterations=H))
    sa = sa_kbdcd_svm(prob, SolverConfig(block_size=2, iterations=H, s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-4)


def test_sa_svm_shorter_than_one_group(svm_data):
    """H < s: zero full groups, everything in the tail."""
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2")
    H, s = 3, 8
    base = bdcd_svm(prob, SolverConfig(block_size=1, iterations=H))
    sa = sa_bdcd_svm(prob, SolverConfig(block_size=1, iterations=H, s=s))
    np.testing.assert_allclose(np.asarray(sa.objective),
                               np.asarray(base.objective),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_sa_kbdcd_final_error_f64():
    """SA-K-BDCD == K-BDCD at machine-epsilon scale in f64 across the
    s x mu x kernel sweep including forced collisions (acceptance bound
    1e-10; f64 needs a subprocess, see DESIGN.md test conventions)."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import SVMProblem, SolverConfig, kbdcd_svm, sa_kbdcd_svm
worst = 0.0
for m, n in ((96, 40), (10, 24)):       # the second forces collisions
    rng = np.random.default_rng(7)
    A = rng.standard_normal((m, n))
    w = rng.standard_normal(n)
    b = np.sign(A @ w + 0.1 * rng.standard_normal(m)); b[b == 0] = 1.0
    for kern, params in (("linear", None), ("rbf", {"gamma": 0.05}),
                         ("poly", {"degree": 2, "coef0": 1.0,
                                   "scale": 0.1})):
        for loss in ("l1", "l2"):
            prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss, kernel=kern,
                              kernel_params=params)
            for mu, s in ((1, 8), (4, 8), (2, 6)):
                base = kbdcd_svm(prob, SolverConfig(
                    block_size=mu, iterations=60, dtype=jnp.float64))
                sa = sa_kbdcd_svm(prob, SolverConfig(
                    block_size=mu, iterations=60, s=s,
                    dtype=jnp.float64))
                o1 = np.asarray(base.objective)
                o2 = np.asarray(sa.objective)
                dev = float(np.max(np.abs(o1 - o2)
                                   / np.maximum(np.abs(o1), 1e-30)))
                adev = float(np.max(np.abs(
                    np.asarray(base.aux["alpha"])
                    - np.asarray(sa.aux["alpha"]))))
                worst = max(worst, dev, adev)
print("DEV", worst)
assert worst < 1e-10, worst
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    dev = float(out.stdout.split("DEV")[1].strip())
    assert dev < 1e-10
