"""Model-layer unit tests: rope, norms, GQA paths, MoE routing
properties, recurrent primitives (chunked == sequential), and
train-vs-decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import layers as L
from repro.models import lm
from repro.models import recurrent as R

KEY = jax.random.key(0)


def test_rmsnorm_scale_invariance():
    p = L.init_norm(32, jnp.float32)
    x = jax.random.normal(KEY, (2, 5, 32))
    out1 = L.rmsnorm(p, x)
    out2 = L.rmsnorm(p, 7.0 * x)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                               atol=1e-4)
    norm = np.asarray(jnp.mean(out1.astype(jnp.float32) ** 2, -1))
    np.testing.assert_allclose(norm, 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(KEY, (1, 2, 8, 64))
    pos = jnp.arange(8)
    out = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)
    # dot products depend only on relative offsets
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 1, 1, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 1, 1, 64))

    def dot_at(pq, pk):
        qr = L.apply_rope(q, jnp.asarray([pq]), 1e4)
        kr = L.apply_rope(k, jnp.asarray([pk]), 1e4)
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(9, 7)) < 1e-3


def test_moe_routing_properties():
    E, K, D, F = 8, 2, 16, 32
    p = L.init_moe(KEY, D, F, E, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 12, D))
    out, aux = L.moe(p, x, n_experts=E, top_k=K, ep_axis=None)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 1.0 - 1e-3     # Switch aux loss lower bound ~1


def test_moe_capacity_drops_gracefully():
    """With capacity_factor near zero most tokens drop -> output ~0 but
    still finite (residual passthrough happens in the block)."""
    E, K, D, F = 4, 1, 8, 16
    p = L.init_moe(KEY, D, F, E, jnp.float32)
    x = jax.random.normal(KEY, (1, 16, D))
    out, _ = L.moe(p, x, n_experts=E, top_k=K, capacity_factor=0.01,
                   ep_axis=None)
    assert np.isfinite(np.asarray(out)).all()


def _naive_gla(q, k, v, log_a):
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    S = np.zeros((B, H, dk, dv), np.float64)
    outs = []
    qn, kn, vn = (np.asarray(t, np.float64) for t in (q, k, v))
    an = np.exp(np.asarray(log_a, np.float64))
    for t in range(T):
        S = an[:, :, t, None, None] * S + np.einsum(
            "bhd,bhv->bhdv", kn[:, :, t], vn[:, :, t])
        outs.append(np.einsum("bhd,bhdv->bhv", qn[:, :, t], S))
    return np.stack(outs, axis=2), S


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (64, 64)])
def test_chunked_gla_matches_sequential(T, chunk):
    B, H, dk, dv = 1, 2, 4, 8
    q = jax.random.normal(KEY, (B, H, T, dk))
    k = jax.random.normal(jax.random.fold_in(KEY, 4), (B, H, T, dk)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 5), (B, H, T, dv))
    log_a = -0.1 - 0.3 * jax.random.uniform(jax.random.fold_in(KEY, 6),
                                            (B, H, T))
    o, S, _ = R.chunked_gla(q, k, v, log_a, chunk=chunk)
    o_ref, S_ref = _naive_gla(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(o), o_ref, atol=1e-3)
    np.testing.assert_allclose(np.asarray(S), S_ref, atol=1e-3)


def test_gla_step_matches_train_tail():
    """Running T-1 steps chunked then one gla_step == T steps chunked."""
    B, H, T, dk, dv = 1, 2, 17, 4, 4   # T-1 = 16 divides the chunk
    q = jax.random.normal(KEY, (B, H, T, dk))
    k = jax.random.normal(jax.random.fold_in(KEY, 7), (B, H, T, dk)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (B, H, T, dv))
    log_a = -0.2 * jnp.ones((B, H, T))
    # chunk=1 on the full (odd-length) run: degenerate but exact chunking
    o_full, S_full, _ = R.chunked_gla(q, k, v, log_a, chunk=1)
    _, S_part, _ = R.chunked_gla(q[:, :, :T - 1], k[:, :, :T - 1],
                                 v[:, :, :T - 1], log_a[:, :, :T - 1],
                                 chunk=8)
    o_step, S_step, _ = R.gla_step(q[:, :, -1], k[:, :, -1], v[:, :, -1],
                                   log_a[:, :, -1], S_part)
    np.testing.assert_allclose(np.asarray(o_step),
                               np.asarray(o_full[:, :, -1]), atol=1e-4)
    np.testing.assert_allclose(np.asarray(S_step), np.asarray(S_full),
                               atol=1e-4)


@pytest.mark.parametrize("arch_name", ["tinyllama-1.1b", "xlstm-350m",
                                       "hymba-1.5b", "mixtral-8x7b"])
def test_decode_matches_forward_last_position(arch_name):
    """Teacher-forced decode through the cache must reproduce the full
    forward pass logits at the final position (train/serve consistency —
    the strongest end-to-end invariant the serving stack has)."""
    arch = get_smoke_config(arch_name)
    # meta_tokens=0 aligns positions; high capacity_factor removes MoE
    # token drops (train batches tokens per capacity, decode sees one
    # token — dropless is the regime where the paths must agree exactly).
    arch = dataclasses.replace(arch, meta_tokens=0, capacity_factor=8.0)
    params = lm.init_params(arch, KEY)
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.fold_in(KEY, 9), (B, S), 0,
                                arch.vocab_size)
    logits_full, _, _ = lm.forward(params, arch, tokens)

    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         lm.cache_specs(arch, B, S))
    logits_step = None
    for t in range(S):
        batch = {"tokens": tokens[:, t:t + 1], "cache": cache,
                 "pos": jnp.int32(t)}
        logits_step, cache = lm.decode_step(params, arch, batch)
    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=0.12, rtol=0.05)


def test_attention_qkv_bias_used():
    p = L.init_attention(KEY, 32, 4, 2, 8, True, jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 32))
    out1, _ = L.attention_train(p, x, n_heads=4, n_kv_heads=2, head_dim=8,
                                rope_theta=1e4)
    p2 = dict(p, bq=p["bq"] + 1.0)
    out2, _ = L.attention_train(p2, x, n_heads=4, n_kv_heads=2, head_dim=8,
                                rope_theta=1e4)
    assert not np.allclose(np.asarray(out1), np.asarray(out2))
