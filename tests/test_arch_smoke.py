"""Per-architecture smoke tests (deliverable f): every assigned arch, in
its reduced same-family config, runs one forward/train step on CPU with
finite loss + gradients and a working decode step. The FULL configs are
exercised only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.configs.base import SHAPES, input_specs
from repro.models import lm

ALL_ARCHS = list_archs()


def _batch_for(arch, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": rng.integers(0, arch.vocab_size, (B, S)).astype(np.int32),
        "targets": rng.integers(0, arch.vocab_size, (B, S)).astype(np.int32),
    }
    if arch.frontend == "vision_stub":
        batch["patches"] = rng.standard_normal(
            (B, arch.n_patches, arch.d_model)).astype(np.float32)
    if arch.frontend == "audio_stub":
        batch["frames"] = rng.standard_normal(
            (B, arch.encoder_seq, arch.d_model)).astype(np.float32)
    return batch


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_train_step_smoke(arch_name):
    arch = get_smoke_config(arch_name)
    params = lm.init_params(arch, jax.random.key(0))
    batch = _batch_for(arch)
    loss, grads = jax.value_and_grad(
        lambda p: lm.train_loss(p, arch, batch))(params)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    # output shape sanity via forward
    logits, _, _ = lm.forward(params, arch, jnp.asarray(batch["tokens"]),
                              {k: v for k, v in batch.items()
                               if k not in ("tokens", "targets")})
    n_prefix = (arch.n_patches if arch.frontend == "vision_stub" else 0) \
        + arch.meta_tokens
    assert logits.shape == (2, 32 + n_prefix, arch.vocab_size)


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_decode_step_smoke(arch_name):
    arch = get_smoke_config(arch_name)
    params = lm.init_params(arch, jax.random.key(0))
    B, S = 2, 32
    batch = _batch_for(arch, B, S)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                         lm.cache_specs(arch, B, S))
    db = {"tokens": jnp.asarray(batch["tokens"][:, :1]), "cache": cache,
          "pos": jnp.int32(S - 1)}
    logits, new_cache = lm.decode_step(params, arch, db)
    assert logits.shape == (B, 1, arch.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch_name", ALL_ARCHS)
def test_full_config_specs_are_lazy(arch_name):
    """Full configs must build input/param specs without any allocation."""
    arch = get_config(arch_name)
    for shape_name, shape in SHAPES.items():
        if shape_name in arch.skip_shapes:
            continue
        specs = input_specs(arch, shape)
        for leaf in jax.tree.leaves(specs):
            assert isinstance(leaf, jax.ShapeDtypeStruct)
    p = lm.param_specs(arch)
    n = lm.param_count(arch)
    assert n > 1e8      # full configs are all >100M params


def test_skip_table_matches_design():
    """Sub-quadratic requirement: exactly hymba, mixtral, xlstm run
    long_500k; everything else skips it."""
    runners = {a for a in ALL_ARCHS
               if "long_500k" not in get_config(a).skip_shapes}
    assert runners == {"hymba-1.5b", "mixtral-8x7b", "xlstm-350m"}
