"""The ``repro.api`` facade and the problem-family registry.

Three contracts under test:

1. **Shim equivalence** — every legacy entry point is a thin shim over
   ``repro.api.solve``: same compiled program, BIT-identical results
   (``np.array_equal``, not allclose), per family x variant x backend.
2. **Registry round-trip** — ``register_family`` on a toy family makes it
   reachable from ``solve``; unknown family/backend/variant errors list
   the registered names (the ``SVMProblem.__post_init__`` convention).
3. **Warm start** — ``solve(..., x0=...)`` resumes a second solve at the
   first solve's final objective, for every family.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import api
from repro.api import (FAMILIES, LassoProblem, LogRegProblem, ProblemFamily,
                       SVMProblem, SolverConfig, register_family)
from repro.core import (acc_bcd_lasso, acc_cd_lasso, bcd_lasso, bcd_logreg,
                        bdcd_svm, ca_sfista, cd_lasso, dcd_svm, kbdcd_svm,
                        sa_acc_bcd_lasso, sa_acc_cd_lasso, sa_bcd_lasso,
                        sa_bcd_logreg, sa_bdcd_svm, sa_cd_lasso, sa_kbdcd_svm,
                        sa_svm, sfista)
from repro.core.sfista import SFISTAProblem

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ROOT = os.path.join(os.path.dirname(__file__), "..")


def _problems(lasso_data, svm_data):
    A, b, lam = lasso_data
    As, bs = svm_data
    return {
        "lasso": LassoProblem(A=A, b=b, lam=lam),
        "svm": SVMProblem(A=As, b=bs, lam=1.0),
        "ksvm": SVMProblem(A=As, b=bs, lam=1.0, kernel="rbf",
                           kernel_params={"gamma": 0.1}),
        "logreg": LogRegProblem(A=As, b=bs, lam=1e-3),
        "sfista": SFISTAProblem(A=A, b=b, lam=lam),
    }


# ---------------------------------------------------------------------------
# 1. shim equivalence, local backend: family x variant, bit-identical.
# ---------------------------------------------------------------------------

# (family, legacy fn, cfg kwargs driving api.solve to the same variant)
LOCAL_CASES = [
    ("lasso", bcd_lasso, dict(block_size=4, s=1, accelerated=False)),
    ("lasso", acc_bcd_lasso, dict(block_size=4, s=1, accelerated=True)),
    ("lasso", sa_bcd_lasso, dict(block_size=4, s=8, accelerated=False)),
    ("lasso", sa_acc_bcd_lasso, dict(block_size=4, s=8, accelerated=True)),
    ("svm", bdcd_svm, dict(block_size=2, s=1)),
    ("svm", sa_bdcd_svm, dict(block_size=2, s=8)),
    ("ksvm", kbdcd_svm, dict(block_size=2, s=1)),
    ("ksvm", sa_kbdcd_svm, dict(block_size=2, s=8)),
    ("logreg", bcd_logreg, dict(block_size=2, s=1)),
    ("logreg", sa_bcd_logreg, dict(block_size=2, s=8)),
    ("sfista", sfista, dict(block_size=4, s=1)),
    ("sfista", ca_sfista, dict(block_size=4, s=8)),
]


@pytest.mark.parametrize("family,legacy,cfg_kw",
                         LOCAL_CASES,
                         ids=[f"{f}-{fn.__name__}"
                              for f, fn, _ in LOCAL_CASES])
def test_legacy_shims_bit_identical_local(lasso_data, svm_data, family,
                                          legacy, cfg_kw):
    prob = _problems(lasso_data, svm_data)[family]
    cfg = SolverConfig(iterations=24, **cfg_kw)
    ref = legacy(prob, cfg)
    res = api.solve(prob, cfg)
    assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
    assert np.array_equal(np.asarray(ref.objective),
                          np.asarray(res.objective))


def test_family_resolution_by_problem_type(lasso_data, svm_data):
    for name, prob in _problems(lasso_data, svm_data).items():
        assert api.resolve_family(prob).name == name


def test_registry_has_all_families():
    assert {"lasso", "svm", "ksvm", "logreg", "sfista"} <= set(FAMILIES)
    assert api.families() == tuple(sorted(FAMILIES))


# ---------------------------------------------------------------------------
# 1b. shim equivalence, sharded backend (8 placeholder devices, one
# subprocess covering one case per family).
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_legacy_shims_bit_identical_sharded():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax
from repro import api
from repro.api import (LassoProblem, LogRegProblem, SVMProblem,
                       SolverConfig)
from repro.core import solve_lasso_sharded, solve_svm_sharded

mesh_d = jax.make_mesh((8,), ("data",))
mesh_m = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(3)
m, n = 130, 40
A = rng.standard_normal((m, n)).astype(np.float32)
xt = np.zeros(n, np.float32); xt[:5] = 1.0
b = (A @ xt + 0.1 * rng.standard_normal(m)).astype(np.float32)
lam = 0.1 * float(np.abs(A.T @ b).max())
# planted separable-ish labels: logreg's SGD-style steps need signal to
# descend (pure-noise labels put the optimum at w ~ 0).
wt = rng.standard_normal(n).astype(np.float32)
bs = np.sign(A @ wt + 0.1 * rng.standard_normal(m)).astype(np.float32)
bs[bs == 0] = 1.0

cfg = SolverConfig(block_size=2, iterations=16, s=4)
cases = [
    (LassoProblem(A=A, b=b, lam=lam),
     lambda p: solve_lasso_sharded(p, cfg, mesh_d), mesh_d),
    (SVMProblem(A=A, b=bs, lam=1.0),
     lambda p: solve_svm_sharded(p, cfg, mesh_m), mesh_m),
    (SVMProblem(A=A, b=bs, lam=1.0, kernel="rbf",
                kernel_params={"gamma": 0.1}),
     lambda p: solve_svm_sharded(p, cfg, mesh_m), mesh_m),
]
for prob, legacy, mesh in cases:
    ref = legacy(prob)
    res = api.solve(prob, cfg, backend="sharded", mesh=mesh)
    assert np.array_equal(np.asarray(ref.x), np.asarray(res.x))
    assert np.array_equal(np.asarray(ref.objective),
                          np.asarray(res.objective))
    # and the sharded trajectory matches the local one
    loc = api.solve(prob, cfg)
    o1, o2 = np.asarray(loc.objective), np.asarray(res.objective)
    assert np.max(np.abs(o1 - o2) / np.maximum(np.abs(o1), 1e-9)) < 1e-4

# logreg has NO legacy sharded entry point — the whole point: it reaches
# the generic driver by registration alone (and exercises the
# x0_layout="partition" warm-start padding path).
prob = LogRegProblem(A=A, b=bs, lam=1e-3)
loc = api.solve(prob, cfg)
res = api.solve(prob, cfg, backend="sharded", mesh=mesh_m)
o1, o2 = np.asarray(loc.objective), np.asarray(res.objective)
assert np.max(np.abs(o1 - o2) / np.abs(o1)) < 1e-4
assert res.x.shape == (n,) and res.aux["margins"].shape == (m,)
warm = api.solve(prob, cfg, backend="sharded", mesh=mesh_m,
                 x0=np.asarray(res.x))
# resumes at the cold solve's final objective (stochastic steps may
# fluctuate afterwards, but never climb back toward the cold start).
assert abs(float(warm.objective[0]) - float(res.objective[-1])) \
    < 0.02 * abs(float(res.objective[-1]))
assert float(warm.objective[-1]) < float(res.objective[0])
print("SHARDED_SHIMS_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "SHARDED_SHIMS_OK" in out.stdout


# ---------------------------------------------------------------------------
# 2. registry round-trip + error messages.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _ToyProblem:
    A: object
    b: object


def test_register_family_roundtrip():
    from repro.core.types import SolverResult

    def toy_solve(problem, cfg, axis_name=None, x0=None):
        x = np.zeros(np.asarray(problem.A).shape[1]) if x0 is None \
            else np.asarray(x0)
        return SolverResult(x=x, objective=np.zeros(cfg.iterations),
                            aux={"tag": "toy"})

    deco = register_family(
        "toy", problem_cls=_ToyProblem, partition="row",
        default_axes="data",
        variants={"classical": "tests.test_api:_missing"})
    try:
        deco(toy_solve)
        assert "toy" in FAMILIES
        prob = _ToyProblem(A=np.ones((4, 3)), b=np.ones(4))
        # type-inferred dispatch reaches the toy solver
        res = api.solve(prob, SolverConfig(iterations=5))
        assert res.aux["tag"] == "toy" and res.x.shape == (3,)
        # x0 threads through
        res = api.solve(prob, SolverConfig(iterations=5), x0=np.ones(3))
        assert np.array_equal(res.x, np.ones(3))
        # duplicate registration is rejected with the registered names
        with pytest.raises(ValueError, match="already registered"):
            register_family("toy", problem_cls=_ToyProblem,
                            variants={})(toy_solve)
        # unknown variant error lists the registered variants
        with pytest.raises(ValueError, match="classical"):
            FAMILIES["toy"].variant("nope")
    finally:
        FAMILIES.pop("toy", None)


def test_unknown_family_error_lists_registered():
    with pytest.raises(ValueError) as ei:
        api.resolve_family(family="nope")
    for name in ("lasso", "svm", "ksvm", "logreg"):
        assert name in str(ei.value)


def test_unmatched_problem_error_lists_registered():
    with pytest.raises(ValueError, match="no registered problem family"):
        api.resolve_family(problem=object())


def test_unknown_backend_error_lists_registered(lasso_data):
    A, b, lam = lasso_data
    with pytest.raises(ValueError) as ei:
        api.solve(LassoProblem(A=A, b=b, lam=lam), SolverConfig(),
                  backend="tpu-pod")
    assert "local" in str(ei.value) and "sharded" in str(ei.value)


def test_sharded_backend_requires_mesh(lasso_data):
    A, b, lam = lasso_data
    with pytest.raises(ValueError, match="mesh"):
        api.solve(LassoProblem(A=A, b=b, lam=lam), SolverConfig(),
                  backend="sharded")


def test_invalid_family_fields_rejected():
    with pytest.raises(ValueError, match="partition"):
        ProblemFamily(name="bad", problem_cls=_ToyProblem, solve=None,
                      variants={}, partition="diagonal")
    with pytest.raises(ValueError, match="x0_layout"):
        ProblemFamily(name="bad", problem_cls=_ToyProblem, solve=None,
                      variants={}, x0_layout="sideways")


def test_callbacks_run_after_solve(lasso_data):
    A, b, lam = lasso_data
    seen = []
    res = api.solve(LassoProblem(A=A, b=b, lam=lam),
                    SolverConfig(iterations=5),
                    callbacks=[seen.append])
    assert seen == [res]


# ---------------------------------------------------------------------------
# 2b. the mu = 1 aliases reject blocked configs loudly (ValueError, not
# a stripped-under-``python -O`` assert).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("alias", [cd_lasso, acc_cd_lasso, sa_cd_lasso,
                                   sa_acc_cd_lasso, dcd_svm, sa_svm],
                         ids=lambda f: f.__name__)
def test_unit_block_aliases_raise_on_blocked_config(lasso_data, svm_data,
                                                    alias):
    if "lasso" in alias.__name__:
        A, b, lam = lasso_data
        prob = LassoProblem(A=A, b=b, lam=lam)
    else:
        A, b = svm_data
        prob = SVMProblem(A=A, b=b, lam=1.0)
    with pytest.raises(ValueError, match="block_size"):
        alias(prob, SolverConfig(block_size=2, iterations=4))


# ---------------------------------------------------------------------------
# 3. warm start: a second solve resumes at the first's final objective.
# ---------------------------------------------------------------------------

def _warm_start_case(prob, cfg):
    first = api.solve(prob, cfg)
    second = api.solve(prob, cfg, x0=np.asarray(first.x))
    o1 = np.asarray(first.objective)
    o2 = np.asarray(second.objective)
    # the second trace RESUMES: its first point continues from the first
    # solve's final objective (one further step applied), and it never
    # climbs back toward the cold-start values.
    scale = max(abs(float(o1[-1])), 1e-6)
    assert abs(float(o2[0]) - float(o1[-1])) / scale < 0.05, (o1[-1], o2[0])
    assert float(o2[-1]) <= float(o1[-1]) + 1e-5 * scale
    return o1, o2


@pytest.mark.parametrize("variant_cfg", [dict(s=1), dict(s=6)],
                         ids=["classical", "sa"])
def test_warm_start_resumes_lasso(lasso_data, variant_cfg):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    cfg = SolverConfig(block_size=4, iterations=30, accelerated=False,
                       **variant_cfg)
    o1, o2 = _warm_start_case(prob, cfg)
    assert float(o2[-1]) < float(np.asarray(o1)[0])


@pytest.mark.parametrize("kernel", ["linear", "rbf"])
def test_warm_start_resumes_svm_dual(svm_data, kernel):
    """alpha0 != 0 resumes the incremental dual trace at f_D(alpha0)
    (regression: it used to restart at 0, discontinuous)."""
    A, b = svm_data
    params = {"gamma": 0.1} if kernel == "rbf" else None
    prob = SVMProblem(A=A, b=b, lam=1.0, kernel=kernel,
                      kernel_params=params)
    cfg = SolverConfig(block_size=2, iterations=40, s=4)
    first = api.solve(prob, cfg)
    second = api.solve(prob, cfg, x0=np.asarray(first.aux["alpha"]))
    o1, o2 = np.asarray(first.objective), np.asarray(second.objective)
    scale = max(abs(float(o1[-1])), 1e-6)
    assert abs(float(o2[0]) - float(o1[-1])) / scale < 0.05
    assert float(o2[-1]) <= float(o1[-1]) + 1e-4 * scale


def test_warm_start_resumes_logreg(svm_data):
    A, b = svm_data
    prob = LogRegProblem(A=A, b=b, lam=1e-3)
    cfg = SolverConfig(block_size=2, iterations=40, s=5)
    _warm_start_case(prob, cfg)


# ---------------------------------------------------------------------------
# tooling: the checked-in API surface matches the live modules, and the
# registry-driven CLI runs once per family.
# ---------------------------------------------------------------------------

def test_api_surface_matches_checked_in():
    script = os.path.join(ROOT, "tools", "check_api_surface.py")
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, (out.stdout, out.stderr)


@pytest.mark.parametrize("family", ["lasso", "svm", "ksvm", "logreg",
                                    "sfista"])
def test_cli_smoke_per_family(family):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.solve", "--problem", family,
         "--iterations", "4", "--s", "2", "--dataset", "w1a-like"],
        env=env, capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout, out.stderr)
    assert family.split("-")[0] in out.stdout
