"""End-to-end behaviour tests: train a reduced model and watch the loss
drop; serve it with batched requests; resume from a checkpoint."""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.launch.serve import BatchedServer
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.runtime.driver import Trainer, TrainerConfig


def _train(steps, ckpt_dir, seed=0, arch_name="tinyllama-1.1b"):
    arch = get_smoke_config(arch_name)
    pipe = TokenPipeline(vocab_size=arch.vocab_size, global_batch=4,
                         seq_len=32, seed=seed)
    cfg = TrainerConfig(steps=steps, ckpt_dir=ckpt_dir, ckpt_every=5,
                        model_axis=1, seed=seed)
    t = Trainer(arch, AdamW(learning_rate=3e-3), pipe, cfg)
    return t, t.run()


def test_training_reduces_loss():
    with tempfile.TemporaryDirectory() as d:
        _, out = _train(steps=15, ckpt_dir=d)
        losses = out["losses"]
        assert losses[-1] < losses[0] - 0.05, losses[:3] + losses[-3:]


def test_checkpoint_resume_continues_trajectory():
    with tempfile.TemporaryDirectory() as d:
        t1, out1 = _train(steps=10, ckpt_dir=d)
        # new trainer, same dir: restore and continue to step 10 == no-op,
        # then run 5 more steps; trajectory must extend consistently.
        arch = get_smoke_config("tinyllama-1.1b")
        pipe = TokenPipeline(vocab_size=arch.vocab_size, global_batch=4,
                             seq_len=32, seed=0)
        cfg = TrainerConfig(steps=15, ckpt_dir=d, ckpt_every=5,
                            model_axis=1, seed=0)
        t2 = Trainer(arch, AdamW(learning_rate=3e-3), pipe, cfg)
        t2._restore()
        assert t2.step == 10
        out2 = t2.run()
        assert out2["final_step"] == 15
        assert out2["losses"][-1] < out1["losses"][0]


def test_serving_end_to_end():
    arch = get_smoke_config("tinyllama-1.1b")
    params = lm.init_params(arch, jax.random.key(0))
    server = BatchedServer(arch, params, max_seq=24)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, arch.vocab_size, (3, 8)).astype(np.int32)
    out = server.generate(prompts, gen_len=8)
    assert out.shape == (3, 8)
    assert out.dtype == np.int32
    assert np.all(out >= 0) and np.all(out < arch.vocab_size)
    # greedy decoding is deterministic
    out2 = server.generate(prompts, gen_len=8)
    np.testing.assert_array_equal(out, out2)
