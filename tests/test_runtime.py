"""Straggler monitor + failure injector unit tests (the end-to-end
elastic path is covered in test_distributed.py)."""
import pytest

from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor


def test_injector_fires_once():
    inj = FailureInjector(failures={5: [2]})
    assert inj.check(4) == []
    assert inj.check(5) == [2]
    assert inj.check(5) == []          # popped: replay-safe
    assert inj.fired == [(5, 2)]


def test_straggler_detection_and_eviction():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=2,
                           evict_after=4)
    actions_seen = []
    for step in range(8):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0}
        actions = mon.record(times)
        actions_seen.append(actions.get(3))
    assert "rebalance" in actions_seen
    assert "evict" in actions_seen
    # healthy hosts never flagged
    assert all(a is None or a in ("rebalance", "evict")
               for a in actions_seen)


def test_straggler_recovers():
    mon = StragglerMonitor(n_hosts=2, threshold=1.5, patience=2)
    for _ in range(3):
        mon.record({0: 1.0, 1: 4.0})
    for _ in range(6):
        actions = mon.record({0: 1.0, 1: 1.0})
    assert actions == {}


def test_rebalance_weights_inverse_to_speed():
    mon = StragglerMonitor(n_hosts=2)
    for _ in range(5):
        mon.record({0: 1.0, 1: 2.0})
    w = mon.microbatch_weights()
    assert w[0] > w[1]
    assert sum(w) == pytest.approx(2.0)


def test_drop_host():
    mon = StragglerMonitor(n_hosts=3)
    mon.record({0: 1.0, 1: 1.0, 2: 9.0})
    mon.drop_host(2)
    actions = mon.record({0: 1.0, 1: 1.0})
    assert actions == {}
    assert len(mon.microbatch_weights()) == 2


# ---------------------------------------------------------------------
# FailureInjector regressions: the pop semantics are what make
# restore-and-replay safe in the elastic driver.

def test_injector_fires_once_across_restore_and_replay():
    """The elastic driver re-executes the iteration range [k, k+seg)
    after restoring a checkpoint at k. A failure popped on the first
    pass must NOT re-fire on the replay pass — otherwise every recovery
    would kill another host forever."""
    inj = FailureInjector(failures={3: [1], 5: [0, 2]})
    first = [inj.check(t) for t in range(1, 7)]
    assert first == [[], [], [1], [], [0, 2], []]
    # replay the same window after a restore: nothing fires again
    replay = [inj.check(t) for t in range(1, 7)]
    assert replay == [[], [], [], [], [], []]


def test_injector_fired_records_step_host_in_order():
    inj = FailureInjector(failures={7: [3], 2: [0, 1]})
    for t in range(1, 10):
        inj.check(t)
    assert inj.fired == [(2, 0), (2, 1), (7, 3)]


def test_injector_unscheduled_steps_noop():
    inj = FailureInjector(failures={})
    assert inj.check(1) == []
    assert inj.fired == []


# ---------------------------------------------------------------------
# StragglerMonitor invariants. Deterministic checks always run; the
# hypothesis property sweeps run where hypothesis is installed (the CI
# image may not ship it — importorskip, not a hard dependency).

def test_monitor_validation():
    with pytest.raises(ValueError, match="n_hosts"):
        StragglerMonitor(n_hosts=0)
    with pytest.raises(ValueError, match="ema_decay"):
        StragglerMonitor(n_hosts=2, ema_decay=1.0)
    with pytest.raises(ValueError, match="threshold"):
        StragglerMonitor(n_hosts=2, threshold=0.5)
    with pytest.raises(ValueError, match="patience"):
        StragglerMonitor(n_hosts=2, patience=0)
    with pytest.raises(ValueError, match="evict_after"):
        StragglerMonitor(n_hosts=2, patience=3, evict_after=2)


def test_strikes_reset_on_recovery_before_evict():
    """A host whose EMA recovers under threshold x median resets its
    strike count to ZERO — it must re-earn the full evict_after streak,
    not resume the old count. (3 hosts so the median tracks the fast
    pair; a low ema_decay so one fast step actually pulls the EMA back
    under the threshold.)"""
    mon = StragglerMonitor(n_hosts=3, ema_decay=0.1, threshold=1.5,
                           patience=2, evict_after=4)
    for _ in range(3):                      # 3 strikes, one short of evict
        mon.record({0: 1.0, 1: 1.0, 2: 5.0})
    mon.record({0: 1.0, 1: 1.0, 2: 1.0})    # EMA -> 1.4: strikes reset
    for _ in range(3):        # 3 FRESH strikes: rebalance, NOT evict
        actions = mon.record({0: 1.0, 1: 1.0, 2: 5.0})
    assert actions.get(2) == "rebalance"    # without the reset: strike 6
    actions = mon.record({0: 1.0, 1: 1.0, 2: 5.0})   # 4th fresh strike
    assert actions.get(2) == "evict"


def test_dropped_host_never_in_actions():
    mon = StragglerMonitor(n_hosts=3, threshold=1.5, patience=1)
    for _ in range(4):
        mon.record({0: 1.0, 1: 1.0, 2: 9.0})
    mon.drop_host(2)
    # a late heartbeat for the dropped host races its eviction
    actions = mon.record({0: 1.0, 1: 1.0, 2: 9.0})
    assert 2 not in actions
    assert mon.live_hosts == [0, 1]


def test_single_live_host_median_well_defined():
    """With one live host the median EMA is that host's own EMA, so it
    can never exceed threshold x itself (threshold >= 1): a lone
    survivor is structurally never a straggler."""
    mon = StragglerMonitor(n_hosts=3, threshold=1.5, patience=1)
    mon.drop_host(0)
    mon.drop_host(1)
    for _ in range(10):
        actions = mon.record({2: 100.0})
    assert actions == {}


def test_rebalance_precedes_evict():
    """Escalation order: the FIRST action a straggler receives is
    rebalance (at patience strikes); evict only ever follows at
    evict_after >= patience strikes."""
    mon = StragglerMonitor(n_hosts=3, threshold=1.5, patience=2,
                           evict_after=5)
    seen = []
    for _ in range(7):
        seen.append(mon.record({0: 1.0, 1: 1.0, 2: 9.0}).get(2))
    first_action = next(a for a in seen if a is not None)
    assert first_action == "rebalance"
    assert seen.index("evict") > seen.index("rebalance")


# -- hypothesis property sweeps (skipped when hypothesis is absent; the
# deterministic regressions above always run) ----

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

    def given(*a, **k):              # the undecorated test then skips
        return lambda fn: fn

    settings = given

    class _St:                       # strategy placeholders, never drawn
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _St()

needs_hypothesis = pytest.mark.skipif(
    not _HAVE_HYPOTHESIS, reason="hypothesis not installed")

_times = st.floats(min_value=0.01, max_value=100.0,
                   allow_nan=False, allow_infinity=False)


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(st.lists(st.dictionaries(st.integers(0, 3), _times, min_size=1),
                min_size=1, max_size=20),
       st.integers(0, 3))
def test_prop_dropped_host_never_returned(steps, victim):
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=1,
                           evict_after=2)
    mon.drop_host(victim)
    for times in steps:
        actions = mon.record(times)
        assert victim not in actions
        assert victim not in mon.live_hosts


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(st.lists(_times, min_size=1, max_size=30))
def test_prop_single_live_host_never_flagged(series):
    mon = StragglerMonitor(n_hosts=1, threshold=1.5, patience=1)
    for t in series:
        assert mon.record({0: t}) == {}


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=40),
       st.integers(2, 4), st.integers(1, 4))
def test_prop_rebalance_escalates_into_evict(slow_steps, patience, extra):
    """For ANY slow/fast pattern: no action before `patience` records,
    and with evict_after > patience every evict is PRECEDED by a
    rebalance for the same host (strikes grow one per record, so the
    streak must pass through [patience, evict_after) first). The
    invariant is EMA-agnostic — it follows from the strike counter
    alone, whatever the flagging pattern."""
    evict_after = patience + extra
    mon = StragglerMonitor(n_hosts=3, threshold=1.5, patience=patience,
                           evict_after=evict_after)
    seen = []
    for i, slow in enumerate(slow_steps):
        actions = mon.record({0: 1.0, 1: 1.0,
                              2: 9.0 if slow else 1.0})
        act = actions.get(2)
        assert actions.get(0) is None and actions.get(1) is None
        if act is not None:
            assert i + 1 >= patience
        seen.append(act)
    for i, act in enumerate(seen):
        if act == "evict":
            assert "rebalance" in seen[:i]


@needs_hypothesis
@settings(max_examples=50, deadline=None)
@given(st.dictionaries(st.integers(1, 30),
                       st.lists(st.integers(0, 3), min_size=1,
                                max_size=2, unique=True),
                       min_size=0, max_size=5))
def test_prop_injector_total_fire_count(failures):
    """Sweeping check(t) over the full horizon twice fires every
    scheduled (step, host) pair exactly once, in step-major order."""
    inj = FailureInjector(failures={k: list(v)
                                    for k, v in failures.items()})
    for _ in range(2):
        for t in range(1, 31):
            inj.check(t)
    expected = [(t, h) for t in sorted(failures) for h in failures[t]]
    assert inj.fired == expected
