"""Straggler monitor + failure injector unit tests (the end-to-end
elastic path is covered in test_distributed.py)."""
import pytest

from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor


def test_injector_fires_once():
    inj = FailureInjector(failures={5: [2]})
    assert inj.check(4) == []
    assert inj.check(5) == [2]
    assert inj.check(5) == []          # popped: replay-safe
    assert inj.fired == [(5, 2)]


def test_straggler_detection_and_eviction():
    mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=2,
                           evict_after=4)
    actions_seen = []
    for step in range(8):
        times = {0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0}
        actions = mon.record(times)
        actions_seen.append(actions.get(3))
    assert "rebalance" in actions_seen
    assert "evict" in actions_seen
    # healthy hosts never flagged
    assert all(a is None or a in ("rebalance", "evict")
               for a in actions_seen)


def test_straggler_recovers():
    mon = StragglerMonitor(n_hosts=2, threshold=1.5, patience=2)
    for _ in range(3):
        mon.record({0: 1.0, 1: 4.0})
    for _ in range(6):
        actions = mon.record({0: 1.0, 1: 1.0})
    assert actions == {}


def test_rebalance_weights_inverse_to_speed():
    mon = StragglerMonitor(n_hosts=2)
    for _ in range(5):
        mon.record({0: 1.0, 1: 2.0})
    w = mon.microbatch_weights()
    assert w[0] > w[1]
    assert sum(w) == pytest.approx(2.0)


def test_drop_host():
    mon = StragglerMonitor(n_hosts=3)
    mon.record({0: 1.0, 1: 1.0, 2: 9.0})
    mon.drop_host(2)
    actions = mon.record({0: 1.0, 1: 1.0})
    assert actions == {}
    assert len(mon.microbatch_weights()) == 2
