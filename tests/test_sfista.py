"""CA-SFISTA — the fifth registered family (arXiv:1710.08883), built
entirely as an engine FamilyProgram: the s-step unroll reproduces
classical SFISTA's iterates, the subspace momentum actually converges,
SolveState resume works, and the compiled sharded HLO keeps ONE static
Allreduce per outer iteration with zero driver edits."""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverConfig
from repro.core.sfista import (SFISTAProblem, ca_sfista, sfista,
                               sfista_objective, solve_sfista)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def sfista_prob(lasso_data):
    A, b, lam = lasso_data
    return SFISTAProblem(A=A, b=b, lam=lam)


@pytest.mark.parametrize("mu", [1, 4])
@pytest.mark.parametrize("s", [4, 12])
def test_ca_trajectory_matches_classical(sfista_prob, mu, s):
    """The SA transformation only rearranges arithmetic: same objective
    trajectory and final iterate to f32 roundoff."""
    H = 48
    base = sfista(sfista_prob, SolverConfig(block_size=mu, iterations=H))
    sa = ca_sfista(sfista_prob, SolverConfig(block_size=mu, iterations=H,
                                             s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(sa.aux["residual"]),
                               np.asarray(base.aux["residual"]),
                               atol=2e-5)
    assert o1[-1] < o1[0]          # the momentum method makes progress
    if mu == 4:                    # blocked: substantial progress by H=48
        assert o1[-1] < 0.5 * o1[0]


@pytest.mark.parametrize("H,s", [(10, 4), (3, 8)])
def test_ca_remainder_tail(sfista_prob, H, s):
    """H mod s != 0: the tail group still matches the classical method
    inner-iteration-for-inner-iteration — including the t-schedule
    window, which the tail reads at its global offset."""
    base = sfista(sfista_prob, SolverConfig(block_size=4, iterations=H))
    sa = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=H,
                                             s=s))
    o2 = np.asarray(sa.objective)
    assert o2.shape == (H,)
    np.testing.assert_allclose(o2, np.asarray(base.objective), rtol=5e-5)


def test_subspace_momentum_support(sfista_prob):
    """The defining invariant of the sampled momentum rule: y - x is
    supported on the LAST sampled block only (<= mu coordinates) —
    full-vector extrapolation under block sampling diverges, which is
    why the family extrapolates in the sampled subspace."""
    res = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=33,
                                              s=8))
    carry = res.aux["state"].carry
    diff = np.asarray(carry["y"]) - np.asarray(carry["x"])
    assert np.count_nonzero(diff) <= 4
    o = np.asarray(res.objective)
    assert o[-1] < o[0]


def test_solve_dispatch_and_objective(sfista_prob):
    """solve_sfista routes on cfg.s; sfista_objective agrees with the
    tracked trace at the final iterate."""
    res1 = solve_sfista(sfista_prob, SolverConfig(block_size=4,
                                                  iterations=12, s=1))
    ref1 = sfista(sfista_prob, SolverConfig(block_size=4, iterations=12,
                                            s=1))
    assert np.array_equal(np.asarray(res1.x), np.asarray(ref1.x))
    res = solve_sfista(sfista_prob, SolverConfig(block_size=4,
                                                 iterations=12, s=4))
    direct = float(sfista_objective(sfista_prob, res.x))
    np.testing.assert_allclose(direct, float(res.objective[-1]), rtol=1e-5)


def test_resume_bitwise_on_aligned_boundary(sfista_prob):
    """Checkpoint/resume at an outer boundary (split % s == 0): group
    windows realign exactly, so the resumed run is bitwise identical to
    the uninterrupted one — iterates AND objective tail."""
    s = 4
    full = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=40,
                                               s=s))
    a = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=24,
                                            s=s))
    b = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=16,
                                            s=s), state=a.aux["state"])
    assert np.array_equal(np.asarray(full.x), np.asarray(b.x))
    assert np.array_equal(np.asarray(full.objective)[24:],
                          np.asarray(b.objective))


def test_resume_unaligned_matches_to_roundoff(sfista_prob):
    """A split that shifts group boundaries (24 % 7 != 0) regroups the
    summations, so bitwise equality is not expected — but the iterates
    agree to roundoff (same guarantee as the chaos tier's 1e-8)."""
    s = 7
    full = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=40,
                                               s=s))
    a = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=24,
                                            s=s))
    b = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=16,
                                            s=s), state=a.aux["state"])
    np.testing.assert_allclose(np.asarray(b.x), np.asarray(full.x),
                               atol=1e-5)


def test_warm_start(sfista_prob):
    """x0 warm start: momentum restarts from y = x0 with locally rebuilt
    residuals; a warm-started solve picks up where the cold one's x
    left off (objective starts near the cold run's end)."""
    cold = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=48,
                                               s=4))
    warm = ca_sfista(sfista_prob, SolverConfig(block_size=4, iterations=8,
                                               s=4), x0=cold.x)
    o_cold, o_warm = np.asarray(cold.objective), np.asarray(warm.objective)
    assert o_warm[0] < 1.2 * o_cold[-1]
    assert o_warm[-1] < o_cold[0]


def test_sharded_one_allreduce_per_outer():
    """The registry satellite claim end-to-end: CA-SFISTA lowers through
    the UNMODIFIED generic sharded driver to HLO with exactly one
    static all-reduce in the scan body, at every s."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import re, jax
from repro.core import api
from repro.core.types import SolverConfig
mesh = jax.make_mesh((8,), ("data",))
for s in (1, 8):
    cfg = SolverConfig(block_size=4, iterations=16, s=s,
                       track_objective=False)
    txt = api.lower_solve("sfista", cfg, mesh, m=256, n=64,
                          axes="data").compile().as_text()
    static = len(re.findall(r"= \S+ all-reduce\(", txt))
    print("STATIC", s, static)
    assert static == 1, (s, static)
print("SFISTA_COLL_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "SFISTA_COLL_OK" in out.stdout


@pytest.mark.slow
def test_ca_sfista_final_error_f64():
    """Table III analogue for the fifth family: CA-SFISTA == SFISTA at
    machine-epsilon scale in f64 (acceptance bound 1e-10), across an s
    sweep including remainder tails."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import SolverConfig
from repro.core.sfista import SFISTAProblem, sfista, ca_sfista
rng = np.random.default_rng(0)
m, n = 120, 48
A = rng.standard_normal((m, n))
xt = np.zeros(n); xt[:6] = rng.standard_normal(6)
b = A @ xt + 0.1 * rng.standard_normal(m)
lam = 0.1 * float(np.abs(A.T @ b).max())
prob = SFISTAProblem(A=A, b=b, lam=lam)
H = 99
base = sfista(prob, SolverConfig(block_size=4, iterations=H,
                                 dtype=jnp.float64))
o1 = np.asarray(base.objective)
worst = 0.0
for s in (1, 3, 8, 16, 33):
    sa = ca_sfista(prob, SolverConfig(block_size=4, iterations=H, s=s,
                                      dtype=jnp.float64))
    dev = float(np.max(np.abs(np.asarray(sa.objective) - o1)
                       / np.maximum(np.abs(o1), 1e-30)))
    xdev = float(np.max(np.abs(np.asarray(sa.x) - np.asarray(base.x))))
    worst = max(worst, dev, xdev)
assert o1[-1] < 0.5 * o1[0]           # converges, not just agrees
print("DEV", worst)
assert worst < 1e-10, worst
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    dev = float(out.stdout.split("DEV")[1].strip())
    assert dev < 1e-10
