"""Checkpoint round-trips of SA solver state, for every registered
family x variant: save the :class:`SolveState` at an outer-iteration
boundary through ``repro.checkpoint``, restore it, continue — the final
iterate must be BIT-IDENTICAL to the uninterrupted solve (resume
restores the recurrence carries verbatim; nothing is recomputed).

The multi-device failure/re-mesh path lives in tests/test_chaos.py;
everything here runs on the default single device."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.api import resolve_family
from repro.core.types import (FAMILIES, LassoProblem, LogRegProblem,
                              SVMProblem, SolveState, SolverConfig,
                              SparseOperand)
from repro.runtime.elastic import ElasticConfig

_RNG = np.random.default_rng(11)
_M, _N = 24, 40
_A = _RNG.standard_normal((_M, _N)).astype(np.float32)
_B = _RNG.standard_normal(_M).astype(np.float32)
_SIGNS = np.sign(_RNG.standard_normal(_M)).astype(np.float32)
_LAM = 0.1 * float(np.abs(_A.T @ _B).max())


def _problem(family: str, sparse: bool = False):
    A = SparseOperand.from_dense(_A) if sparse else jnp.asarray(_A)
    if family == "lasso":
        return LassoProblem(A=A, b=jnp.asarray(_B), lam=_LAM)
    if family == "svm":
        return SVMProblem(A=A, b=jnp.asarray(_SIGNS), lam=0.5)
    if family == "ksvm":
        return SVMProblem(A=A, b=jnp.asarray(_SIGNS), lam=0.5,
                          kernel="rbf", kernel_params={"gamma": 0.3})
    if family == "logreg":
        return LogRegProblem(A=A, b=jnp.asarray(_SIGNS), lam=0.1)
    raise AssertionError(family)


# (family, s, accelerated): every registered family x variant. H=12 and
# the h=6 cut are multiples of every s here, so the cut is always an
# outer-iteration boundary.
CASES = [
    ("lasso", 1, False), ("lasso", 1, True),
    ("lasso", 3, False), ("lasso", 3, True),
    ("svm", 1, False), ("svm", 2, False),
    ("ksvm", 1, False), ("ksvm", 2, False),
    ("logreg", 1, False), ("logreg", 2, False),
]


def _cfg(family, s, accelerated, iterations):
    return SolverConfig(block_size=4, s=s, iterations=iterations,
                        accelerated=accelerated, dtype=jnp.float32)


def _roundtrip_state(tmp_path, fam, cfg, state: SolveState) -> SolveState:
    """State -> npz checkpoint on disk -> state, through the real
    save/restore path with the family's logical specs."""
    layout = fam.state_layout(cfg)
    axis = fam.default_axes if isinstance(fam.default_axes, str) else "data"
    specs = {name: (P(axis) if lay == "partition" else P())
             for name, lay in layout}
    save_checkpoint(str(tmp_path), state.iteration, dict(state.carry),
                    specs=specs, extra={"iteration": state.iteration})
    tree, extra = restore_checkpoint(str(tmp_path))
    return SolveState(int(extra["iteration"]), dict(tree))


@pytest.mark.parametrize("family,s,accelerated", CASES)
def test_checkpoint_roundtrip_bit_identical(tmp_path, family, s,
                                            accelerated):
    fam = FAMILIES[family]
    prob = _problem(family)
    full = fam.solve(prob, _cfg(family, s, accelerated, 12))
    half = fam.solve(prob, _cfg(family, s, accelerated, 6))
    state = _roundtrip_state(tmp_path, fam,
                             _cfg(family, s, accelerated, 6),
                             half.aux["state"])
    assert state.iteration == 6
    resumed = fam.solve(prob, _cfg(family, s, accelerated, 6),
                        state=state)
    np.testing.assert_array_equal(np.asarray(resumed.x),
                                  np.asarray(full.x))
    assert resumed.aux["state"].iteration == 12
    # the stitched objective trace matches the uninterrupted one exactly
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(half.objective),
                        np.asarray(resumed.objective)]),
        np.asarray(full.objective))


@pytest.mark.parametrize("family,s", [("lasso", 3), ("logreg", 2)])
def test_checkpoint_roundtrip_sparse_operand(tmp_path, family, s):
    """Resume works when A is a SparseOperand: the checkpointed state
    only holds vectors, so the operand's format is irrelevant to the
    round-trip — but the resumed solve must still run the sparse path
    and stay bit-identical."""
    fam = FAMILIES[family]
    prob = _problem(family, sparse=True)
    cfg6 = _cfg(family, s, False, 6)
    full = fam.solve(prob, _cfg(family, s, False, 12))
    half = fam.solve(prob, cfg6)
    state = _roundtrip_state(tmp_path, fam, cfg6, half.aux["state"])
    resumed = fam.solve(prob, cfg6, state=state)
    np.testing.assert_array_equal(np.asarray(resumed.x),
                                  np.asarray(full.x))


def test_state_and_x0_mutually_exclusive():
    fam = FAMILIES["lasso"]
    prob = _problem("lasso")
    cfg = _cfg("lasso", 1, False, 4)
    state = fam.solve(prob, cfg).aux["state"]
    with pytest.raises(ValueError, match="x0"):
        fam.solve(prob, cfg, x0=jnp.zeros(_N), state=state)


def test_state_layout_covers_carry_for_every_family():
    """The layout hook is the checkpoint schema: every leaf the solver
    emits in its SolveState carry must have a declared placement, and
    vice versa — a drifting carry would otherwise checkpoint partially
    and explode only at restore time."""
    for family, s, accelerated in CASES:
        fam = FAMILIES[family]
        cfg = _cfg(family, s, accelerated, max(s, 2) * 2)
        res = fam.solve(_problem(family), cfg)
        carry_keys = set(res.aux["state"].carry)
        layout_keys = {name for name, _ in fam.state_layout(cfg)}
        assert carry_keys == layout_keys, (family, s, accelerated)
        assert all(lay in ("replicated", "partition")
                   for _, lay in fam.state_layout(cfg))


def test_elastic_config_validation():
    with pytest.raises(ValueError, match="checkpoint_every"):
        ElasticConfig(checkpoint_every=0)
    with pytest.raises(ValueError, match="keep"):
        ElasticConfig(keep=0)


def test_solve_elastic_single_device_matches_local(tmp_path):
    """The elastic driver on a 1-device mesh with no failures equals the
    plain local solve bit-for-bit (segmentation at outer boundaries is
    exact, not approximate)."""
    from repro.runtime import solve_elastic
    fam = FAMILIES["lasso"]
    prob = _problem("lasso")
    cfg = dataclasses.replace(_cfg("lasso", 3, False, 12),
                              track_objective=True)
    ref = fam.solve(prob, cfg)
    res = solve_elastic(prob, cfg, elastic=ElasticConfig(
        checkpoint_dir=str(tmp_path), checkpoint_every=2))
    np.testing.assert_array_equal(np.asarray(res.x), np.asarray(ref.x))
    np.testing.assert_array_equal(np.asarray(res.objective),
                                  np.asarray(ref.objective))
    assert res.aux["elastic"]["recoveries"] == []


def test_solve_elastic_all_hosts_lost_raises(tmp_path):
    from repro.runtime import FailureInjector, solve_elastic
    prob = _problem("lasso")
    cfg = _cfg("lasso", 1, False, 4)
    with pytest.raises(RuntimeError, match="all hosts lost"):
        solve_elastic(prob, cfg,
                      elastic=ElasticConfig(checkpoint_dir=str(tmp_path)),
                      injector=FailureInjector(failures={2: [0]}))


def test_resolve_family_state_layout_registered_everywhere():
    for name, fam in FAMILIES.items():
        assert fam.state_layout is not None, name
