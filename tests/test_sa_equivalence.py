"""The paper's central claim (Sec. III / Fig. 2 / Table III): the SA
variants produce the SAME iterate sequence as the classical methods — the
transformation only rearranges arithmetic. We verify the full objective
trajectories match to f32 roundoff for all four Lasso methods and both
SVM losses, across several s and block sizes, and reproduce the
machine-epsilon-level Table III errors in f64 via a subprocess."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (LassoProblem, SVMProblem, SolverConfig,
                        bcd_lasso, acc_bcd_lasso, dcd_svm, sa_svm,
                        sa_bcd_lasso, sa_acc_bcd_lasso)


@pytest.mark.parametrize("mu,accelerated", [(1, True), (4, True),
                                            (1, False), (4, False)])
@pytest.mark.parametrize("s", [4, 12])
def test_lasso_sa_trajectory_matches(lasso_data, mu, accelerated, s):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    H = 48
    cfg = SolverConfig(block_size=mu, iterations=H, accelerated=accelerated)
    cfg_sa = SolverConfig(block_size=mu, iterations=H, s=s,
                          accelerated=accelerated)
    base = (acc_bcd_lasso if accelerated else bcd_lasso)(prob, cfg)
    sa = (sa_acc_bcd_lasso if accelerated else sa_bcd_lasso)(prob, cfg_sa)
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    # the solver actually makes progress (non-trivial trajectory)
    assert o1[-1] < 0.9 * o1[0]


@pytest.mark.parametrize("loss", ["l1", "l2"])
@pytest.mark.parametrize("s", [4, 16])
def test_svm_sa_trajectory_matches(svm_data, loss, s):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
    H = 64
    base = dcd_svm(prob, SolverConfig(iterations=H))
    sa = sa_svm(prob, SolverConfig(iterations=H, s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    assert o1[-1] < o1[0]          # dual objective decreases


def test_final_relative_error_f64_table3():
    """Table III analogue: in f64 the final relative objective error of
    SA vs non-SA is at machine-epsilon scale (paper: ~1e-16; we allow
    1e-12 headroom for the different BLAS)."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import (LassoProblem, SolverConfig, acc_bcd_lasso,
                        sa_acc_bcd_lasso)
rng = np.random.default_rng(0)
m, n = 120, 40
A = rng.standard_normal((m, n))
xt = np.zeros(n); xt[:5] = rng.standard_normal(5)
b = A @ xt + 0.1 * rng.standard_normal(m)
lam = 0.1 * float(np.abs(A.T @ b).max())
prob = LassoProblem(A=A, b=b, lam=lam)
H = 64
base = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=H,
                                        dtype=jnp.float64))
sa = sa_acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=H, s=8,
                                         dtype=jnp.float64))
o1 = float(base.objective[-1]); o2 = float(sa.objective[-1])
rel = abs(o1 - o2) / abs(o1)
print("REL", rel)
assert rel < 1e-12, rel
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rel = float(out.stdout.split("REL")[1].strip())
    assert rel < 1e-12
