"""The paper's central claim (Sec. III / Fig. 2 / Table III): the SA
variants produce the SAME iterate sequence as the classical methods — the
transformation only rearranges arithmetic. We verify the full objective
trajectories match to f32 roundoff for all four Lasso methods and both
SVM losses, across several s and block sizes, and reproduce the
machine-epsilon-level Table III errors in f64 via a subprocess."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (LassoProblem, SVMProblem, SolverConfig,
                        bcd_lasso, acc_bcd_lasso, bdcd_svm, dcd_svm,
                        duality_gap, sa_bdcd_svm, sa_svm,
                        sa_bcd_lasso, sa_acc_bcd_lasso)


@pytest.mark.parametrize("mu,accelerated", [(1, True), (4, True),
                                            (1, False), (4, False)])
@pytest.mark.parametrize("s", [4, 12])
def test_lasso_sa_trajectory_matches(lasso_data, mu, accelerated, s):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    H = 48
    cfg = SolverConfig(block_size=mu, iterations=H, accelerated=accelerated)
    cfg_sa = SolverConfig(block_size=mu, iterations=H, s=s,
                          accelerated=accelerated)
    base = (acc_bcd_lasso if accelerated else bcd_lasso)(prob, cfg)
    sa = (sa_acc_bcd_lasso if accelerated else sa_bcd_lasso)(prob, cfg_sa)
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    # the solver actually makes progress (non-trivial trajectory)
    assert o1[-1] < 0.9 * o1[0]


@pytest.mark.parametrize("loss", ["l1", "l2"])
@pytest.mark.parametrize("s", [4, 16])
def test_svm_sa_trajectory_matches(svm_data, loss, s):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
    H = 64
    base = dcd_svm(prob, SolverConfig(iterations=H))
    sa = sa_svm(prob, SolverConfig(iterations=H, s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    assert o1[-1] < o1[0]          # dual objective decreases


_BDCD_BASE_CACHE = {}


def _bdcd_base(svm_data, loss, mu, H):
    """bdcd_svm depends only on (loss, mu, H) — cache across the s sweep."""
    key = (loss, mu, H)
    if key not in _BDCD_BASE_CACHE:
        A, b = svm_data
        prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
        _BDCD_BASE_CACHE[key] = bdcd_svm(
            prob, SolverConfig(block_size=mu, iterations=H))
    return _BDCD_BASE_CACHE[key]


@pytest.mark.parametrize("loss", ["l1", "l2"])
@pytest.mark.parametrize("mu", [1, 2, 4])
@pytest.mark.parametrize("s", [1, 2, 4, 8])
def test_svm_blocked_sa_trajectory_matches(svm_data, loss, mu, s):
    """SA-BDCD == BDCD iterates across the full (s, mu, loss) sweep."""
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
    H = 32
    base = _bdcd_base(svm_data, loss, mu, H)
    sa = sa_bdcd_svm(prob, SolverConfig(block_size=mu, iterations=H, s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.aux["alpha"]),
                               np.asarray(base.aux["alpha"]), atol=1e-4)
    assert o1[-1] < o1[0]          # dual objective decreases


@pytest.mark.parametrize("loss", ["l1", "l2"])
def test_svm_blocked_sa_collisions_within_group(loss):
    """Tiny m forces the same row index to repeat across the s blocks of
    one outer group (s*mu > m) — the Eq. 14/15 collision terms must keep
    SA-BDCD exact."""
    import jax
    from repro.core.linalg import sample_block

    rng = np.random.default_rng(3)
    m, n = 10, 24
    A = rng.standard_normal((m, n)).astype(np.float32)
    b = np.sign(rng.standard_normal(m)).astype(np.float32)
    b[b == 0] = 1.0
    prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
    s, mu, H = 8, 2, 16
    # verify the shared index stream actually collides within an s-group
    key = jax.random.key(0)
    idxs = np.asarray(jax.vmap(
        lambda h: sample_block(jax.random.fold_in(key, h), m, mu))(
        np.arange(1, s + 1)))
    assert len(np.unique(idxs)) < idxs.size
    base = bdcd_svm(prob, SolverConfig(block_size=mu, iterations=H))
    sa = sa_bdcd_svm(prob, SolverConfig(block_size=mu, iterations=H, s=s))
    np.testing.assert_allclose(np.asarray(sa.objective),
                               np.asarray(base.objective),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sa.aux["alpha"]),
                               np.asarray(base.aux["alpha"]), atol=1e-4)


def test_svm_blocked_duality_gap_decreases(svm_data):
    """Convergence of the blocked SA path: the duality gap shrinks as H
    grows (weak duality keeps it nonnegative up to roundoff)."""
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2")
    gaps = []
    for H in (16, 64, 256):
        res = sa_bdcd_svm(prob, SolverConfig(block_size=4, iterations=H,
                                             s=4))
        gaps.append(float(duality_gap(prob, res.x, res.aux["alpha"])))
    assert gaps[-1] < gaps[0]
    assert all(g > -1e-3 for g in gaps)


@pytest.mark.parametrize("accelerated", [False, True])
def test_lasso_sa_remainder_iterations(lasso_data, accelerated):
    """iterations % s != 0 (regression: objs.reshape(H) used to crash):
    the SA Lasso solvers run the H mod s tail group and still match the
    classical trajectory inner-iteration-for-inner-iteration."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    H, s = 10, 4
    cfg = SolverConfig(block_size=4, iterations=H, accelerated=accelerated)
    cfg_sa = SolverConfig(block_size=4, iterations=H, s=s,
                          accelerated=accelerated)
    assert cfg_sa.outer_iterations == 3     # 2 full groups + tail of 2
    base = (acc_bcd_lasso if accelerated else bcd_lasso)(prob, cfg)
    sa = (sa_acc_bcd_lasso if accelerated else sa_bcd_lasso)(prob, cfg_sa)
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)


def test_lasso_sa_shorter_than_one_group(lasso_data):
    """H < s: zero full groups, the whole solve is the tail group."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    H, s = 3, 8
    base = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=H))
    sa = sa_acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=H,
                                             s=s))
    np.testing.assert_allclose(np.asarray(sa.objective),
                               np.asarray(base.objective), rtol=5e-5)


def test_lasso_symmetric_gram_matches_dense(lasso_data):
    """Triangle-packed Allreduce (cfg.symmetric_gram) reduces the same
    values as the dense path, only re-laid-out -> identical iterates."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    cfg = SolverConfig(block_size=4, iterations=32, s=8)
    cfg_sym = SolverConfig(block_size=4, iterations=32, s=8,
                           symmetric_gram=True)
    dense = sa_acc_bcd_lasso(prob, cfg)
    packed = sa_acc_bcd_lasso(prob, cfg_sym)
    np.testing.assert_allclose(np.asarray(packed.objective),
                               np.asarray(dense.objective), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(packed.x), np.asarray(dense.x),
                               atol=1e-6)


def test_svm_symmetric_gram_matches_dense(svm_data):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l1")
    cfg = SolverConfig(block_size=2, iterations=32, s=8)
    cfg_sym = SolverConfig(block_size=2, iterations=32, s=8,
                           symmetric_gram=True)
    dense = sa_bdcd_svm(prob, cfg)
    packed = sa_bdcd_svm(prob, cfg_sym)
    np.testing.assert_allclose(np.asarray(packed.objective),
                               np.asarray(dense.objective), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(packed.x), np.asarray(dense.x),
                               atol=1e-6)


@pytest.mark.slow
def test_svm_blocked_final_error_f64():
    """SA-BDCD == BDCD at machine-epsilon scale in f64 (Table III
    analogue for the blocked SVM; acceptance bound 1e-10)."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import SVMProblem, SolverConfig, bdcd_svm, sa_bdcd_svm
rng = np.random.default_rng(7)
m, n = 96, 40
A = rng.standard_normal((m, n))
w = rng.standard_normal(n)
b = np.sign(A @ w + 0.1 * rng.standard_normal(m)); b[b == 0] = 1.0
worst = 0.0
for loss in ("l1", "l2"):
    prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
    for mu in (1, 4):
        base = bdcd_svm(prob, SolverConfig(block_size=mu, iterations=64,
                                           dtype=jnp.float64))
        sa = sa_bdcd_svm(prob, SolverConfig(block_size=mu, iterations=64,
                                            s=8, dtype=jnp.float64))
        o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
        dev = float(np.max(np.abs(o1 - o2) / np.maximum(np.abs(o1), 1e-30)))
        xdev = float(np.max(np.abs(np.asarray(base.x) - np.asarray(sa.x))))
        worst = max(worst, dev, xdev)
print("DEV", worst)
assert worst < 1e-10, worst
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    dev = float(out.stdout.split("DEV")[1].strip())
    assert dev < 1e-10


@pytest.mark.slow
def test_final_relative_error_f64_table3():
    """Table III analogue: in f64 the final relative objective error of
    SA vs non-SA is at machine-epsilon scale (paper: ~1e-16; we allow
    1e-12 headroom for the different BLAS)."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import (LassoProblem, SolverConfig, acc_bcd_lasso,
                        sa_acc_bcd_lasso)
rng = np.random.default_rng(0)
m, n = 120, 40
A = rng.standard_normal((m, n))
xt = np.zeros(n); xt[:5] = rng.standard_normal(5)
b = A @ xt + 0.1 * rng.standard_normal(m)
lam = 0.1 * float(np.abs(A.T @ b).max())
prob = LassoProblem(A=A, b=b, lam=lam)
H = 64
base = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=H,
                                        dtype=jnp.float64))
sa = sa_acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=H, s=8,
                                         dtype=jnp.float64))
o1 = float(base.objective[-1]); o2 = float(sa.objective[-1])
rel = abs(o1 - o2) / abs(o1)
print("REL", rel)
assert rel < 1e-12, rel
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rel = float(out.stdout.split("REL")[1].strip())
    assert rel < 1e-12


# ---------------------------------------------------------------------------
# Elastic-net and group-lasso SA equivalence: prox.py supports l2/groups
# and both flow through _prep into all four lasso variants, but until
# this tier only unit prox tests exercised them.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("accelerated", [False, True])
@pytest.mark.parametrize("s", [4, 6])       # 6 does not divide H = 32
def test_elastic_net_sa_trajectory_matches(lasso_data, accelerated, s):
    """SA == classical for the elastic-net prox (l2 > 0), including a
    remainder tail group (H % s != 0)."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam, l2=0.5 * lam)
    H = 32
    cfg = SolverConfig(block_size=4, iterations=H, accelerated=accelerated)
    cfg_sa = SolverConfig(block_size=4, iterations=H, s=s,
                          accelerated=accelerated)
    base = (acc_bcd_lasso if accelerated else bcd_lasso)(prob, cfg)
    sa = (sa_acc_bcd_lasso if accelerated else sa_bcd_lasso)(prob, cfg_sa)
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    assert o1[-1] < o1[0]


@pytest.mark.parametrize("accelerated", [False, True])
@pytest.mark.parametrize("s", [4, 6])       # 6 does not divide H = 32
def test_group_lasso_sa_trajectory_matches(lasso_data, accelerated, s):
    """SA == classical for group lasso (whole-group sampling + block
    soft-threshold), including a remainder tail group."""
    A, b, lam = lasso_data
    n, mu = A.shape[1], 4
    groups = np.repeat(np.arange(n // mu), mu)
    prob = LassoProblem(A=A, b=b, lam=lam, groups=groups)
    H = 32
    cfg = SolverConfig(block_size=mu, iterations=H, accelerated=accelerated)
    cfg_sa = SolverConfig(block_size=mu, iterations=H, s=s,
                          accelerated=accelerated)
    base = (acc_bcd_lasso if accelerated else bcd_lasso)(prob, cfg)
    sa = (sa_acc_bcd_lasso if accelerated else sa_bcd_lasso)(prob, cfg_sa)
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    assert o1[-1] < o1[0]


@pytest.mark.slow
def test_elastic_net_and_group_lasso_sa_f64():
    """The f64 <= 1e-10 tier for the two non-plain regularizers: the SA
    transformation only rearranges arithmetic, so elastic-net and
    group-lasso trajectories match the classical solvers at machine
    epsilon across an s x mu sweep including remainder groups
    (H % s != 0) — same acceptance bound as the Table III tiers."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import (LassoProblem, SolverConfig, acc_bcd_lasso,
                        bcd_lasso, sa_acc_bcd_lasso, sa_bcd_lasso)
rng = np.random.default_rng(12)
m, n = 96, 48
A = rng.standard_normal((m, n))
xt = np.zeros(n); xt[:6] = rng.standard_normal(6)
b = A @ xt + 0.1 * rng.standard_normal(m)
lam = 0.1 * float(np.abs(A.T @ b).max())
H = 36
worst = 0.0
for reg in ("l2", "groups"):
    for mu in (2, 4):
        for s in (4, 8, 10):                # 8, 10 do not divide H = 36
            kw = {"l2": 0.5 * lam} if reg == "l2" else \
                 {"groups": np.repeat(np.arange(n // mu), mu)}
            prob = LassoProblem(A=A, b=b, lam=lam, **kw)
            for acc in (False, True):
                cfg = SolverConfig(block_size=mu, iterations=H,
                                   accelerated=acc, dtype=jnp.float64)
                cfg_sa = SolverConfig(block_size=mu, iterations=H, s=s,
                                      accelerated=acc, dtype=jnp.float64)
                base = (acc_bcd_lasso if acc else bcd_lasso)(prob, cfg)
                sa = (sa_acc_bcd_lasso if acc else sa_bcd_lasso)(prob,
                                                                 cfg_sa)
                o1 = np.asarray(base.objective)
                o2 = np.asarray(sa.objective)
                dev = float(np.max(np.abs(o1 - o2)
                                   / np.maximum(np.abs(o1), 1e-30)))
                xdev = float(np.max(np.abs(np.asarray(base.x)
                                           - np.asarray(sa.x))))
                worst = max(worst, dev, xdev)
print("DEV", worst)
assert worst < 1e-10, worst
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    dev = float(out.stdout.split("DEV")[1].strip())
    assert dev < 1e-10
