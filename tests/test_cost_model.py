"""Paper Table I properties + machine-model sanity (Fig. 4 shape)."""
import math

import pytest

from repro.core.cost_model import (Machine, PAPER_DATASETS, ProblemDims,
                                   best_s, lasso_costs, lasso_speedup,
                                   predicted_time, svm_costs, svm_speedup)

DIMS = ProblemDims(m=100_000, n=10_000, f=0.01)


def test_latency_drops_by_s():
    c1 = lasso_costs(DIMS, H=1024, mu=8, s=1, P=256)
    c16 = lasso_costs(DIMS, H=1024, mu=8, s=16, P=256)
    assert c16["L"] == pytest.approx(c1["L"] / 16)


def test_flops_and_bandwidth_grow_by_s():
    c1 = lasso_costs(DIMS, H=1024, mu=8, s=1, P=256)
    c16 = lasso_costs(DIMS, H=1024, mu=8, s=16, P=256)
    # the data-dependent flop term scales by exactly s; the H*mu^3
    # subproblem term is s-independent.
    assert c16["W"] == pytest.approx(16 * c1["W"])
    sub = 1024 * 8 ** 3
    assert c16["F"] - sub == pytest.approx(16 * (c1["F"] - sub))
    # memory grows with the s^2 Gram term
    assert c16["M"] > c1["M"]


def test_speedup_has_interior_optimum():
    """Fig. 4e-h: speedup rises with s then falls once bandwidth/flops
    dominate -> best_s is interior for a latency-dominated machine."""
    machine = Machine("latency-heavy", alpha=1e-4, beta=1e-10, gamma=1e-12)
    s_star, sp = best_s(DIMS, H=4096, mu=4, P=4096, machine=machine)
    assert sp > 1.5
    assert 1 < s_star <= 1024
    # monotone decline after a much larger s
    sp_huge = lasso_speedup(DIMS, 4096, 4, 8192, 4096, machine)
    assert sp_huge < sp


def test_speedup_at_s1_is_unity():
    m = Machine.cray_xc30()
    assert lasso_speedup(DIMS, 100, 4, 1, 64, m) == pytest.approx(1.0)
    assert svm_speedup(DIMS, 100, 1, 64, m) == pytest.approx(1.0)


def test_paper_scale_speedups_plausible():
    """On Cray-XC30-like parameters at paper scale (P up to 12k cores,
    sparse datasets), predicted best-s speedups land in the paper's
    reported 1.2x-5.1x band (order-of-magnitude check, not a fit)."""
    m = Machine.cray_xc30()
    found = []
    for name in ("news20", "covtype", "url", "epsilon"):
        d = PAPER_DATASETS[name]
        s_star, sp = best_s(d, H=10_000, mu=1, P=1024, machine=m)
        found.append(sp)
    assert all(1.0 < sp < 40 for sp in found)
    assert any(sp > 1.5 for sp in found)


def test_svm_latency_model():
    c1 = svm_costs(DIMS, H=512, s=1, P=128)
    c8 = svm_costs(DIMS, H=512, s=8, P=128)
    assert c8["L"] == pytest.approx(c1["L"] / 8)
    assert c8["W"] == pytest.approx(8 * c1["W"])


def test_kernel_svm_costs():
    """The kernelized solver still amortizes latency by s, but moves the
    (m, s*mu) cross block (W independent of s per inner iteration, >>
    the linear s*mu^2 message) and pays the kernel-evaluation flops."""
    lin = svm_costs(DIMS, H=512, s=8, P=128, mu=4)
    rbf = svm_costs(DIMS, H=512, s=8, P=128, mu=4, kernel="rbf")
    rbf1 = svm_costs(DIMS, H=512, s=1, P=128, mu=4, kernel="rbf")
    assert rbf["L"] == pytest.approx(rbf1["L"] / 8)   # SA latency win
    assert rbf["W"] == pytest.approx(rbf1["W"])       # bandwidth flat in s
    assert rbf["W"] > lin["W"]                        # m-row cross block
    assert rbf["F"] > svm_costs(DIMS, H=512, s=8, P=128, mu=4,
                                kernel="poly")["F"] > lin["F"]
    assert svm_speedup(DIMS, 100, 1, 64, Machine.cray_xc30(),
                       kernel="rbf") == pytest.approx(1.0)


def test_logreg_costs():
    """logreg moves the (m, s*mu) cross block (kernel-SVM message shape):
    latency amortizes by s, bandwidth is flat in s, and the margin
    update adds O(m mu) flops per inner iteration."""
    from repro.core.cost_model import logreg_costs, logreg_speedup
    c1 = logreg_costs(DIMS, H=512, mu=4, s=1, P=128)
    c8 = logreg_costs(DIMS, H=512, mu=4, s=8, P=128)
    assert c8["L"] == pytest.approx(c1["L"] / 8)
    assert c8["W"] == pytest.approx(c1["W"])
    assert c8["M"] > c1["M"]                       # s*mu*m replicated cross
    assert logreg_speedup(DIMS, 100, 1, 64,
                          Machine.cray_xc30()) == pytest.approx(1.0)
    assert logreg_speedup(DIMS, 10_000, 32, 1024,
                          Machine.cray_xc30()) > 1.0


def test_family_cost_entries_follow_table1_shape():
    """Every registered family exposes a cost-model entry with the
    Table I keys and the s-fold latency reduction."""
    from repro.core.types import FAMILIES
    import repro.core.api  # noqa: F401  (populates FAMILIES)
    for fam in FAMILIES.values():
        assert fam.costs is not None, fam.name
        c1 = fam.costs(DIMS, 512, 2, 1, 128)
        c16 = fam.costs(DIMS, 512, 2, 16, 128)
        assert {"F", "L", "W", "M"} <= set(c1)
        assert c16["L"] == pytest.approx(c1["L"] / 16), fam.name


def test_predicted_time_positive_and_additive():
    m = Machine.tpu_v5e_pod()
    c = lasso_costs(DIMS, H=256, mu=8, s=4, P=256)
    t = predicted_time(c, m)
    assert t > 0
    assert t == pytest.approx(m.gamma * c["F"] + m.beta * c["W"]
                              + m.alpha * c["L"] + m.kappa * c["I"])
