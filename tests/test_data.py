import numpy as np
import pytest

from repro.data.sparse import (SYNTHETIC_DATASETS, make_lasso_dataset,
                               make_svm_dataset)
from repro.data.tokens import TokenPipeline


def test_pipeline_deterministic():
    p1 = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    p2 = TokenPipeline(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    t1, y1 = p1.batch_at(5)
    t2, y2 = p2.batch_at(5)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(y1, y2)
    t3, _ = p1.batch_at(6)
    assert not np.array_equal(t1, t3)


def test_targets_are_shifted_tokens():
    p = TokenPipeline(vocab_size=50, global_batch=2, seq_len=8, seed=0)
    t, y = p.batch_at(0)
    # token stream continuity: targets[i] == tokens[i+1]
    np.testing.assert_array_equal(t[:, 1:], y[:, :-1])


def test_shard_invariance_across_topologies():
    """The elastic-scaling invariant: concatenating the shards of ANY
    shard count reproduces the same global batch."""
    p = TokenPipeline(vocab_size=64, global_batch=12, seq_len=8, seed=1)
    g_tokens, _ = p.batch_at(3)
    for n_shards in (1, 2, 3, 4, 6):
        parts = [p.shard_at(3, s, n_shards)[0] for s in range(n_shards)]
        np.testing.assert_array_equal(np.concatenate(parts), g_tokens)


def test_checkpoint_restore_resumes():
    p = TokenPipeline(vocab_size=64, global_batch=4, seq_len=8, seed=1)
    next(p)
    next(p)
    ck = p.checkpoint()
    expected, _ = p.batch_at(2)
    p2 = TokenPipeline.restore(ck)
    got, _ = next(p2)
    np.testing.assert_array_equal(got, expected)


def test_zipf_distribution_is_skewed():
    p = TokenPipeline(vocab_size=1000, global_batch=16, seq_len=64, seed=0)
    t, _ = p.batch_at(0)
    # low-rank (common) tokens dominate
    assert np.mean(t < 100) > 0.5


@pytest.mark.parametrize("name", list(SYNTHETIC_DATASETS))
def test_synthetic_regimes(name):
    spec = SYNTHETIC_DATASETS[name]
    A, b, lam_max = make_lasso_dataset(name, seed=0) \
        if True else (None, None, None)
    assert A.shape == (spec.m, spec.n)
    density = np.mean(A != 0)
    if spec.density < 1.0:
        assert density == pytest.approx(spec.density, rel=0.5)
    assert lam_max > 0
    # no empty columns (Gram blocks stay PSD-nonzero)
    assert np.all(np.abs(A).sum(axis=0) > 0)


def test_svm_dataset_labels():
    A, b = make_svm_dataset("w1a-like", seed=0)
    assert set(np.unique(b)) <= {-1.0, 1.0}
