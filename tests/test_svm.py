import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SVMProblem, SolverConfig, bdcd_svm, dcd_svm,
                        dual_objective, duality_gap, primal_objective,
                        sa_bdcd_svm, sa_svm)


def test_incremental_dual_tracking_exact(svm_data):
    """The per-iteration dual objective (tracked with local scalars only)
    must equal the direct quadratic-form evaluation."""
    A, b = svm_data
    for loss in ("l1", "l2"):
        prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
        res = dcd_svm(prob, SolverConfig(iterations=96))
        tracked = float(res.objective[-1])
        direct = float(dual_objective(prob, res.aux["alpha"]))
        assert abs(tracked - direct) < 1e-3 * max(1.0, abs(direct))


def test_duality_gap_decreases(svm_data):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2")
    gaps = []
    for H in (16, 64, 256):
        res = dcd_svm(prob, SolverConfig(iterations=H))
        gaps.append(float(duality_gap(prob, res.x, res.aux["alpha"])))
    assert gaps[-1] < gaps[0]
    assert all(g > -1e-3 for g in gaps)      # weak duality


def test_alpha_box_constraints(svm_data):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l1")
    res = dcd_svm(prob, SolverConfig(iterations=128))
    alpha = np.asarray(res.aux["alpha"])
    assert np.all(alpha >= -1e-6)
    assert np.all(alpha <= prob.lam + 1e-6)   # nu = lam for L1


def test_x_is_dual_combination(svm_data):
    """x must equal  A^T (b * alpha)  at all times (Alg. 3 line 2/14)."""
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2")
    res = sa_svm(prob, SolverConfig(iterations=64, s=8))
    alpha = np.asarray(res.aux["alpha"])
    np.testing.assert_allclose(np.asarray(res.x),
                               A.T @ (b * alpha), atol=1e-3)


def test_blocked_incremental_dual_tracking_exact(svm_data):
    """The block dual-objective increments (DESIGN.md) must agree with the
    direct quadratic-form evaluation, for both hinge losses."""
    A, b = svm_data
    for loss in ("l1", "l2"):
        prob = SVMProblem(A=A, b=b, lam=1.0, loss=loss)
        res = bdcd_svm(prob, SolverConfig(block_size=4, iterations=96))
        tracked = float(res.objective[-1])
        direct = float(dual_objective(prob, res.aux["alpha"]))
        assert abs(tracked - direct) < 1e-3 * max(1.0, abs(direct))


def test_blocked_alpha_box_constraints(svm_data):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l1")
    for solve in (lambda c: bdcd_svm(prob, c),
                  lambda c: sa_bdcd_svm(prob, dataclasses.replace(c, s=8))):
        res = solve(SolverConfig(block_size=4, iterations=128))
        alpha = np.asarray(res.aux["alpha"])
        assert np.all(alpha >= -1e-6)
        assert np.all(alpha <= prob.lam + 1e-6)   # nu = lam for L1


def test_blocked_x_is_dual_combination(svm_data):
    A, b = svm_data
    prob = SVMProblem(A=A, b=b, lam=1.0, loss="l2")
    res = sa_bdcd_svm(prob, SolverConfig(block_size=4, iterations=64, s=8))
    alpha = np.asarray(res.aux["alpha"])
    np.testing.assert_allclose(np.asarray(res.x),
                               A.T @ (b * alpha), atol=1e-3)
