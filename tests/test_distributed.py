"""Distributed solver + trainer semantics on 8 placeholder devices.

Runs in subprocesses because XLA_FLAGS must be set before jax imports
(the main test process keeps the default 1 device, per DESIGN.md)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
"""


def test_solvers_match_single_device():
    out = _run(HEADER + """
from repro.core import (LassoProblem, SVMProblem, SolverConfig,
                        solve_lasso, solve_svm, solve_lasso_sharded,
                        solve_svm_sharded)
mesh = jax.make_mesh((2, 4), ("pod", "data"))
mesh_m = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(1)
m, n = 203, 60
A = rng.standard_normal((m, n)).astype(np.float32)
xt = np.zeros(n); xt[:6] = rng.standard_normal(6)
b = (A @ xt + 0.1 * rng.standard_normal(m)).astype(np.float32)
lam = 0.1 * float(np.abs(A.T @ b).max())
prob = LassoProblem(A=A, b=b, lam=lam)
cfg = SolverConfig(block_size=4, iterations=48, s=8)
o1 = np.asarray(solve_lasso(prob, cfg).objective)
o2 = np.asarray(solve_lasso_sharded(prob, cfg, mesh,
                                    axes=("pod", "data")).objective)
assert np.max(np.abs(o1 - o2) / np.abs(o1)) < 1e-4, "lasso mismatch"

b2 = np.sign(rng.standard_normal(m)).astype(np.float32)
sprob = SVMProblem(A=A, b=b2, lam=1.0)
scfg = SolverConfig(iterations=48, s=8)
s1 = np.asarray(solve_svm(sprob, scfg).objective)
s2 = np.asarray(solve_svm_sharded(sprob, scfg, mesh_m).objective)
assert np.max(np.abs(s1 - s2) / np.maximum(np.abs(s1), 1e-9)) < 1e-4
print("DIST_OK")
""")
    assert "DIST_OK" in out


def test_sa_collective_count_reduction():
    """THE paper claim, verified structurally: the compiled HLO of the
    distributed solver contains H all-reduces for s=1 but only H/s for
    s>1 (+ O(1) for output reductions)."""
    out = _run(HEADER + """
from repro.core.distributed import lower_lasso_step
from repro.core.types import SolverConfig
mesh = jax.make_mesh((8,), ("data",))
import re
def count_allreduce(cfg):
    lowered = lower_lasso_step(cfg, mesh, m=256, n=64)
    txt = lowered.compile().as_text()
    # collectives inside the scan body execute once per outer iteration;
    # count distinct all-reduce ops in the while body.
    return len(re.findall(r"= \\S+ all-reduce\\(", txt))
H = 32
n1 = count_allreduce(SolverConfig(block_size=4, iterations=H, s=1,
                                  track_objective=False))
n8 = count_allreduce(SolverConfig(block_size=4, iterations=H, s=8,
                                  track_objective=False))
# static op counts are per scan body (1 outer iteration): both ~1; the
# RUNTIME counts are trips x static: s=1 -> H trips, s=8 -> H/8 trips.
print("STATIC", n1, n8)
assert n1 >= 1 and n8 >= 1
# runtime collective invocations = static * trip count
trips1, trips8 = H, H // 8
assert n8 * trips8 <= n1 * trips1 / 4, (n1, n8)
print("COLL_OK", n1 * trips1, n8 * trips8)
""")
    assert "COLL_OK" in out


@pytest.mark.slow
def test_trainer_elastic_restart():
    """Fault tolerance end-to-end: inject a host failure mid-run; the
    driver re-meshes to fewer devices, restores the checkpoint, and the
    loss trajectory continues (same global batches -> comparable loss)."""
    out = _run(HEADER + """
from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.optim.adamw import AdamW
from repro.runtime.driver import Trainer, TrainerConfig
from repro.runtime.failures import FailureInjector
import tempfile

arch = get_smoke_config("tinyllama-1.1b")
pipe = TokenPipeline(vocab_size=arch.vocab_size, global_batch=8,
                     seq_len=32, seed=0)
opt = AdamW(learning_rate=1e-3)
d = tempfile.mkdtemp()
cfg = TrainerConfig(steps=12, ckpt_dir=d, ckpt_every=4, model_axis=1)

# baseline: no failures
t0 = Trainer(arch, opt, pipe, cfg)
base = t0.run()

# with a failure at step 6 killing hosts 6,7 (devices 6,7)
pipe2 = TokenPipeline(vocab_size=arch.vocab_size, global_batch=8,
                      seq_len=32, seed=0)
d2 = tempfile.mkdtemp()
cfg2 = TrainerConfig(steps=12, ckpt_dir=d2, ckpt_every=4, model_axis=1)
inj = FailureInjector(failures={6: [6, 7]})
t1 = Trainer(arch, opt, pipe2, cfg2, failure_injector=inj)
res = t1.run()
assert res["final_step"] == 12
assert any("re-meshed" in e for e in res["events"]), res["events"]
assert len(t1.devices) == 6
# same data -> final losses in the same ballpark despite the restart
lb, lf = base["losses"][-1], res["losses"][-1]
assert abs(lb - lf) / lb < 0.2, (lb, lf)
print("ELASTIC_OK", lb, lf, res["events"])
""")
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_trainer_microbatch_equivalence():
    """Deferred-allreduce grad accumulation == single big batch (the
    SA-exactness analogue at the trainer level)."""
    out = _run(HEADER + """
from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.optim.adamw import AdamW
from repro.runtime.driver import Trainer, TrainerConfig
import tempfile
arch = get_smoke_config("tinyllama-1.1b")
def run(mb):
    pipe = TokenPipeline(vocab_size=arch.vocab_size, global_batch=8,
                         seq_len=32, seed=0)
    cfg = TrainerConfig(steps=6, ckpt_dir=tempfile.mkdtemp(),
                        ckpt_every=100, microbatches=mb, model_axis=2)
    t = Trainer(arch, AdamW(learning_rate=1e-3), pipe, cfg)
    return t.run()["losses"]
l1 = run(1)
l4 = run(4)
import numpy as np
d = abs(np.array(l1) - np.array(l4)) / np.abs(l1)
assert d.max() < 0.05, (l1, l4)
print("MICRO_OK", l1[-1], l4[-1])
""")
    assert "MICRO_OK" in out
