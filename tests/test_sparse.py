"""Sparse-operand execution tier.

Contracts under test:

1. **Operand integrity** — ``SparseOperand``'s BCOO and blocked-ELL
   forms agree with the dense matrix exactly (todense round-trip,
   matvec/rmatvec, gathers), and the data layer's ``as_operand`` path
   returns the SAME draw as the dense path (one RNG stream).
2. **Sparse == dense equivalence** — every family x variant solves a
   sparse-operand problem through ``repro.api.solve`` with f64
   deviation <= 1e-10 vs the dense path, including SA remainder groups
   (iterations % s != 0), collisions (small m), symmetric-gram packing,
   warm starts, objective diagnostics, and the sharded backend. Per the
   repo test convention (DESIGN.md) the f64 tiers run in subprocesses
   (x64 must be configured before the first JAX use and would leak into
   the main process); an f32 per-case sweep stays in-process for the
   fast tier.
3. **Bugfix regressions** — the inverted ``margin`` knob, the
   ``best_s`` logreg branch, and the ksvm cost hook's hardcoded kernel.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import (LassoProblem, LogRegProblem, SVMProblem,
                       SolverConfig, SparseOperand)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _sparse_matrix(seed, m, n, density=0.3, dtype=np.float32):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(dtype)
    A[rng.random((m, n)) >= density] = 0.0
    # no empty columns (keeps Gram blocks nonzero, as in repro.data).
    for j in np.flatnonzero(~(A != 0).any(axis=0)):
        A[rng.integers(m), j] = 1.0
    return A


@pytest.fixture(scope="module")
def sparse_problem_data():
    m, n = 72, 46
    A = _sparse_matrix(0, m, n)
    rng = np.random.default_rng(1)
    xt = np.zeros(n, np.float32)
    xt[:6] = rng.standard_normal(6)
    b = (A @ xt + 0.1 * rng.standard_normal(m)).astype(np.float32)
    lam = 0.1 * float(np.abs(A.T @ b).max())
    bs = np.sign(A @ rng.standard_normal(n).astype(np.float32)
                 + 0.1 * rng.standard_normal(m)).astype(np.float32)
    bs[bs == 0] = 1.0
    return A, SparseOperand.from_dense(A), b, lam, bs


# ---------------------------------------------------------------------------
# 1. operand integrity.
# ---------------------------------------------------------------------------

def test_operand_roundtrip_exact(sparse_problem_data):
    A, op, *_ = sparse_problem_data
    assert op.shape == A.shape and op.ndim == 2
    assert np.array_equal(np.asarray(op.todense()), A)
    assert np.array_equal(np.asarray(op.to_bcoo().todense()), A)
    assert op.nnz == int((A != 0).sum())
    # blocked-ELL metadata: per-row active K-blocks cover the nnz.
    row_nnz = (A != 0).sum(axis=1)
    blocks = np.asarray(op.row_blocks)
    assert np.all(blocks * op.ell_block >= row_nnz)
    assert np.all((blocks - 1) * op.ell_block < np.maximum(row_nnz, 1))


def test_operand_products_match_dense(sparse_problem_data):
    A, op, *_ = sparse_problem_data
    rng = np.random.default_rng(2)
    x = rng.standard_normal(A.shape[1]).astype(np.float32)
    y = rng.standard_normal(A.shape[0]).astype(np.float32)
    np.testing.assert_allclose(np.asarray(op.matvec(x)), A @ x,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(op.rmatvec(y)), A.T @ y,
                               rtol=1e-5, atol=1e-5)


def test_operand_gathers_match_dense(sparse_problem_data):
    from repro.kernels import spmm

    A, op, *_ = sparse_problem_data
    m, n = A.shape
    cols = jnp.asarray([1, 7, 7, 30])       # with a collision
    rows_g, vals_g, _ = op.gather_cols(cols)
    assert np.array_equal(
        np.asarray(spmm.scatter_dense(rows_g, vals_g, m)),
        A[:, np.asarray(cols)])
    ridx = jnp.asarray([0, 5, 5, 40])
    cols_g, rvals_g, _ = op.gather_rows(ridx)
    assert np.array_equal(
        np.asarray(spmm.scatter_dense(cols_g, rvals_g, n)),
        A[np.asarray(ridx)].T)


def test_operand_from_bcoo_and_astype(sparse_problem_data):
    A, op, *_ = sparse_problem_data
    op2 = SparseOperand.from_bcoo(op.to_bcoo())
    assert np.array_equal(np.asarray(op2.todense()), A)
    op16 = op.astype(jnp.bfloat16)
    assert op16.dtype == jnp.bfloat16
    assert op16.bcoo.data.dtype == jnp.bfloat16
    assert op16.shape == op.shape


def test_operand_is_a_pytree(sparse_problem_data):
    _, op, *_ = sparse_problem_data
    leaves, treedef = jax.tree_util.tree_flatten(op)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(op2, SparseOperand)
    assert op2.ell_block == op.ell_block
    doubled = jax.jit(lambda o: o.todense() * 2.0)(op)
    np.testing.assert_allclose(np.asarray(doubled),
                               2.0 * np.asarray(op.todense()))


def test_operand_rejects_bad_shapes():
    with pytest.raises(ValueError, match="matrix"):
        SparseOperand.from_dense(np.zeros(4))
    with pytest.raises(ValueError, match="ELL width"):
        SparseOperand.from_dense(np.ones((4, 20)), row_width=8)


def test_dataset_operand_same_rng_stream():
    from repro.data.sparse import make_lasso_dataset, make_svm_dataset

    A, b, lam = make_lasso_dataset("w1a-like", seed=3)
    op, b2, lam2 = make_lasso_dataset("w1a-like", seed=3, as_operand=True)
    assert isinstance(op, SparseOperand)
    assert np.array_equal(np.asarray(op.todense()), A)
    assert np.array_equal(b, b2) and lam == lam2
    As, bs = make_svm_dataset("w1a-like", seed=3)
    opS, bs2 = make_svm_dataset("w1a-like", seed=3, as_operand=True)
    assert np.array_equal(np.asarray(opS.todense()), As)
    assert np.array_equal(bs, bs2)


# ---------------------------------------------------------------------------
# 2. sparse == dense equivalence, family x variant.
# ---------------------------------------------------------------------------

# iterations=30 with s=8 forces a remainder tail group (30 % 8 != 0);
# the small m/n of the fixture forces same-index collisions inside SA
# groups.
EQUIV_CASES = [
    ("lasso-classical", "lasso", dict(block_size=4, s=1, accelerated=False)),
    ("lasso-accelerated", "lasso", dict(block_size=4, s=1, accelerated=True)),
    ("lasso-sa", "lasso", dict(block_size=4, s=8, accelerated=False)),
    ("lasso-sa-acc", "lasso", dict(block_size=4, s=8, accelerated=True)),
    ("lasso-sa-symmetric", "lasso",
     dict(block_size=4, s=8, accelerated=True, symmetric_gram=True)),
    ("svm-classical", "svm", dict(block_size=2, s=1)),
    ("svm-sa", "svm", dict(block_size=2, s=8)),
    ("ksvm-classical", "ksvm", dict(block_size=2, s=1)),
    ("ksvm-sa", "ksvm", dict(block_size=2, s=8)),
    ("logreg-classical", "logreg", dict(block_size=2, s=1)),
    ("logreg-sa", "logreg", dict(block_size=2, s=8)),
]


def _problem(family, A, b, lam, bs):
    if family == "lasso":
        return LassoProblem(A=A, b=b, lam=lam)
    if family == "svm":
        return SVMProblem(A=A, b=bs, lam=1.0)
    if family == "ksvm":
        return SVMProblem(A=A, b=bs, lam=1.0, kernel="rbf",
                          kernel_params={"gamma": 0.1})
    return LogRegProblem(A=A, b=bs, lam=1e-3)


def _deviation(res_a, res_b):
    o1, o2 = np.asarray(res_a.objective), np.asarray(res_b.objective)
    x1, x2 = np.asarray(res_a.x), np.asarray(res_b.x)
    return max(
        float(np.max(np.abs(o1 - o2) / np.maximum(np.abs(o1), 1e-9))),
        float(np.max(np.abs(x1 - x2)) / max(float(np.max(np.abs(x1))),
                                            1e-9)))


@pytest.mark.parametrize("name,family,cfg_kw", EQUIV_CASES,
                         ids=[c[0] for c in EQUIV_CASES])
def test_sparse_matches_dense_local_f32(sparse_problem_data, name,
                                        family, cfg_kw):
    """In-process f32 sweep (same summands in a different order, so
    roundoff-level deviation only); the 1e-10 acceptance bound runs in
    f64 in the subprocess tier below."""
    A, op, b, lam, bs = sparse_problem_data
    cfg = SolverConfig(iterations=30, **cfg_kw)
    res_d = api.solve(_problem(family, A, b, lam, bs), cfg)
    res_s = api.solve(_problem(family, op, b, lam, bs), cfg)
    dev = _deviation(res_d, res_s)
    assert dev <= 2e-4, (name, dev)
    # the use_pallas contract: the sparse solve surfaces its SpMM path.
    assert res_s.aux.get("spmm_impl") == "ref"
    assert "spmm_impl" not in res_d.aux


def test_sparse_pallas_interpret_solver_parity(sparse_problem_data):
    """The sparse SA-Lasso group product through the Pallas kernel
    (interpret mode, f32) vs the ref path — solver-level parity of the
    fused Gram/projection block, not just the kernel microtest."""
    from repro.kernels import spmm

    A, op, b, lam, bs = sparse_problem_data
    flat = jnp.asarray([3, 9, 9, 17, 20, 44, 2, 8])
    rows_g, vals_g, nnb_g = op.gather_cols(flat)
    Yd = spmm.scatter_dense(rows_g, vals_g, A.shape[0])
    r = jnp.asarray(-b)[:, None]
    D = jnp.concatenate([Yd, r], axis=1)
    ref = spmm.ell_spmm(vals_g, rows_g, nnb_g, D, ell_block=op.ell_block)
    pal = spmm.ell_spmm(vals_g, rows_g, nnb_g, D, ell_block=op.ell_block,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    dense = A[:, np.asarray(flat)].T @ np.asarray(D)
    np.testing.assert_allclose(np.asarray(pal), dense, rtol=1e-3,
                               atol=1e-3)


_F64_PRELUDE = r"""
import dataclasses
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro import api
from repro.api import (LassoProblem, LogRegProblem, SVMProblem,
                       SolverConfig, SparseOperand)

def sparse_matrix(seed, m, n, density=0.3):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n))
    A[rng.random((m, n)) >= density] = 0.0
    for j in np.flatnonzero(~(A != 0).any(axis=0)):
        A[rng.integers(m), j] = 1.0
    return A

m, n = 72, 46
A = sparse_matrix(0, m, n)
rng = np.random.default_rng(1)
xt = np.zeros(n); xt[:6] = rng.standard_normal(6)
b = A @ xt + 0.1 * rng.standard_normal(m)
lam = 0.1 * float(np.abs(A.T @ b).max())
bs = np.sign(A @ rng.standard_normal(n) + 0.1 * rng.standard_normal(m))
bs[bs == 0] = 1.0
op = SparseOperand.from_dense(A)

def problem(family, M):
    if family == "lasso":
        return LassoProblem(A=M, b=b, lam=lam)
    if family == "svm":
        return SVMProblem(A=M, b=bs, lam=1.0)
    if family == "ksvm":
        return SVMProblem(A=M, b=bs, lam=1.0, kernel="rbf",
                          kernel_params={"gamma": 0.1})
    return LogRegProblem(A=M, b=bs, lam=1e-3)

def deviation(ra, rb):
    o1, o2 = np.asarray(ra.objective), np.asarray(rb.objective)
    x1, x2 = np.asarray(ra.x), np.asarray(rb.x)
    return max(
        float(np.max(np.abs(o1 - o2) / np.maximum(np.abs(o1), 1e-9))),
        float(np.max(np.abs(x1 - x2)) / max(float(np.max(np.abs(x1))),
                                            1e-9)))
"""


@pytest.mark.slow
def test_sparse_matches_dense_f64():
    """The acceptance tier: f64 <= 1e-10 per family x variant (incl. SA
    remainder groups, collisions, symmetric-gram packing), plus warm
    starts and the objective diagnostics — in a subprocess per the
    repo's f64 convention."""
    code = _F64_PRELUDE + r"""
CASES = [
    ("lasso", dict(block_size=4, s=1, accelerated=False)),
    ("lasso", dict(block_size=4, s=1, accelerated=True)),
    ("lasso", dict(block_size=4, s=8, accelerated=False)),
    ("lasso", dict(block_size=4, s=8, accelerated=True)),
    ("lasso", dict(block_size=4, s=8, accelerated=True,
                   symmetric_gram=True)),
    ("svm", dict(block_size=2, s=1)),
    ("svm", dict(block_size=2, s=8)),
    ("ksvm", dict(block_size=2, s=1)),
    ("ksvm", dict(block_size=2, s=8)),
    ("logreg", dict(block_size=2, s=1)),
    ("logreg", dict(block_size=2, s=8)),
]
for family, kw in CASES:
    cfg = SolverConfig(iterations=30, dtype=jnp.float64, **kw)
    rd = api.solve(problem(family, A), cfg)
    rs = api.solve(problem(family, op), cfg)
    dev = deviation(rd, rs)
    assert dev <= 1e-10, (family, kw, dev)
    assert rs.aux.get("spmm_impl") == "ref"

# warm starts thread the sparse path identically.
cfg = SolverConfig(block_size=2, s=4, iterations=12, dtype=jnp.float64)
for family in ("lasso", "svm", "ksvm", "logreg"):
    cold = api.solve(problem(family, op), cfg)
    x0 = np.asarray(cold.aux["alpha"]) if family in ("svm", "ksvm") \
        else np.asarray(cold.x)
    rd = api.solve(problem(family, A), cfg, x0=x0)
    rs = api.solve(problem(family, op), cfg, x0=x0)
    assert deviation(rd, rs) <= 1e-10, family

# objective diagnostics accept operands.
from repro.core import (dual_objective, kernel_dual_objective,
                        lasso_objective, logreg_objective,
                        primal_objective)
x = np.random.default_rng(5).standard_normal(n)
alpha = np.random.default_rng(6).uniform(0.0, 1.0, m)
for fn, fam, arg in [(lasso_objective, "lasso", x),
                     (dual_objective, "svm", alpha),
                     (primal_objective, "svm", x),
                     (kernel_dual_objective, "ksvm", alpha),
                     (logreg_objective, "logreg", x)]:
    d = abs(float(fn(problem(fam, A), arg))
            - float(fn(problem(fam, op), arg)))
    assert d < 1e-9, (fn.__name__, d)
print("SPARSE_F64_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "SPARSE_F64_OK" in out.stdout


@pytest.mark.slow
def test_sparse_matches_dense_sharded():
    """f64 <= 1e-10 dense-vs-sparse AND local-vs-sharded through the
    generic driver (8 placeholder devices; the 90/44 shape is not a
    multiple of 8, so the sparse pad/stack path is exercised)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
""" + _F64_PRELUDE + r"""
mesh_d = jax.make_mesh((8,), ("data",))
mesh_m = jax.make_mesh((8,), ("model",))
ms, ns = 90, 44
As = sparse_matrix(3, ms, ns)
rng = np.random.default_rng(4)
xt = np.zeros(ns); xt[:5] = 1.0
b = As @ xt + 0.1 * rng.standard_normal(ms)
lam = 0.1 * float(np.abs(As.T @ b).max())
bs = np.sign(As @ rng.standard_normal(ns) + 0.1 * rng.standard_normal(ms))
bs[bs == 0] = 1.0
ops = SparseOperand.from_dense(As)
cfg = SolverConfig(block_size=2, iterations=22, s=4, dtype=jnp.float64)

cases = [
    (LassoProblem(A=As, b=b, lam=lam), mesh_d),
    (SVMProblem(A=As, b=bs, lam=1.0), mesh_m),
    (SVMProblem(A=As, b=bs, lam=1.0, kernel="rbf",
                kernel_params={"gamma": 0.1}), mesh_m),
    (LogRegProblem(A=As, b=bs, lam=1e-3), mesh_m),
]
for prob, mesh in cases:
    dres = api.solve(prob, cfg, backend="sharded", mesh=mesh)
    sprob = dataclasses.replace(prob, A=ops)
    sres = api.solve(sprob, cfg, backend="sharded", mesh=mesh)
    lres = api.solve(sprob, cfg)
    o1, o2, o3 = (np.asarray(r.objective) for r in (dres, sres, lres))
    assert np.max(np.abs(o1 - o2) / np.maximum(np.abs(o1), 1e-9)) < 1e-10
    assert np.max(np.abs(o3 - o2) / np.maximum(np.abs(o3), 1e-9)) < 1e-10
    assert np.max(np.abs(np.asarray(dres.x) - np.asarray(sres.x))) < 1e-10
print("SPARSE_SHARDED_OK")
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "SPARSE_SHARDED_OK" in out.stdout


# ---------------------------------------------------------------------------
# 3. bugfix regressions.
# ---------------------------------------------------------------------------

def test_margin_larger_means_more_separable():
    """Regression: larger ``margin`` used to INJECT more label noise
    (sign(scores + margin*0.1*noise)); now it divides the noise."""
    from repro.data.sparse import make_svm_dataset

    # margin -> inf recovers the clean planted labels (noise / margin).
    _, b_clean = make_svm_dataset("w1a-like", seed=0, margin=1e12)

    def noise_rate(margin):
        _, b = make_svm_dataset("w1a-like", seed=0, margin=margin)
        return float(np.mean(b != b_clean))

    r_tight, r_default, r_wide = (noise_rate(m) for m in (0.25, 1.0, 8.0))
    assert r_wide < r_default < r_tight
    with pytest.raises(ValueError, match="margin"):
        make_svm_dataset("w1a-like", margin=0.0)


def test_margin_default_bit_identical():
    """margin=1 must reproduce the historical (pre-fix) datasets
    bit-for-bit: sign(scores + (0.1/1)*noise) == the old
    sign(scores + 1*0.1*noise)."""
    from repro.data.sparse import SYNTHETIC_DATASETS, make_svm_dataset

    spec = SYNTHETIC_DATASETS["w1a-like"]
    rng = np.random.default_rng(7)
    A_old = rng.standard_normal((spec.m, spec.n)).astype(np.float32)
    mask = rng.random((spec.m, spec.n)) < spec.density
    A_old = A_old * mask
    empty = ~mask.any(axis=0)
    if empty.any():
        rows = rng.integers(0, spec.m, size=int(empty.sum()))
        A_old[rows, np.flatnonzero(empty)] = \
            rng.standard_normal(int(empty.sum())).astype(np.float32)
    w = rng.standard_normal(spec.n).astype(np.float32)
    w /= np.linalg.norm(w)
    scores = A_old @ w
    b_old = np.sign(scores + 1.0 * 0.1 * rng.standard_normal(spec.m))
    b_old[b_old == 0] = 1.0
    A_new, b_new = make_svm_dataset("w1a-like", seed=7)
    assert np.array_equal(A_old, A_new)
    assert np.array_equal(b_old.astype(np.float32), b_new)


def test_best_s_logreg_branch_and_unknown_kind():
    """Regression: best_s silently modeled kind="logreg" (and any other
    non-lasso kind) with the SVM formula."""
    from repro.core.cost_model import (Machine, ProblemDims, best_s,
                                      logreg_speedup, svm_speedup)

    dims = ProblemDims(m=100_000, n=10_000, f=0.01)
    machine = Machine.cray_xc30()
    s_star, sp = best_s(dims, H=10_000, mu=4, P=1024, machine=machine,
                        kind="logreg")
    assert sp == pytest.approx(
        logreg_speedup(dims, 10_000, s_star, 1024, machine, 4))
    svm_sp = svm_speedup(dims, 10_000, s_star, 1024, machine, 4)
    assert sp != pytest.approx(svm_sp)
    with pytest.raises(ValueError, match="unknown kind"):
        best_s(dims, H=100, mu=1, P=64, machine=machine, kind="ridge")


def test_ksvm_cost_hook_threads_kernel():
    """Regression: the ksvm registry cost hook hardcoded kernel="rbf",
    so poly/linear-kernelized problems reported rbf eval flops."""
    from repro.core.cost_model import ProblemDims, svm_costs
    from repro.core.types import FAMILIES
    import repro.core.api  # noqa: F401  (populates FAMILIES)

    dims = ProblemDims(m=100_000, n=10_000, f=0.01)
    hook = FAMILIES["ksvm"].costs
    assert hook(dims, 512, 4, 8, 128, kernel="poly") \
        == svm_costs(dims, 512, 8, 128, mu=4, kernel="poly")
    assert hook(dims, 512, 4, 8, 128, kernel="poly")["F"] \
        != hook(dims, 512, 4, 8, 128, kernel="rbf")["F"]
    # default (no kernel passed) stays the family's bench default, rbf.
    assert hook(dims, 512, 4, 8, 128) \
        == svm_costs(dims, 512, 8, 128, mu=4, kernel="rbf")
    # registry-wide: every family's hook accepts the kernel argument.
    for fam in FAMILIES.values():
        c = fam.costs(dims, 512, 2, 4, 128, kernel="linear")
        assert {"F", "L", "W", "M"} <= set(c), fam.name