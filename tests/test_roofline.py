import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (HW_V5E, collective_bytes_from_hlo,
                                     collective_stats_from_hlo,
                                     cost_analysis_dict, model_flops,
                                     roofline_terms, two_point_fit)

SAMPLE_HLO = """
ENTRY %main {
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[64,64]{1,0} all-gather(%p0), dimensions={0}
  %rs = f32[8,8]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = f32[16]{0} all-to-all(%rs), dimensions={0}
  %cp = s32[4,4]{1,0} collective-permute(%a2a), source_target_pairs={{0,1}}
  %add = f32[64,64]{1,0} add(%ag, %ag)
  ROOT %out = f32[64,64]{1,0} copy(%add)
}
"""


def test_collective_parser_counts_each_type():
    out = collective_bytes_from_hlo(SAMPLE_HLO)
    assert out["all-reduce"] == 128 * 256 * 2
    assert out["all-gather"] == 64 * 64 * 4
    assert out["reduce-scatter"] == 8 * 8 * 4
    assert out["all-to-all"] == 16 * 4
    assert out["collective-permute"] == 4 * 4 * 4
    assert out["total"] == sum(out[k] for k in (
        "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute"))


def test_collective_parser_ignores_non_collectives():
    out = collective_bytes_from_hlo("%x = f32[8]{0} add(%a, %b)")
    assert out["total"] == 0


def test_collective_parser_tuple_shapes():
    hlo = "%t = (f32[8]{0}, f32[8]{0}) all-gather(%a, %b)"
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 2 * 8 * 4


# verbatim shape of a real jax 0.4.x XLA-CPU post-SPMD dump (4 forced
# host devices, psum of an (8, 8) f32 inside shard_map): ROOT-prefixed
# op, typed operands, channel/replica metadata trailing the call.
REAL_CPU_HLO = """\
HloModule jit_fn, entry_computation_layout={(f32[8,8]{1,0})->f32[8,8]{1,0}}

%region_0.4 (Arg_0.5: f32[], Arg_1.6: f32[]) -> f32[] {
  %Arg_0.5 = f32[] parameter(0)
  %Arg_1.6 = f32[] parameter(1)
  ROOT %add.7 = f32[] add(f32[] %Arg_0.5, f32[] %Arg_1.6)
}

ENTRY %main.9 (Arg_0.1: f32[8,8]) -> f32[8,8] {
  %Arg_0.1 = f32[8,8]{1,0} parameter(0), metadata={op_name="x"}
  %dot.1 = f32[8,8]{1,0} dot(f32[8,8]{1,0} %Arg_0.1, f32[8,8]{1,0} %Arg_0.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %all-reduce.1 = f32[8,8]{1,0} all-reduce(f32[8,8]{1,0} %dot.1), channel_id=1, replica_groups={{0,1,2,3}}, use_global_device_ids=true, to_apply=%region_0.4, metadata={op_name="jit(fn)/jit(main)/psum"}
}
"""


def test_collective_parser_real_cpu_hlo_root_prefix():
    """The historical parser's regex missed ROOT-prefixed collectives
    entirely — which is exactly how XLA prints an all-reduce that is the
    computation's result. Pin the real-dump form."""
    stats = collective_stats_from_hlo(REAL_CPU_HLO)
    assert stats.counts["all-reduce"] == 1
    # operand shape f32[8,8] inside all-reduce(...) must NOT be summed:
    # only the result buffer travels.
    assert stats.bytes["all-reduce"] == 8 * 8 * 4
    assert stats.total_count == 1


def test_collective_parser_start_done_counted_once():
    """Async collectives appear as a -start/-done pair whose -start
    result tuple aliases the operand buffers in its first half; the op
    is ONE transfer of the result half's bytes."""
    hlo = """\
  %ar-start = (f32[128,64]{1,0}, f32[128,64]{1,0}) all-reduce-start(f32[128,64]{1,0} %p0), replica_groups={{0,1}}, to_apply=%sum
  %ar-done = f32[128,64]{1,0} all-reduce-done((f32[128,64]{1,0}, f32[128,64]{1,0}) %ar-start)
"""
    stats = collective_stats_from_hlo(hlo)
    assert stats.counts["all-reduce"] == 1
    assert stats.bytes["all-reduce"] == 128 * 64 * 4


def test_collective_parser_typed_counts():
    """CollectiveStats keeps counts and bytes in separate typed fields;
    the legacy dict view mirrors them under \"counts\"/\"total\"."""
    stats = collective_stats_from_hlo(SAMPLE_HLO)
    assert stats.counts == {"all-reduce": 1, "all-gather": 1,
                            "reduce-scatter": 1, "all-to-all": 1,
                            "collective-permute": 1}
    legacy = collective_bytes_from_hlo(SAMPLE_HLO)
    assert legacy["counts"] == dict(stats.counts)
    assert legacy["total"] == stats.total_bytes


def test_two_point_fit_exact_linear():
    # cost(n) = 10 + 3n
    assert two_point_fit(13, 16, 1, 2, 32) == pytest.approx(10 + 3 * 32)


def test_roofline_terms_classification():
    t = roofline_terms(flops_per_dev=1e15, bytes_per_dev=1e9,
                       coll_bytes_per_dev=1e9)
    assert t["dominant"] == "compute"
    t = roofline_terms(flops_per_dev=1e9, bytes_per_dev=1e13,
                       coll_bytes_per_dev=1e9)
    assert t["dominant"] == "memory"
    t = roofline_terms(flops_per_dev=1e9, bytes_per_dev=1e9,
                       coll_bytes_per_dev=1e13)
    assert t["dominant"] == "collective"
    assert 0 < t["roofline_fraction"] <= 1.0


def test_model_flops_conventions():
    assert model_flops(1e9, "train", tokens=1000) == 6e12
    assert model_flops(1e9, "prefill", tokens=1000) == 2e12
    assert model_flops(1e9, "decode", tokens=0, batch=64) == 2e9 * 64


def test_xla_flops_convention_is_2mnk():
    """Pin the XLA cost-model convention the roofline relies on:
    cost_analysis reports 2*M*N*K FLOPs for a dot (per device)."""
    a = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    c = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    flops = cost_analysis_dict(c)["flops"]
    assert flops == pytest.approx(2 * 256 * 128 * 64, rel=0.05)


def test_xla_scan_body_counted_once():
    """Pin the scan-counting behaviour that motivates the two-point fit."""
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    fl8 = cost_analysis_dict(jax.jit(f).lower(x).compile())["flops"]

    def f1(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=1)
        return out

    fl1 = cost_analysis_dict(jax.jit(f1).lower(x).compile())["flops"]
    assert fl8 == pytest.approx(fl1, rel=0.01)
