"""Logistic regression (BCD + SA-BCD, after arXiv:2011.08281): SA
equivalence across (s, mu, lam), exact objective tracking from the
maintained margins, remainder/collision handling, f64 machine-epsilon
equivalence — the same hardening tier every other family gets."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (LogRegProblem, SolverConfig, bcd_logreg,
                        logreg_objective, sa_bcd_logreg, solve_logreg)


@pytest.fixture(scope="module")
def logreg_data(svm_data):
    """Planted separable-ish labels (the SVM fixture): logreg's
    SGD-style steps need signal to descend."""
    return svm_data


def test_objective_decreases(logreg_data):
    A, b = logreg_data
    prob = LogRegProblem(A=A, b=b, lam=1e-3)
    res = bcd_logreg(prob, SolverConfig(block_size=4, iterations=200))
    obj = np.asarray(res.objective)
    assert obj[0] < np.log(2.0)          # already below the w=0 value
    assert obj[-1] < 0.75 * float(np.log(2.0))
    assert obj[-1] < obj[0]


def test_tracked_objective_matches_direct(logreg_data):
    """The incrementally maintained (margins, ||w||^2) pair reproduces
    the directly evaluated objective at the final iterate."""
    A, b = logreg_data
    prob = LogRegProblem(A=A, b=b, lam=1e-2)
    res = bcd_logreg(prob, SolverConfig(block_size=4, iterations=64))
    direct = float(logreg_objective(prob, res.x))
    assert abs(float(res.objective[-1]) - direct) < 1e-5 * max(direct, 1.0)
    # margins aux is exactly A @ w
    np.testing.assert_allclose(np.asarray(res.aux["margins"]),
                               np.asarray(prob.A) @ np.asarray(res.x),
                               atol=1e-4)


_BASE_CACHE = {}


def _base(logreg_data, lam, mu, H):
    key = (lam, mu, H)
    if key not in _BASE_CACHE:
        A, b = logreg_data
        prob = LogRegProblem(A=A, b=b, lam=lam)
        _BASE_CACHE[key] = bcd_logreg(
            prob, SolverConfig(block_size=mu, iterations=H))
    return _BASE_CACHE[key]


@pytest.mark.parametrize("lam", [0.0, 1e-2])
@pytest.mark.parametrize("mu", [1, 2, 4])
@pytest.mark.parametrize("s", [1, 2, 4, 8])
def test_sa_trajectory_matches(logreg_data, lam, mu, s):
    """SA-BCD == BCD iterates across the full (s, mu, lam) sweep —
    including lam > 0, which exercises the d = 1 - eta*lam decay
    recurrence in the deferred updates."""
    A, b = logreg_data
    prob = LogRegProblem(A=A, b=b, lam=lam)
    H = 32
    base = _base(logreg_data, lam, mu, H)
    sa = sa_bcd_logreg(prob, SolverConfig(block_size=mu, iterations=H, s=s))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    assert o1.shape == o2.shape == (H,)
    np.testing.assert_allclose(o2, o1, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(sa.aux["margins"]),
                               np.asarray(base.aux["margins"]), atol=1e-4)


def test_sa_remainder_iterations(logreg_data):
    """iterations % s != 0: floor(H/s) groups + one tail group, exactly H
    inner iterations, trajectory matches inner-iteration-for-inner-
    iteration (H < s degenerates to tail-only)."""
    A, b = logreg_data
    prob = LogRegProblem(A=A, b=b, lam=1e-3)
    for H, s in ((10, 4), (3, 8)):
        base = bcd_logreg(prob, SolverConfig(block_size=2, iterations=H))
        sa = sa_bcd_logreg(prob, SolverConfig(block_size=2, iterations=H,
                                              s=s))
        o2 = np.asarray(sa.objective)
        assert o2.shape == (H,)
        np.testing.assert_allclose(o2, np.asarray(base.objective),
                                   rtol=1e-4, atol=1e-5)


def test_sa_collisions_within_group():
    """Tiny m forces repeated row indices across the s blocks of one
    outer group: the single replicated margin copy must keep SA exact."""
    import jax
    from repro.core.linalg import sample_block

    rng = np.random.default_rng(5)
    m, n = 10, 24
    A = rng.standard_normal((m, n)).astype(np.float32)
    wt = rng.standard_normal(n).astype(np.float32)
    b = np.sign(A @ wt).astype(np.float32)
    b[b == 0] = 1.0
    s, mu, H = 8, 2, 16
    key = jax.random.key(0)
    idxs = np.asarray(jax.vmap(
        lambda h: sample_block(jax.random.fold_in(key, h), m, mu))(
        np.arange(1, s + 1)))
    assert len(np.unique(idxs)) < idxs.size
    prob = LogRegProblem(A=A, b=b, lam=1e-2)
    base = bcd_logreg(prob, SolverConfig(block_size=mu, iterations=H))
    sa = sa_bcd_logreg(prob, SolverConfig(block_size=mu, iterations=H, s=s))
    np.testing.assert_allclose(np.asarray(sa.objective),
                               np.asarray(base.objective),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sa.x), np.asarray(base.x),
                               atol=2e-5)


def test_dispatch_solve_logreg(logreg_data):
    """solve_logreg routes on cfg.s; cfg.accelerated is ignored (no
    accelerated variant, as for SVM)."""
    A, b = logreg_data
    prob = LogRegProblem(A=A, b=b, lam=1e-3)
    for s in (1, 4):
        for accelerated in (False, True):
            cfg = SolverConfig(block_size=2, iterations=12, s=s,
                               accelerated=accelerated)
            res = solve_logreg(prob, cfg)
            assert np.asarray(res.objective).shape == (12,)


@pytest.mark.slow
def test_sa_final_error_f64():
    """SA-BCD == BCD at machine-epsilon scale in f64 (Table III analogue
    for logistic regression; acceptance bound 1e-10)."""
    code = r"""
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core import LogRegProblem, SolverConfig, bcd_logreg, \
    sa_bcd_logreg
rng = np.random.default_rng(7)
m, n = 96, 40
A = rng.standard_normal((m, n))
w = rng.standard_normal(n)
b = np.sign(A @ w + 0.1 * rng.standard_normal(m)); b[b == 0] = 1.0
worst = 0.0
for lam in (0.0, 1e-2):
    prob = LogRegProblem(A=A, b=b, lam=lam)
    for mu in (1, 4):
        base = bcd_logreg(prob, SolverConfig(block_size=mu, iterations=64,
                                             dtype=jnp.float64))
        for s in (8, 12):
            sa = sa_bcd_logreg(prob, SolverConfig(
                block_size=mu, iterations=64, s=s, dtype=jnp.float64))
            o1 = np.asarray(base.objective); o2 = np.asarray(sa.objective)
            dev = float(np.max(np.abs(o1 - o2)
                               / np.maximum(np.abs(o1), 1e-30)))
            xdev = float(np.max(np.abs(np.asarray(base.x)
                                       - np.asarray(sa.x))))
            worst = max(worst, dev, xdev)
print("DEV", worst)
assert worst < 1e-10, worst
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    dev = float(out.stdout.split("DEV")[1].strip())
    assert dev < 1e-10
