import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.asarray(2.5)}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, extra = restore_checkpoint(str(tmp_path), tree_like=tree)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_specs_roundtrip_and_mesh_placement(tmp_path):
    tree = _tree()
    specs = {"a": P(None, None), "nested": {"b": P(None), "c": P()}}
    save_checkpoint(str(tmp_path), 1, tree, specs=specs)
    mesh = jax.make_mesh((1,), ("data",))
    restored, _ = restore_checkpoint(str(tmp_path), tree_like=tree,
                                     mesh=mesh)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = _tree()
    mgr.save(5, tree)
    restored, _ = mgr.restore_latest(tree_like=tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_atomicity_no_partial_dirs(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    entries = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert entries == []


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path))


def test_partial_step_dir_skipped(tmp_path):
    """A ``step_<N>`` directory without a manifest (a crash mid-copy, or
    a foreign tool's leftovers) must be invisible to latest_step /
    restore-latest — they land on the newest COMPLETE checkpoint."""
    tree = _tree()
    save_checkpoint(str(tmp_path), 3, tree, extra={"iteration": 3})
    os.makedirs(tmp_path / "step_00000009")      # partial: no manifest
    assert latest_step(str(tmp_path)) == 3
    restored, extra = restore_checkpoint(str(tmp_path), tree_like=tree)
    assert extra["iteration"] == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_only_partial_dirs_means_no_checkpoint(tmp_path):
    os.makedirs(tmp_path / "step_00000001")
    assert latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path))


def test_close_joins_outstanding_async_save(tmp_path, monkeypatch):
    """Regression for the async-save thread lifecycle: close() (and the
    context-manager exit) must JOIN the in-flight save, not abandon a
    daemon thread mid-``np.savez``. A deliberately slowed save is still
    fully on disk after the with-block."""
    import threading
    import time as _time

    from repro.checkpoint import ckpt as ckpt_mod

    real_save = ckpt_mod.save_checkpoint
    started = threading.Event()

    def slow_save(*args, **kwargs):
        started.set()
        _time.sleep(0.3)
        return real_save(*args, **kwargs)

    monkeypatch.setattr(ckpt_mod, "save_checkpoint", slow_save)
    tree = _tree()
    with CheckpointManager(str(tmp_path), async_save=True) as mgr:
        mgr.save(4, tree, extra={"iteration": 4})
        assert started.wait(timeout=5.0)
        # exiting the with-block blocks on the slow thread
    assert mgr._thread is None
    assert latest_step(str(tmp_path)) == 4
    restored, extra = restore_checkpoint(str(tmp_path), tree_like=tree)
    assert extra["iteration"] == 4
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    mgr.close()                                   # idempotent


def test_sync_manager_needs_no_close(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    assert mgr._thread is None
    assert latest_step(str(tmp_path)) == 1
