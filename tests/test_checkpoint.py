import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": jnp.asarray(2.5)}}


def test_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, extra = restore_checkpoint(str(tmp_path), tree_like=tree)
    assert extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_specs_roundtrip_and_mesh_placement(tmp_path):
    tree = _tree()
    specs = {"a": P(None, None), "nested": {"b": P(None), "c": P()}}
    save_checkpoint(str(tmp_path), 1, tree, specs=specs)
    mesh = jax.make_mesh((1,), ("data",))
    restored, _ = restore_checkpoint(str(tmp_path), tree_like=tree,
                                     mesh=mesh)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    tree = _tree()
    mgr.save(5, tree)
    restored, _ = mgr.restore_latest(tree_like=tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_atomicity_no_partial_dirs(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    entries = [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]
    assert entries == []


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path))
