import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.prox import (elastic_net_prox, group_soft_threshold,
                             lasso_objective, make_prox, reg_value,
                             soft_threshold)

finite_floats = st.floats(-1e6, 1e6, allow_nan=False, width=32)


@given(st.lists(finite_floats, min_size=1, max_size=32),
       st.floats(0, 1e3, width=32))
@settings(max_examples=60, deadline=None)
def test_soft_threshold_properties(vals, alpha):
    v = jnp.asarray(vals, jnp.float32)
    out = np.asarray(soft_threshold(v, alpha))
    # shrinks toward zero, never crosses it, shrinks by at most alpha
    assert np.all(np.abs(out) <= np.abs(np.asarray(v)) + 1e-6)
    assert np.all(out * np.asarray(v) >= -1e-6)
    assert np.all(np.abs(np.asarray(v)) - np.abs(out)
                  <= alpha + 1e-4 + 1e-5 * np.abs(np.asarray(v)))
    # exact zero inside the threshold band
    band = np.abs(np.asarray(v)) <= alpha
    assert np.all(out[band] == 0.0)


def test_soft_threshold_known_values():
    v = jnp.asarray([3.0, -3.0, 0.5, -0.5, 0.0])
    out = np.asarray(soft_threshold(v, 1.0))
    np.testing.assert_allclose(out, [2.0, -2.0, 0.0, 0.0, 0.0])


@given(finite_floats, st.floats(1e-3, 10.0), st.floats(0.0, 5.0),
       st.floats(0.0, 5.0))
@settings(max_examples=60, deadline=None)
def test_elastic_net_prox_is_scaled_shrinkage(v, eta, l1, l2):
    out = float(elastic_net_prox(jnp.float32(v), eta, l1, l2))
    expected = float(soft_threshold(jnp.float32(v), eta * l1)) \
        / (1.0 + 2.0 * eta * l2)
    assert abs(out - expected) <= 1e-5 * max(1.0, abs(expected))


def test_group_soft_threshold_zeroes_small_groups():
    v = jnp.asarray([0.1, -0.1, 0.05])
    assert np.all(np.asarray(group_soft_threshold(v, 10.0)) == 0)
    v2 = jnp.asarray([3.0, 4.0])            # norm 5
    out = np.asarray(group_soft_threshold(v2, 1.0))
    np.testing.assert_allclose(out, np.asarray(v2) * (1 - 1.0 / 5.0),
                               rtol=1e-6)


def test_make_prox_dispatch():
    p_l1 = make_prox(1.0)
    p_en = make_prox(1.0, l2=0.5)
    p_gl = make_prox(1.0, groups=np.array([0, 0, 1, 1]))
    v = jnp.asarray([2.0, -2.0])
    assert np.allclose(np.asarray(p_l1(v, 0.5)), [1.5, -1.5])
    assert not np.allclose(np.asarray(p_en(v, 0.5)),
                           np.asarray(p_l1(v, 0.5)))
    out = p_gl(jnp.asarray([3.0, 4.0]), 1.0)
    assert out.shape == (2,)


def test_objective_matches_manual(lasso_data):
    A, b, lam = lasso_data
    x = np.zeros(A.shape[1], dtype=np.float32)
    x[0] = 1.0
    r = A @ x - b
    manual = 0.5 * np.sum(r ** 2) + lam * np.sum(np.abs(x))
    got = float(lasso_objective(jnp.asarray(r), jnp.asarray(x), lam))
    assert abs(got - manual) / manual < 1e-5
