import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LassoProblem, SolverConfig, acc_bcd_lasso,
                        bcd_lasso, solve_lasso)


def _ista_reference(A, b, lam, iters=4000):
    """Plain ISTA as an independent oracle for the lasso optimum."""
    L = np.linalg.norm(A, 2) ** 2
    x = np.zeros(A.shape[1], dtype=np.float64)
    Af = A.astype(np.float64)
    bf = b.astype(np.float64)
    for _ in range(iters):
        g = Af.T @ (Af @ x - bf)
        v = x - g / L
        x = np.sign(v) * np.maximum(np.abs(v) - lam / L, 0)
    return x, 0.5 * np.sum((Af @ x - bf) ** 2) + lam * np.sum(np.abs(x))


def test_bcd_converges_to_ista_optimum(lasso_data):
    A, b, lam = lasso_data
    x_star, f_star = _ista_reference(A, b, lam)
    prob = LassoProblem(A=A, b=b, lam=lam)
    res = acc_bcd_lasso(prob, SolverConfig(block_size=8, iterations=1500))
    f_final = float(res.objective[-1])
    assert f_final <= f_star * 1.02, (f_final, f_star)


def test_objective_monotone_nonacc(lasso_data):
    """Non-accelerated BCD is a descent method: objective never increases
    (accelerated variants may oscillate — only tested for convergence)."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    res = bcd_lasso(prob, SolverConfig(block_size=4, iterations=200))
    obj = np.asarray(res.objective)
    assert np.all(np.diff(obj) <= 1e-3)


def test_solution_is_sparse(lasso_data):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=5 * lam)
    res = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=800))
    x = np.asarray(res.x)
    assert np.sum(np.abs(x) > 1e-6) < A.shape[1] * 0.5


def test_residual_consistency(lasso_data):
    """aux residual must equal A x - b for the returned x."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    res = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=100))
    np.testing.assert_allclose(np.asarray(res.aux["residual"]),
                               A @ np.asarray(res.x) - b, atol=2e-3)


def test_dispatch_solve_lasso(lasso_data):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    for acc in (True, False):
        for s in (1, 8):
            cfg = SolverConfig(block_size=4, iterations=32, s=s,
                               accelerated=acc)
            res = solve_lasso(prob, cfg)
            assert res.objective.shape == (32,)


def test_iterations_need_not_divide_s():
    """iterations % s != 0 is now a supported configuration (the SA
    solvers run a remainder tail group): ceil-division outer count, and
    only genuinely invalid configs raise."""
    cfg = SolverConfig(iterations=10, s=4)
    assert cfg.outer_iterations == 3
    with pytest.raises(ValueError):
        SolverConfig(iterations=0)
    with pytest.raises(ValueError):
        SolverConfig(s=0)
