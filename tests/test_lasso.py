import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LassoProblem, SolverConfig, acc_bcd_lasso,
                        bcd_lasso, solve_lasso)


def _ista_reference(A, b, lam, iters=4000):
    """Plain ISTA as an independent oracle for the lasso optimum."""
    L = np.linalg.norm(A, 2) ** 2
    x = np.zeros(A.shape[1], dtype=np.float64)
    Af = A.astype(np.float64)
    bf = b.astype(np.float64)
    for _ in range(iters):
        g = Af.T @ (Af @ x - bf)
        v = x - g / L
        x = np.sign(v) * np.maximum(np.abs(v) - lam / L, 0)
    return x, 0.5 * np.sum((Af @ x - bf) ** 2) + lam * np.sum(np.abs(x))


def test_bcd_converges_to_ista_optimum(lasso_data):
    A, b, lam = lasso_data
    x_star, f_star = _ista_reference(A, b, lam)
    prob = LassoProblem(A=A, b=b, lam=lam)
    res = acc_bcd_lasso(prob, SolverConfig(block_size=8, iterations=1500))
    f_final = float(res.objective[-1])
    assert f_final <= f_star * 1.02, (f_final, f_star)


def test_objective_monotone_nonacc(lasso_data):
    """Non-accelerated BCD is a descent method: objective never increases
    (accelerated variants may oscillate — only tested for convergence)."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    res = bcd_lasso(prob, SolverConfig(block_size=4, iterations=200))
    obj = np.asarray(res.objective)
    assert np.all(np.diff(obj) <= 1e-3)


def test_solution_is_sparse(lasso_data):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=5 * lam)
    res = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=800))
    x = np.asarray(res.x)
    assert np.sum(np.abs(x) > 1e-6) < A.shape[1] * 0.5


def test_residual_consistency(lasso_data):
    """aux residual must equal A x - b for the returned x."""
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    res = acc_bcd_lasso(prob, SolverConfig(block_size=4, iterations=100))
    np.testing.assert_allclose(np.asarray(res.aux["residual"]),
                               A @ np.asarray(res.x) - b, atol=2e-3)


def test_dispatch_solve_lasso(lasso_data):
    A, b, lam = lasso_data
    prob = LassoProblem(A=A, b=b, lam=lam)
    for acc in (True, False):
        for s in (1, 8):
            cfg = SolverConfig(block_size=4, iterations=32, s=s,
                               accelerated=acc)
            res = solve_lasso(prob, cfg)
            assert res.objective.shape == (32,)


def test_iterations_need_not_divide_s():
    """iterations % s != 0 is now a supported configuration (the SA
    solvers run a remainder tail group): ceil-division outer count, and
    only genuinely invalid configs raise."""
    cfg = SolverConfig(iterations=10, s=4)
    assert cfg.outer_iterations == 3
    with pytest.raises(ValueError):
        SolverConfig(iterations=0)
    with pytest.raises(ValueError):
        SolverConfig(s=0)


# ---------------------------------------------------------------------------
# Regression: all-zero sampled column blocks must not poison x with NaN.
# ---------------------------------------------------------------------------

def _zero_column_problem():
    """A small dense problem with a planted all-zero column. The
    synthetic generators guard empty columns, but user-supplied data
    has no such guarantee — one unlucky draw of the zero column used to
    give power_iteration_max_eig(G) == 0, eta = 1/0 = inf, and
    inf * 0 = NaN forever after."""
    rng = np.random.default_rng(11)
    m, n = 64, 6
    A = rng.standard_normal((m, n)).astype(np.float32)
    A[:, 4] = 0.0
    x_true = np.zeros(n, np.float32)
    x_true[:2] = [1.5, -2.0]
    b = (A @ x_true + 0.05 * rng.standard_normal(m)).astype(np.float32)
    lam = 0.05 * float(np.abs(A.T @ b).max())
    return A, b, lam


def _assert_zero_block_draw_hits(n, mu, H, seed=0):
    """The regression is only exercised if the shared index stream
    actually samples the planted zero column — verify it does."""
    import jax
    from repro.core.linalg import sample_block

    key = jax.random.key(seed)
    draws = np.asarray(jax.vmap(
        lambda h: sample_block(jax.random.fold_in(key, h), n, mu))(
        np.arange(1, H + 1)))
    assert (draws == 4).any(), "seed never samples the zero column"


@pytest.mark.parametrize("accelerated", [False, True])
@pytest.mark.parametrize("s", [1, 4])
def test_zero_column_block_stays_finite(accelerated, s):
    """Regression (NaN step size on zero Gram blocks): a sampled
    all-zero column block must be a no-op, not a NaN factory — across
    classical and SA, accelerated and not."""
    from repro.core import sa_acc_bcd_lasso, sa_bcd_lasso

    A, b, lam = _zero_column_problem()
    prob = LassoProblem(A=A, b=b, lam=lam)
    H, mu = 48, 1
    _assert_zero_block_draw_hits(A.shape[1], mu, H)
    cfg = SolverConfig(block_size=mu, iterations=H, s=s,
                       accelerated=accelerated)
    if s == 1:
        res = (acc_bcd_lasso if accelerated else bcd_lasso)(prob, cfg)
    else:
        res = (sa_acc_bcd_lasso if accelerated else sa_bcd_lasso)(prob,
                                                                  cfg)
    x = np.asarray(res.x)
    obj = np.asarray(res.objective)
    assert np.isfinite(x).all(), x
    assert np.isfinite(obj).all(), obj
    assert x[4] == 0.0                      # the zero column stays put
    assert obj[-1] < obj[0]                 # and the solve still works


def test_zero_block_sa_inner_kernel_parity():
    """The Pallas sa_inner kernel applies the same eigenvalue floor as
    the jnp reference: a fully-zero Gram block yields finite, matching
    (and zero) updates on both paths."""
    import jax
    from repro.kernels.sa_inner.ops import sa_inner_loop
    from repro.kernels.sa_inner.ref import sa_inner_ref

    s, mu = 4, 2
    key = jax.random.key(5)
    G0 = jax.random.normal(key, (32, s * mu))
    G = (G0.T @ G0).at[2 * mu:3 * mu, :].set(0.0).at[:, 2 * mu:3 * mu] \
        .set(0.0)                           # block j=2 is all-zero
    yp = jax.random.normal(jax.random.fold_in(key, 1), (s, mu))
    yp = yp.at[2].set(0.0)                  # its projections are 0 too
    zp = jax.random.normal(jax.random.fold_in(key, 2), (s, mu))
    zp = zp.at[2].set(0.0)
    zv = jnp.zeros((s, mu))
    idx = jnp.arange(s * mu).reshape(s, mu)
    th = jnp.linspace(0.5, 0.1, s)
    coefU = (1.0 - 8 * th) / (th * th)
    dz_ref, e_ref = sa_inner_ref(G, yp, zp, zv, idx, th, coefU, 8.0, 0.3)
    dz_pal, e_pal = sa_inner_loop(G, yp, zp, zv, idx, th, coefU, q=8.0,
                                  lam1=0.3, interpret=True)
    assert np.isfinite(np.asarray(dz_ref)).all()
    assert np.isfinite(np.asarray(dz_pal)).all()
    np.testing.assert_array_equal(np.asarray(dz_ref[2]), 0.0)
    np.testing.assert_array_equal(np.asarray(dz_pal[2]), 0.0)
    np.testing.assert_allclose(np.asarray(dz_pal), np.asarray(dz_ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Regression: group lasso must reject configurations it would silently
# mis-solve (DESIGN.md contract: contiguous, equal-sized mu-blocks).
# ---------------------------------------------------------------------------

def _group_problem(n, mu, rng_seed=3):
    rng = np.random.default_rng(rng_seed)
    m = 48
    A = rng.standard_normal((m, n)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    groups = np.repeat(np.arange(n // mu), mu)
    return A, b, groups


def test_group_lasso_rejects_indivisible_n():
    """Regression: with mu not dividing n, n_groups = n // mu silently
    dropped the trailing n % mu coordinates from the sampler — they
    were never updated. Now a hard ValueError."""
    A, b, _ = _group_problem(12, 4)
    groups = np.repeat(np.arange(3), 4)     # valid ids, but n=12, mu=5
    prob = LassoProblem(A=A, b=b, lam=0.1, groups=groups)
    with pytest.raises(ValueError, match="trailing"):
        bcd_lasso(prob, SolverConfig(block_size=5, iterations=4))


def test_group_lasso_rejects_non_contiguous_groups():
    """Regression: nothing validated that the groups array actually is
    contiguous mu-sized blocks; a permuted labeling solved a DIFFERENT
    problem (the block prox shrank coordinate sets that were not the
    declared groups) without any error."""
    A, b, groups = _group_problem(12, 4)
    rng = np.random.default_rng(0)
    shuffled = rng.permutation(groups)
    assert not np.array_equal(shuffled, groups)
    prob = LassoProblem(A=A, b=b, lam=0.1, groups=shuffled)
    with pytest.raises(ValueError, match="contiguous"):
        bcd_lasso(prob, SolverConfig(block_size=4, iterations=4))
    # wrong group size relative to block_size is the same violation
    prob2 = LassoProblem(A=A, b=b, lam=0.1,
                         groups=np.repeat(np.arange(6), 2))
    with pytest.raises(ValueError, match="contiguous"):
        bcd_lasso(prob2, SolverConfig(block_size=4, iterations=4))


def test_group_lasso_valid_groups_still_solve():
    """The contract check must not reject the documented valid form."""
    A, b, groups = _group_problem(12, 4)
    prob = LassoProblem(A=A, b=b, lam=0.1, groups=groups)
    res = bcd_lasso(prob, SolverConfig(block_size=4, iterations=16))
    assert np.isfinite(np.asarray(res.objective)).all()


def test_group_lasso_accepts_relabeled_contiguous_groups():
    """The contract is contiguous mu-sized blocks with distinct ids —
    NOT ascending ids: [1,1,0,0]-style labelings solved correctly
    before validation existed and must keep working."""
    A, b, _ = _group_problem(12, 4)
    relabeled = np.array([5, 5, 5, 5, 0, 0, 0, 0, 2, 2, 2, 2])
    prob = LassoProblem(A=A, b=b, lam=0.1, groups=relabeled)
    res = bcd_lasso(prob, SolverConfig(block_size=4, iterations=8))
    assert np.isfinite(np.asarray(res.objective)).all()
    # but an id spanning two blocks is still a violation
    spanning = np.array([0, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 0])
    with pytest.raises(ValueError, match="contiguous"):
        bcd_lasso(LassoProblem(A=A, b=b, lam=0.1, groups=spanning),
                  SolverConfig(block_size=4, iterations=8))
