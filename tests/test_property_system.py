"""Hypothesis property tests on system-level invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.linalg import (power_iteration_max_eig, sample_block,
                               theta_schedule)
from repro.roofline.analysis import collective_bytes_from_hlo, \
    two_point_fit


@given(st.integers(2, 40), st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_sample_block_valid(n, mu):
    mu = min(mu, n)
    idx = np.asarray(sample_block(jax.random.key(0), n, mu))
    assert idx.shape == (mu,)
    assert len(set(idx.tolist())) == mu          # without replacement
    assert idx.min() >= 0 and idx.max() < n


@given(st.integers(1, 64), st.integers(2, 256))
@settings(max_examples=30, deadline=None)
def test_theta_schedule_decreasing_in_unit_interval(num, q):
    theta0 = jnp.float32(1.0 / q)
    th = np.asarray(theta_schedule(theta0, num, q))
    assert th.shape == (num + 1,)
    assert np.all(th > 0) and np.all(th <= 1.0)
    assert np.all(np.diff(th) <= 1e-7)           # monotone non-increasing


@given(st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_power_iteration_matches_eigvalsh(seed):
    rng = np.random.default_rng(seed)
    mu = rng.integers(1, 9)
    B = rng.standard_normal((20, mu)).astype(np.float32)
    G = jnp.asarray(B.T @ B)
    est = float(power_iteration_max_eig(G, iters=64))
    true = float(np.linalg.eigvalsh(np.asarray(G)).max())
    assert est <= true * 1.001
    assert est >= true * 0.95                    # fixed-iter approx


@given(st.floats(1, 1e6), st.floats(0, 1e6), st.integers(3, 100))
@settings(max_examples=40, deadline=None)
def test_two_point_fit_recovers_linear(fixed, per, n):
    c1 = fixed + per
    c2 = fixed + 2 * per
    got = two_point_fit(c1, c2, 1, 2, n)
    expected = fixed + n * per
    assert abs(got - expected) <= 1e-6 * max(1.0, abs(expected))


@given(st.integers(1, 64), st.integers(1, 64), st.sampled_from(
    ["bf16", "f32", "s32"]), st.sampled_from(
    ["all-reduce", "all-gather", "reduce-scatter", "all-to-all",
     "collective-permute"]))
@settings(max_examples=40, deadline=None)
def test_collective_parser_roundtrip(d0, d1, dt, op):
    bytes_per = {"bf16": 2, "f32": 4, "s32": 4}[dt]
    hlo = f"  %x.1 = {dt}[{d0},{d1}]{{1,0}} {op}(%p), channel_id=1"
    out = collective_bytes_from_hlo(hlo)
    assert out[op] == d0 * d1 * bytes_per


@given(st.integers(0, 10_000), st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=25, deadline=None)
def test_pipeline_shard_concat_invariant(step, gb_mult, seq):
    from repro.data.tokens import TokenPipeline
    gb = 4 * max(1, gb_mult % 4)
    p = TokenPipeline(vocab_size=97, global_batch=gb, seq_len=seq, seed=7)
    full, _ = p.batch_at(step)
    parts = [p.shard_at(step, s, 4)[0] for s in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
