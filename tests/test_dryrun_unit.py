"""Dry-run machinery on a small placeholder mesh (subprocess: needs its
own XLA device count). The production 512-device matrix runs via
``python -m repro.launch.dryrun``; here we prove the machinery end-to-end
cheaply and pin the mesh contract."""
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


def test_production_mesh_contract():
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.shape == {"data": 16, "model": 16}, m1.shape
m2 = make_production_mesh(multi_pod=True)
assert m2.shape == {"pod": 2, "data": 16, "model": 16}, m2.shape
print("MESH_OK")
""")
    assert "MESH_OK" in out


def test_run_cell_small_mesh():
    """run_cell on a 2x2 mesh with the smoke config machinery: exercises
    lower+compile+memory+cost-fit+collective-parse end to end."""
    out = _run("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax
from repro.launch import dryrun
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2), ("data", "model"))
r = dryrun.run_cell("tinyllama-1.1b", "train_4k", multi_pod=False,
                    opts=dryrun.DryrunOptions(include_optimizer=False),
                    mesh=mesh, verbose=False)
assert r["status"] == "ok", r.get("error")
assert r["memory"]["total_bytes"] > 0
assert r["per_device"]["flops_macs"] > 0
assert r["roofline"]["dominant"] in ("compute", "memory", "collective")
assert 0 < r["useful_ratio"] < 10
print("CELL_OK", r["roofline"]["dominant"], round(r["useful_ratio"], 3))
""", timeout=1200)
    assert "CELL_OK" in out


def test_input_specs_shapes():
    out = _run("""
from repro.configs import get_config
from repro.configs.base import SHAPES, input_specs
arch = get_config("llama3-8b")
tr = input_specs(arch, SHAPES["train_4k"])
assert tr["tokens"].shape == (256, 4096)
pf = input_specs(arch, SHAPES["prefill_32k"])
assert pf["tokens"].shape == (32, 32768)
dec = input_specs(arch, SHAPES["decode_32k"])
assert dec["tokens"].shape == (128, 1)
k = dec["cache"]["slot0_attn_mlp"]["k"]
assert k.shape == (32, 128, 8, 32768, 128), k.shape
arch2 = get_config("mixtral-8x7b")
d2 = input_specs(arch2, SHAPES["long_500k"])
k2 = d2["cache"]["slot0_moe"]["k"]
assert k2.shape[3] == 4096, k2.shape  # SWA ring cache, not 500k
print("SPECS_OK")
""")
    assert "SPECS_OK" in out
