"""Chaos tier: kill hosts mid-solve and assert the elastic sharded
driver recovers to the undisturbed answer.

Runs in subprocesses on 4 forced CPU devices (XLA_FLAGS must be set
before jax imports; same pattern as tests/test_distributed.py). Every
scenario asserts the recovered f64 solution matches the undisturbed
4-device solve to <= 1e-8 — NOT bit-identity, because after a failure
the survivors' mesh is smaller and the Allreduce reduction order
changes.

Failure schedules cover the hard alignments: mid-s-group kills (the
in-flight unrolled recurrences are lost and replayed), remainder tails
(H not a multiple of s), back-to-back failures in adjacent segments,
and failures before the first checkpoint. Schedules are drawn by
hypothesis when it is installed, and from a seeded RNG otherwise — both
reproducible.

Select with ``-m chaos`` (excluded from the fast tier)."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.chaos

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import tempfile
import numpy as np, jax, jax.numpy as jnp
jax.config.update("jax_enable_x64", True)

from repro.core.api import resolve_family, solve_sharded
from repro.core.types import (LassoProblem, LogRegProblem, SVMProblem,
                              SolverConfig)
from repro.runtime import ElasticConfig, FailureInjector, solve_elastic
from repro.runtime.elastic import build_1d_mesh

rng = np.random.default_rng(5)
m, n = 30, 44
A = jnp.asarray(rng.standard_normal((m, n)), jnp.float64)
b = jnp.asarray(rng.standard_normal(m), jnp.float64)
signs = jnp.asarray(np.sign(rng.standard_normal(m)), jnp.float64)
lam = 0.1 * float(jnp.max(jnp.abs(A.T @ b)))

PROBLEMS = {
    "lasso": LassoProblem(A=A, b=b, lam=lam),
    "svm": SVMProblem(A=A, b=signs, lam=0.5),
    "ksvm": SVMProblem(A=A, b=signs, lam=0.5, kernel="rbf",
                       kernel_params={"gamma": 0.3}),
    "logreg": LogRegProblem(A=A, b=signs, lam=0.1),
}

def chaos_run(family, cfg, failures, checkpoint_every=1,
              accelerated_label=""):
    '''Undisturbed 4-device solve vs elastic solve with the injected
    failure schedule; returns (max_abs_err, report).'''
    prob = PROBLEMS[family]
    fam = resolve_family(prob, family)
    ax = fam.default_axes if isinstance(fam.default_axes, str) else "data"
    ref = solve_sharded(prob, cfg, build_1d_mesh(jax.devices(), ax),
                        family=fam)
    with tempfile.TemporaryDirectory() as d:
        res = solve_elastic(
            prob, cfg, family=fam,
            elastic=ElasticConfig(checkpoint_dir=d,
                                  checkpoint_every=checkpoint_every),
            injector=FailureInjector(
                failures={k: list(v) for k, v in failures.items()}))
    err = float(np.max(np.abs(np.asarray(res.x) - np.asarray(ref.x))))
    assert res.objective.shape[0] == cfg.iterations
    return err, res.aux["elastic"]
"""


FAMILY_CASES = [
    # family, s, accelerated, iterations (remainder tail: H % s != 0
    # for the sa rows), failure schedule {inner_step: [hosts]}
    ("lasso", 1, False, 11, {5: [2]}),
    ("lasso", 4, False, 14, {6: [1]}),           # mid-s-group + tail
    ("lasso", 4, True, 14, {6: [3]}),            # SA-accelerated
    ("svm", 3, False, 13, {7: [0]}),
    ("ksvm", 3, False, 13, {8: [2]}),
    ("logreg", 3, False, 13, {5: [1]}),
]


@pytest.mark.parametrize("family,s,accelerated,H,failures", FAMILY_CASES)
def test_chaos_single_kill_recovers(family, s, accelerated, H, failures):
    out = _run(HEADER + textwrap.dedent(f"""
        cfg = SolverConfig(block_size=4, s={s}, iterations={H},
                           accelerated={accelerated}, dtype=jnp.float64)
        err, report = chaos_run({family!r}, cfg, {failures!r})
        assert report["recoveries"], "no recovery happened"
        assert len(report["live_hosts"]) == 3, report
        assert err <= 1e-8, err
        print("CHAOS_OK", err)
        """))
    assert "CHAOS_OK" in out


def test_chaos_back_to_back_and_first_segment():
    """Two failures in adjacent segments (the second hits the
    just-restored mesh) plus a kill before any checkpoint exists
    (restart from the initial state)."""
    out = _run(HEADER + textwrap.dedent("""
        cfg = SolverConfig(block_size=4, s=3, iterations=14,
                           dtype=jnp.float64)
        err, report = chaos_run("lasso", cfg, {4: [3], 5: [1]},
                                checkpoint_every=1)
        assert len(report["live_hosts"]) == 2, report
        assert err <= 1e-8, err

        # failure in the FIRST segment: no checkpoint yet
        cfg2 = SolverConfig(block_size=4, s=3, iterations=9,
                            dtype=jnp.float64)
        err2, report2 = chaos_run("svm", cfg2, {2: [0]},
                                  checkpoint_every=2)
        assert any("no checkpoint yet" in e for e in report2["events"])
        assert err2 <= 1e-8, err2
        print("CHAOS_OK", err, err2)
        """))
    assert "CHAOS_OK" in out


def _schedules(n_schedules: int):
    """Failure schedules for the randomized sweep: hypothesis-drawn if
    available, else from a seeded RNG (both reproducible)."""
    try:
        import hypothesis  # noqa: F401
        return None  # the hypothesis test below covers this
    except ImportError:
        import numpy as np
        rng = np.random.default_rng(2026)
        scheds = []
        for _ in range(n_schedules):
            n_fail = int(rng.integers(1, 3))
            steps = sorted(rng.choice(np.arange(1, 14), size=n_fail,
                                      replace=False).tolist())
            hosts = rng.choice(4, size=n_fail, replace=False).tolist()
            scheds.append({int(t): [int(h)]
                           for t, h in zip(steps, hosts)})
        return scheds


def test_chaos_randomized_schedules():
    """Randomized (step x host x family x variant) sweep. With
    hypothesis installed the schedules are property-generated in
    test_chaos_hypothesis_schedules instead."""
    scheds = _schedules(3)
    if scheds is None:
        pytest.skip("hypothesis installed - covered by the property test")
    fams = ["lasso", "svm", "logreg"]
    body = "\n".join(textwrap.dedent(f"""
        cfg = SolverConfig(block_size=4, s=3, iterations=14,
                           dtype=jnp.float64)
        err, report = chaos_run({fam!r}, cfg, {sched!r})
        assert err <= 1e-8, ({fam!r}, {sched!r}, err)
        """) for fam, sched in zip(fams, scheds))
    out = _run(HEADER + body + "\nprint('CHAOS_OK')\n")
    assert "CHAOS_OK" in out


def test_chaos_hypothesis_schedules():
    """Property-based schedules: any 1-2 kills at any steps/hosts (never
    all four hosts) recover to <=1e-8. Runs only where hypothesis is
    installed; the subprocess re-checks importability because the
    schedule GENERATION happens out-of-process."""
    pytest.importorskip("hypothesis")
    out = _run(HEADER + textwrap.dedent("""
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=5, deadline=None)
        @given(st.lists(
            st.tuples(st.integers(1, 13), st.integers(0, 3)),
            min_size=1, max_size=2,
            unique_by=lambda p: p[1]))
        def prop(schedule):
            failures = {}
            for step, host in schedule:
                failures.setdefault(step, []).append(host)
            cfg = SolverConfig(block_size=4, s=3, iterations=14,
                               dtype=jnp.float64)
            err, report = chaos_run("lasso", cfg, failures)
            assert err <= 1e-8, (schedule, err)

        prop()
        print("CHAOS_OK")
        """), timeout=1800)
    assert "CHAOS_OK" in out


def test_chaos_straggler_eviction_recovers():
    """The 'evict' escalation rides the same re-mesh path as a hard
    failure; a persistently slow host is removed and the answer still
    matches the undisturbed solve."""
    out = _run(HEADER + textwrap.dedent("""
        from repro.runtime import StragglerMonitor
        cfg = SolverConfig(block_size=4, s=2, iterations=12,
                           dtype=jnp.float64)
        prob = PROBLEMS["lasso"]
        fam = resolve_family(prob, "lasso")
        ref = solve_sharded(prob, cfg,
                            build_1d_mesh(jax.devices(), "data"),
                            family=fam)
        with tempfile.TemporaryDirectory() as d:
            mon = StragglerMonitor(n_hosts=4, threshold=1.5, patience=1,
                                   evict_after=2)
            res = solve_elastic(
                prob, cfg, family=fam,
                elastic=ElasticConfig(checkpoint_dir=d,
                                      checkpoint_every=1),
                monitor=mon,
                host_times=lambda seg, live: {
                    h: (6.0 if h == 2 else 1.0) for h in live})
        report = res.aux["elastic"]
        assert 2 not in report["live_hosts"], report
        assert any(r["kind"] == "evict" for r in report["recoveries"])
        err = float(np.max(np.abs(np.asarray(res.x) - np.asarray(ref.x))))
        assert err <= 1e-8, err
        print("CHAOS_OK", err)
        """))
    assert "CHAOS_OK" in out
