"""The static contract analyzer (repro.analysis): registry-wide green
runs, seeded violations producing distinct diagnostics, lint rules, and
the CLI.

The seeded-violation tests build stub ProblemFamily instances whose
``solve`` deliberately breaks ONE contract (a second psum, a missing
psum before a replicated output, a hard-coded f32 cast) and assert the
matching pass — and only that pass — flags it.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (CHECKS, Diagnostic, check_all,
                            check_collectives, check_dtypes,
                            check_registry, check_replication,
                            collective_budget, find_float_narrowing,
                            lint_source, shard_map_out_taints)
from repro.core.types import FAMILIES, LassoProblem, ProblemFamily, \
    SolverResult

pytestmark = pytest.mark.analysis


# ---------------------------------------------------------------------------
# stub families: each breaks exactly one contract
# ---------------------------------------------------------------------------

def _stub(solve, name="stub"):
    return ProblemFamily(
        name=name, problem_cls=LassoProblem, solve=solve,
        variants={"classical": ""}, partition="row", default_axes="data",
        bench_problem_kwargs={"lam": 0.1})


def _scan_solve(body_grad, length_attr="iterations"):
    def solve(problem, cfg, axis_name=None, x0=None):
        def body(c, _):
            return c - 0.01 * body_grad(problem, c, axis_name), 0.0
        x, obj = jax.lax.scan(body, jnp.zeros(problem.A.shape[1],
                                              problem.A.dtype),
                              None, length=getattr(cfg, length_attr))
        return SolverResult(x=x, objective=jnp.sum(obj))
    return solve


def _good_grad(problem, c, axis_name):
    return jax.lax.psum(problem.A.T @ (problem.A @ c - problem.b),
                        axis_name)


GOOD = _stub(_scan_solve(_good_grad), "stub_good")


def test_stub_good_is_clean():
    for check in (check_collectives, check_replication, check_dtypes):
        diags, checked = check(GOOD)
        assert checked == ["stub_good:classical"]
        assert not [d for d in diags if d.severity == "error"], \
            [d.format() for d in diags]


def test_seeded_second_psum_flags_collectives_only():
    def grad(problem, c, axis_name):
        g = _good_grad(problem, c, axis_name)
        return g + jax.lax.psum(jnp.sum(g), axis_name)   # the 2nd psum
    fam = _stub(_scan_solve(grad), "stub_two_psum")
    errs = [d for d in check_collectives(fam)[0] if d.severity == "error"]
    assert len(errs) == 1 and errs[0].check == "collectives"
    assert "found 2" in errs[0].message
    # the extra psum keeps everything replicated: replication stays green
    assert not check_replication(fam)[0]


def test_seeded_shard_divergent_replicated_output():
    def grad(problem, c, axis_name):
        return problem.A.T @ (problem.A @ c - problem.b)  # never psum'd
    fam = _stub(_scan_solve(grad), "stub_divergent")
    errs = [d for d in check_replication(fam)[0] if d.severity == "error"]
    assert errs and all(d.check == "replication" for d in errs)
    assert any("'x'" in d.message and "data" in d.message for d in errs)


def test_seeded_f64_downcast_flags_dtypes_only():
    def solve(problem, cfg, axis_name=None, x0=None):
        A32 = problem.A.astype(jnp.float32)              # silent narrow
        def body(c, _):
            g = jax.lax.psum(A32.T @ (A32 @ c), axis_name)
            return c - 0.01 * g.astype(problem.A.dtype), 0.0
        x, obj = jax.lax.scan(body, jnp.zeros(problem.A.shape[1],
                                              problem.A.dtype),
                              None, length=cfg.iterations)
        return SolverResult(x=x, objective=jnp.sum(obj))
    fam = _stub(solve, "stub_downcast")
    errs = [d for d in check_dtypes(fam)[0] if d.severity == "error"]
    assert errs and all(d.check == "dtypes" for d in errs)
    assert "float64 -> float32" in errs[0].message
    # the cast is shard-uniform and the psum is intact: the other two
    # passes stay green (distinct diagnostics per seeded violation).
    assert not check_replication(fam)[0]
    assert not [d for d in check_collectives(fam)[0]
                if d.severity == "error"]


# ---------------------------------------------------------------------------
# jaxpr walkers, directly
# ---------------------------------------------------------------------------

def test_collective_budget_splits_loop_vs_amortized():
    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "i"), None
        out, _ = jax.lax.scan(body, x, None, length=4)
        return jax.lax.psum(out, "i")                    # tail/amortized

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))
    fn = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_rep=False)
    budget = collective_budget(
        jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert budget.per_iteration["all-reduce"] == 1
    assert budget.amortized["all-reduce"] == 1
    assert budget.per_iteration_bytes == 8 * 4
    assert budget.total["all-reduce"] == 2


def test_taint_axis_index_and_while_predicate():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))

    def f(x):
        div = jnp.float32(jax.lax.axis_index("i"))       # shard-varying

        def cond(c):
            return jnp.sum(c) + div < 10.0               # tainted pred

        def body(c):
            return c + 1.0

        looped = jax.lax.while_loop(cond, body, jnp.zeros(()))
        return jax.lax.psum(x, "i"), looped

    fn = shard_map(f, mesh=mesh, in_specs=(P("i"),), out_specs=(P(), P()),
                   check_rep=False)
    outs, _ = shard_map_out_taints(
        jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert outs[0] == frozenset()          # psum'd: replicated
    assert outs[1] == frozenset({"i"})     # trip count may diverge


def test_find_float_narrowing_reports_site():
    from jax.experimental import enable_x64
    with enable_x64():
        j = jax.make_jaxpr(lambda x: (x * 2.0).astype(jnp.float32))(
            jax.ShapeDtypeStruct((4,), jnp.float64))
    hits = find_float_narrowing(j)
    assert hits and hits[0][:2] == ("float64", "float32")


# ---------------------------------------------------------------------------
# lint rules
# ---------------------------------------------------------------------------

def _rules(diags):
    return sorted({d.message.split("]")[0].lstrip("[") for d in diags})


def test_lint_raw_collective_outside_allowlist():
    src = "import jax\n\ndef f(x):\n    return jax.lax.psum(x, 'i')\n"
    assert _rules(lint_source(src, "core/sa_new.py")) == ["raw-collective"]
    assert not lint_source(src, "optim/compress.py")
    assert not lint_source(src, "core/linalg.py")


def test_lint_raw_collective_from_import():
    src = "from jax.lax import psum\n"
    assert _rules(lint_source(src, "core/x.py")) == ["raw-collective"]


def test_lint_ambient_rng():
    assert _rules(lint_source("import random\n", "core/x.py")) == \
        ["ambient-rng"]
    assert _rules(lint_source(
        "import numpy as np\nnp.random.seed(0)\n", "data/x.py")) == \
        ["ambient-rng"]   # global state: not allowed even in data/
    gen = "import numpy as np\nr = np.random.default_rng(0)\n"
    assert _rules(lint_source(gen, "core/x.py")) == ["ambient-rng"]
    assert not lint_source(gen, "data/x.py")
    assert not lint_source(gen, "tune/microbench.py")
    assert not lint_source("import jax\nk = jax.random.key(0)\n",
                           "core/x.py")


def test_lint_bare_assert():
    assert _rules(lint_source("def f(x):\n    assert x > 0\n",
                              "core/x.py")) == ["bare-assert"]
    assert not lint_source(
        "def f(x):\n    if x <= 0:\n        raise ValueError('x')\n",
        "core/x.py")


def test_diagnostic_rejects_unknown_severity():
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("lint", "fatal", "x", "y")


# ---------------------------------------------------------------------------
# registry-wide runs + CLI
# ---------------------------------------------------------------------------

def test_registry_contract_covers_all_programs():
    diags, checked = check_registry()
    # every family with engine-backed variants exposes its program(s)
    assert len(checked) >= len(FAMILIES)
    assert not diags, [d.format() for d in diags]


def test_check_all_full_registry_green():
    report = check_all()
    assert report.ok, report.format()
    combos = sum(len(f.variants) for f in FAMILIES.values())
    for check in ("collectives", "replication", "dtypes", "costs"):
        assert sum(c.startswith(f"{check}:") for c in report.checked) \
            == combos
    from repro.kernels import KERNEL_PACKAGES
    assert sum(c.startswith("kernels:") for c in report.checked) \
        == len(KERNEL_PACKAGES)
    assert any(c.startswith("lint:") for c in report.checked)
    assert any(c.startswith("registry:") for c in report.checked)
    # the bytes-per-outer measurements ride along as info diagnostics
    assert sum(d.severity == "info" and d.check == "collectives"
               for d in report.diagnostics) == combos
    # ...as do the per-variant certified cost ratios
    assert sum(d.severity == "info" and d.check == "costs"
               for d in report.diagnostics) == combos


def test_check_all_validates_selection():
    with pytest.raises(ValueError, match="unknown checks"):
        check_all(checks=("nope",))
    with pytest.raises(ValueError, match="unknown family"):
        check_all(checks=("lint",), families=("nope",))
    with pytest.raises(ValueError, match="registered by no selected"):
        check_all(checks=("collectives",), families=("lasso",),
                  variants=("nope",))
    assert set(CHECKS) == {"collectives", "replication", "dtypes",
                           "costs", "kernels", "lint", "registry"}


def test_check_all_variant_filter():
    report = check_all(checks=("collectives",), families=("lasso",),
                       variants=("sa",))
    assert report.checked == ["collectives:lasso:sa"]
    assert report.ok, report.format()


def test_cli_lint_and_registry():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--checks", "lint",
         "registry"], capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 error(s)" in out.stdout


def test_sa_lint_cli_clean():
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, str(root / "tools" / "sa_lint.py")],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stdout


# ---------------------------------------------------------------------------
# cost certifier: seeded hooks, each firing exactly one diagnostic
# ---------------------------------------------------------------------------

import dataclasses
import json

from repro.analysis import (check_costs, check_kernels,
                            guard_drift_diags, index_map_bounds_diags,
                            output_injectivity_diags, solver_cost_count,
                            variant_config)
from repro.analysis.costs import CERT_SHAPES, CostTolerance, cost_ratio_rows
from repro.analysis.kernels import KernelCapture, SpecView

# wide bands isolate the s-scaling drift check: band violations cannot
# fire, so a drifting ratio is the ONLY possible error.
WIDE = CostTolerance(f_band=(1e-3, 1e3), w_band=(1e-3, 1e3))


def _sa_stub(solve, name):
    return ProblemFamily(
        name=name, problem_cls=LassoProblem, solve=solve,
        variants={"sa": ""}, partition="row", default_axes="data",
        bench_problem_kwargs={"lam": 0.1})


def _outer_scan_solve(problem, cfg, axis_name=None, x0=None):
    # an honest s-stepper for counting purposes: ONE psum'd gradient
    # per OUTER iteration, so flops/words/messages all fall as 1/s.
    def body(c, _):
        return c - 0.01 * _good_grad(problem, c, axis_name), 0.0
    x, obj = jax.lax.scan(body, jnp.zeros(problem.A.shape[1],
                                          problem.A.dtype),
                          None, length=cfg.outer_iterations)
    return SolverResult(x=x, objective=jnp.sum(obj))


def _counted(fam, variant):
    m, n = CERT_SHAPES[fam.partition]
    cfg = variant_config(fam, variant, iterations=48, s=1)
    return solver_cost_count(fam, cfg, m=m, n=n)


def test_cost_certifier_green_on_matching_hook():
    fam0 = _sa_stub(_outer_scan_solve, "stub_cost_good")
    base = _counted(fam0, "sa")

    def costs(dims, H, mu, s, P, kernel="linear"):
        outer = -(-H // s)
        return {"F": base.flops * outer / 48.0,
                "W": base.words * outer / 48.0, "L": outer, "M": dims.n}

    diags, checked = check_costs(dataclasses.replace(fam0, costs=costs),
                                 sparse=False, tolerance=CostTolerance())
    assert checked == ["stub_cost_good:sa"]
    assert not [d for d in diags if d.severity == "error"], \
        [d.format() for d in diags]


def test_cost_mismatch_fires_f_band_alone():
    fam0 = _stub(_scan_solve(_good_grad), "stub_cost_off")
    base = _counted(fam0, "classical")

    def costs(dims, H, mu, s, P, kernel="linear"):
        return {"F": base.flops * 20.0, "W": base.words, "L": H,
                "M": dims.n}                  # F off by a constant 20x

    errs = [d for d in check_costs(dataclasses.replace(fam0, costs=costs),
                                   sparse=False,
                                   tolerance=CostTolerance())[0]
            if d.severity == "error"]
    assert len(errs) == 1, [d.format() for d in errs]
    assert errs[0].check == "costs"
    assert "term F" in errs[0].message and "band" in errs[0].message


def test_wrong_s_exponent_fires_scaling_alone():
    fam0 = _sa_stub(_outer_scan_solve, "stub_cost_sexp")
    base = _counted(fam0, "sa")

    def costs(dims, H, mu, s, P, kernel="linear"):
        outer = -(-H // s)
        return {"F": base.flops,              # misses the 1/s factor
                "W": base.words * outer / 48.0, "L": outer, "M": dims.n}

    errs = [d for d in check_costs(dataclasses.replace(fam0, costs=costs),
                                   sparse=False, tolerance=WIDE)[0]
            if d.severity == "error"]
    assert len(errs) == 1, [d.format() for d in errs]
    assert "term F s-scaling" in errs[0].message
    assert "wrong s exponent" in errs[0].message


def test_ignored_s_fires_latency_alone():
    # the solve issues one message per INNER iteration (it ignores s):
    # counted flops/words still match a constant model, so the latency
    # term is the only violated contract.
    fam0 = _sa_stub(_scan_solve(_good_grad), "stub_cost_lat")
    base = _counted(fam0, "sa")

    def costs(dims, H, mu, s, P, kernel="linear"):
        return {"F": base.flops, "W": base.words, "L": H, "M": dims.n}

    errs = [d for d in check_costs(dataclasses.replace(fam0, costs=costs),
                                   sparse=False, tolerance=WIDE)[0]
            if d.severity == "error"]
    assert len(errs) == 1, [d.format() for d in errs]
    assert "term L" in errs[0].message
    assert "ceil(H/s)" in errs[0].message


def test_sparse_certification_counts_nnz_not_mn():
    # the SparseOperand traces of the real SA solvers must cost O(nnz):
    # at 8% density the sparse flop count sits well below both the
    # density x dense bound and the dense count itself.
    for name in ("lasso", "logreg"):
        rows = cost_ratio_rows(FAMILIES[name], variants=("sa",),
                               s_grid=(1, 4))
        assert rows
        for row in rows:
            assert row.sparse_ratio is not None
            assert row.sparse_ratio <= 1.0, \
                (name, row.s, row.sparse_ratio)
            assert row.sparse_flops < 0.25 * row.flops


def test_select_config_refuses_uncertified_costs():
    import numpy as np
    from repro.core.cost_model import Machine
    from repro.core.types import SolverConfig
    from repro.tune.select import select_config

    A = np.arange(64 * 32, dtype=np.float32).reshape(64, 32) % 7 - 3.0
    prob = LassoProblem(A=jnp.asarray(A), b=jnp.ones(64, jnp.float32),
                        lam=0.1)
    cfg = SolverConfig(block_size=4, iterations=16)
    bad = dataclasses.replace(
        FAMILIES["lasso"],
        costs=lambda dims, H, mu, s, P, kernel="linear":
        {"F": 1.0, "W": 1.0, "L": 1.0, "M": 1.0})
    with pytest.raises(ValueError, match="uncertified cost model"):
        select_config(prob, Machine.cray_xc30(), cfg, family=bad,
                      certified=True)
    tuned = select_config(prob, Machine.cray_xc30(), cfg,
                          family=FAMILIES["lasso"], certified=True)
    assert tuned.s >= 1


# ---------------------------------------------------------------------------
# kernel safety pass: seeded captures, each firing exactly one diagnostic
# ---------------------------------------------------------------------------

def test_guard_drift_fires_on_understating_model():
    assert not guard_drift_diags("k", 1000.0, 1100.0, 8.0e6)  # in slack
    errs = guard_drift_diags("k", 1000.0, 2000.0, 8.0e6)
    assert len(errs) == 1 and errs[0].check == "kernels"
    assert "guard drift" in errs[0].message


def test_write_race_fires_alone():
    cap = KernelCapture(
        name="stub", grid=(2, 2), inputs=(),
        outputs=(SpecView("out0", (2, 2), jnp.float32, (1, 1),
                          lambda i, j: (0, 0)),),
        scratch=(), semantics=("parallel", "parallel"))
    errs = output_injectivity_diags("stub", cap)
    assert len(errs) == 1 and "write race" in errs[0].message
    assert not index_map_bounds_diags("stub", cap)
    # the SAME revisit across "arbitrary" (sequential) dimensions is the
    # legal accumulation pattern — and the TPU default when no
    # dimension_semantics are declared.
    assert not output_injectivity_diags(
        "stub", dataclasses.replace(cap, semantics=None))


def test_oob_index_map_fires_alone():
    cap = KernelCapture(
        name="stub", grid=(2, 2), inputs=(),
        outputs=(SpecView("out0", (2, 2), jnp.float32, (1, 1),
                          lambda i, j: (i + 1, j)),),
        scratch=(), semantics=("parallel", "parallel"))
    errs = index_map_bounds_diags("stub", cap)
    assert len(errs) == 1 and "out of bounds" in errs[0].message
    assert not output_injectivity_diags("stub", cap)


def test_kernel_safety_pass_green_over_all_packages():
    from repro.kernels import KERNEL_PACKAGES
    diags, checked = check_kernels()
    assert checked == list(KERNEL_PACKAGES)
    assert not [d for d in diags if d.severity == "error"], \
        [d.format() for d in diags if d.severity == "error"]
    infos = {d.where.split("[")[0] for d in diags if d.severity == "info"}
    assert set(KERNEL_PACKAGES) <= infos


# ---------------------------------------------------------------------------
# replication taint: cond nested inside scan carries
# ---------------------------------------------------------------------------

def _scan_cond_taints(use_tainted_branch):
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))

    def f(x):
        div = jnp.float32(jax.lax.axis_index("i"))       # shard-varying

        def body(c, _):
            c2 = jax.lax.cond(jnp.sum(c) < 10.0,
                              (lambda: c + div) if use_tainted_branch
                              else (lambda: c + 1.0),
                              lambda: c)
            return c2, None

        out, _ = jax.lax.scan(body, jnp.zeros(4, jnp.float32), None,
                              length=3)
        return jax.lax.psum(x, "i"), out

    fn = shard_map(f, mesh=mesh, in_specs=(P("i"),),
                   out_specs=(P(), P()), check_rep=False)
    outs, _ = shard_map_out_taints(
        jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32)))
    return outs


def test_taint_cond_branch_inside_scan_carry():
    outs = _scan_cond_taints(use_tainted_branch=True)
    assert outs[0] == frozenset()          # psum'd: replicated
    assert outs[1] == frozenset({"i"})     # tainted branch joins carry


def test_clean_cond_inside_scan_stays_untainted():
    outs = _scan_cond_taints(use_tainted_branch=False)
    assert outs[1] == frozenset()


def test_taint_cond_predicate_inside_scan_carry():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))

    def f(x):
        div = jnp.float32(jax.lax.axis_index("i"))

        def body(c, _):
            # both branches are shard-uniform; the PREDICATE diverges,
            # so which one ran (and hence the carry) is shard-varying.
            c2 = jax.lax.cond(div < 1.0, lambda: c + 1.0, lambda: c)
            return c2, None

        out, _ = jax.lax.scan(body, jnp.zeros(4, jnp.float32), None,
                              length=3)
        return jax.lax.psum(x, "i"), out

    fn = shard_map(f, mesh=mesh, in_specs=(P("i"),),
                   out_specs=(P(), P()), check_rep=False)
    outs, _ = shard_map_out_taints(
        jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert outs[1] == frozenset({"i"})


def test_cli_json_report():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--checks", "lint",
         "registry", "--json"], capture_output=True, text=True,
        timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    data = json.loads(out.stdout)
    assert data["ok"] is True and data["errors"] == 0
    assert any(c.startswith("lint:") for c in data["checked"])
    assert all({"check", "severity", "where", "message"}
               <= set(d) for d in data["diagnostics"])
