"""The static contract analyzer (repro.analysis): registry-wide green
runs, seeded violations producing distinct diagnostics, lint rules, and
the CLI.

The seeded-violation tests build stub ProblemFamily instances whose
``solve`` deliberately breaks ONE contract (a second psum, a missing
psum before a replicated output, a hard-coded f32 cast) and assert the
matching pass — and only that pass — flags it.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import (CHECKS, Diagnostic, check_all,
                            check_collectives, check_dtypes,
                            check_registry, check_replication,
                            collective_budget, find_float_narrowing,
                            lint_source, shard_map_out_taints)
from repro.core.types import FAMILIES, LassoProblem, ProblemFamily, \
    SolverResult

pytestmark = pytest.mark.analysis


# ---------------------------------------------------------------------------
# stub families: each breaks exactly one contract
# ---------------------------------------------------------------------------

def _stub(solve, name="stub"):
    return ProblemFamily(
        name=name, problem_cls=LassoProblem, solve=solve,
        variants={"classical": ""}, partition="row", default_axes="data",
        bench_problem_kwargs={"lam": 0.1})


def _scan_solve(body_grad, length_attr="iterations"):
    def solve(problem, cfg, axis_name=None, x0=None):
        def body(c, _):
            return c - 0.01 * body_grad(problem, c, axis_name), 0.0
        x, obj = jax.lax.scan(body, jnp.zeros(problem.A.shape[1],
                                              problem.A.dtype),
                              None, length=getattr(cfg, length_attr))
        return SolverResult(x=x, objective=jnp.sum(obj))
    return solve


def _good_grad(problem, c, axis_name):
    return jax.lax.psum(problem.A.T @ (problem.A @ c - problem.b),
                        axis_name)


GOOD = _stub(_scan_solve(_good_grad), "stub_good")


def test_stub_good_is_clean():
    for check in (check_collectives, check_replication, check_dtypes):
        diags, checked = check(GOOD)
        assert checked == ["stub_good:classical"]
        assert not [d for d in diags if d.severity == "error"], \
            [d.format() for d in diags]


def test_seeded_second_psum_flags_collectives_only():
    def grad(problem, c, axis_name):
        g = _good_grad(problem, c, axis_name)
        return g + jax.lax.psum(jnp.sum(g), axis_name)   # the 2nd psum
    fam = _stub(_scan_solve(grad), "stub_two_psum")
    errs = [d for d in check_collectives(fam)[0] if d.severity == "error"]
    assert len(errs) == 1 and errs[0].check == "collectives"
    assert "found 2" in errs[0].message
    # the extra psum keeps everything replicated: replication stays green
    assert not check_replication(fam)[0]


def test_seeded_shard_divergent_replicated_output():
    def grad(problem, c, axis_name):
        return problem.A.T @ (problem.A @ c - problem.b)  # never psum'd
    fam = _stub(_scan_solve(grad), "stub_divergent")
    errs = [d for d in check_replication(fam)[0] if d.severity == "error"]
    assert errs and all(d.check == "replication" for d in errs)
    assert any("'x'" in d.message and "data" in d.message for d in errs)


def test_seeded_f64_downcast_flags_dtypes_only():
    def solve(problem, cfg, axis_name=None, x0=None):
        A32 = problem.A.astype(jnp.float32)              # silent narrow
        def body(c, _):
            g = jax.lax.psum(A32.T @ (A32 @ c), axis_name)
            return c - 0.01 * g.astype(problem.A.dtype), 0.0
        x, obj = jax.lax.scan(body, jnp.zeros(problem.A.shape[1],
                                              problem.A.dtype),
                              None, length=cfg.iterations)
        return SolverResult(x=x, objective=jnp.sum(obj))
    fam = _stub(solve, "stub_downcast")
    errs = [d for d in check_dtypes(fam)[0] if d.severity == "error"]
    assert errs and all(d.check == "dtypes" for d in errs)
    assert "float64 -> float32" in errs[0].message
    # the cast is shard-uniform and the psum is intact: the other two
    # passes stay green (distinct diagnostics per seeded violation).
    assert not check_replication(fam)[0]
    assert not [d for d in check_collectives(fam)[0]
                if d.severity == "error"]


# ---------------------------------------------------------------------------
# jaxpr walkers, directly
# ---------------------------------------------------------------------------

def test_collective_budget_splits_loop_vs_amortized():
    def f(x):
        def body(c, _):
            return jax.lax.psum(c, "i"), None
        out, _ = jax.lax.scan(body, x, None, length=4)
        return jax.lax.psum(out, "i")                    # tail/amortized

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))
    fn = shard_map(f, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_rep=False)
    budget = collective_budget(
        jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert budget.per_iteration["all-reduce"] == 1
    assert budget.amortized["all-reduce"] == 1
    assert budget.per_iteration_bytes == 8 * 4
    assert budget.total["all-reduce"] == 2


def test_taint_axis_index_and_while_predicate():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("i",))

    def f(x):
        div = jnp.float32(jax.lax.axis_index("i"))       # shard-varying

        def cond(c):
            return jnp.sum(c) + div < 10.0               # tainted pred

        def body(c):
            return c + 1.0

        looped = jax.lax.while_loop(cond, body, jnp.zeros(()))
        return jax.lax.psum(x, "i"), looped

    fn = shard_map(f, mesh=mesh, in_specs=(P("i"),), out_specs=(P(), P()),
                   check_rep=False)
    outs, _ = shard_map_out_taints(
        jax.make_jaxpr(fn)(jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert outs[0] == frozenset()          # psum'd: replicated
    assert outs[1] == frozenset({"i"})     # trip count may diverge


def test_find_float_narrowing_reports_site():
    from jax.experimental import enable_x64
    with enable_x64():
        j = jax.make_jaxpr(lambda x: (x * 2.0).astype(jnp.float32))(
            jax.ShapeDtypeStruct((4,), jnp.float64))
    hits = find_float_narrowing(j)
    assert hits and hits[0][:2] == ("float64", "float32")


# ---------------------------------------------------------------------------
# lint rules
# ---------------------------------------------------------------------------

def _rules(diags):
    return sorted({d.message.split("]")[0].lstrip("[") for d in diags})


def test_lint_raw_collective_outside_allowlist():
    src = "import jax\n\ndef f(x):\n    return jax.lax.psum(x, 'i')\n"
    assert _rules(lint_source(src, "core/sa_new.py")) == ["raw-collective"]
    assert not lint_source(src, "optim/compress.py")
    assert not lint_source(src, "core/linalg.py")


def test_lint_raw_collective_from_import():
    src = "from jax.lax import psum\n"
    assert _rules(lint_source(src, "core/x.py")) == ["raw-collective"]


def test_lint_ambient_rng():
    assert _rules(lint_source("import random\n", "core/x.py")) == \
        ["ambient-rng"]
    assert _rules(lint_source(
        "import numpy as np\nnp.random.seed(0)\n", "data/x.py")) == \
        ["ambient-rng"]   # global state: not allowed even in data/
    gen = "import numpy as np\nr = np.random.default_rng(0)\n"
    assert _rules(lint_source(gen, "core/x.py")) == ["ambient-rng"]
    assert not lint_source(gen, "data/x.py")
    assert not lint_source(gen, "tune/microbench.py")
    assert not lint_source("import jax\nk = jax.random.key(0)\n",
                           "core/x.py")


def test_lint_bare_assert():
    assert _rules(lint_source("def f(x):\n    assert x > 0\n",
                              "core/x.py")) == ["bare-assert"]
    assert not lint_source(
        "def f(x):\n    if x <= 0:\n        raise ValueError('x')\n",
        "core/x.py")


def test_diagnostic_rejects_unknown_severity():
    with pytest.raises(ValueError, match="severity"):
        Diagnostic("lint", "fatal", "x", "y")


# ---------------------------------------------------------------------------
# registry-wide runs + CLI
# ---------------------------------------------------------------------------

def test_registry_contract_covers_all_programs():
    diags, checked = check_registry()
    # every family with engine-backed variants exposes its program(s)
    assert len(checked) >= len(FAMILIES)
    assert not diags, [d.format() for d in diags]


def test_check_all_full_registry_green():
    report = check_all()
    assert report.ok, report.format()
    combos = sum(len(f.variants) for f in FAMILIES.values())
    for check in ("collectives", "replication", "dtypes"):
        assert sum(c.startswith(f"{check}:") for c in report.checked) \
            == combos
    assert any(c.startswith("lint:") for c in report.checked)
    assert any(c.startswith("registry:") for c in report.checked)
    # the bytes-per-outer measurements ride along as info diagnostics
    assert sum(d.severity == "info" and d.check == "collectives"
               for d in report.diagnostics) == combos


def test_check_all_validates_selection():
    with pytest.raises(ValueError, match="unknown checks"):
        check_all(checks=("nope",))
    with pytest.raises(ValueError, match="unknown family"):
        check_all(checks=("lint",), families=("nope",))
    assert set(CHECKS) == {"collectives", "replication", "dtypes",
                           "lint", "registry"}


def test_cli_lint_and_registry():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--checks", "lint",
         "registry"], capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 error(s)" in out.stdout


def test_sa_lint_cli_clean():
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, str(root / "tools" / "sa_lint.py")],
        capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 finding(s)" in out.stdout
