import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.compress import (ErrorFeedback, dequantize_int8,
                                  quantize_int8)


def test_adamw_minimizes_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = AdamW(learning_rate=0.01, weight_decay=0.5, clip_norm=0.0)
    params = {"w": jnp.asarray([1.0])}
    state = opt.init(params)
    grads = {"w": jnp.asarray([0.0])}
    params2, _ = opt.update(grads, state, params)
    assert float(params2["w"][0]) < 1.0


def test_clipping_bounds_update():
    opt = AdamW(learning_rate=1.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    g_small = {"w": jnp.full(4, 1e-3)}
    g_huge = {"w": jnp.full(4, 1e6)}
    p1, _ = opt.update(g_small, state, params)
    p2, _ = opt.update(g_huge, state, params)
    # clipped huge gradient produces a comparable (not 1e9x) step
    assert float(jnp.max(jnp.abs(p2["w"]))) < 10 * max(
        float(jnp.max(jnp.abs(p1["w"]))), 1e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < float(lr(50)) < float(lr(10))
    assert float(lr(100)) >= 0.099            # min_ratio floor


@given(st.lists(st.floats(-100, 100, width=32), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_quantize_int8_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    max_abs = float(jnp.max(jnp.abs(x)))
    # elementwise error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= max_abs / 127.0 + 1e-6


def test_error_feedback_preserves_sum():
    """EF property: quantization errors don't accumulate — the cumulative
    applied update tracks the cumulative true gradient."""
    from repro.optim.compress import compressed_psum
    import functools
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    # single-device axis: psum over a size-1 mesh axis is identity, but
    # exercises the full codepath.
    mesh = jax.make_mesh((1,), ("dp",))
    grads_seq = [jnp.asarray([0.3, -0.7, 0.01]) * (i + 1)
                 for i in range(20)]
    ef = ErrorFeedback.init({"g": grads_seq[0]})
    applied = jnp.zeros(3)
    for g in grads_seq:
        def body(gg, res):
            out, ef2 = compressed_psum({"g": gg},
                                       ErrorFeedback(residual={"g": res}),
                                       "dp", n_shards=1)
            return out["g"], ef2.residual["g"]
        fn = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                       out_specs=(P(), P()), check_rep=False)
        out, res = fn(g, ef.residual["g"])
        ef = ErrorFeedback(residual={"g": res})
        applied = applied + out
    true_sum = sum(grads_seq)
    np.testing.assert_allclose(np.asarray(applied), np.asarray(true_sum),
                               rtol=0.02, atol=0.05)
