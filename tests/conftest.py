import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _fresh_fallback_warnings():
    """The kernel dispatch warn-once memo is process-global; without a
    reset, whichever test first trips a Pallas->ref fallback swallows
    the warning every later test asserts on. Re-arm it per test."""
    from repro.kernels import reset_fallback_warnings
    reset_fallback_warnings()
    yield
    reset_fallback_warnings()


@pytest.fixture(scope="session")
def lasso_data():
    """Small well-conditioned lasso problem with a planted sparse x."""
    rng = np.random.default_rng(0)
    m, n = 200, 60
    A = rng.standard_normal((m, n)).astype(np.float32)
    x_true = np.zeros(n, dtype=np.float32)
    x_true[:8] = rng.standard_normal(8)
    b = (A @ x_true + 0.1 * rng.standard_normal(m)).astype(np.float32)
    lam = 0.1 * float(np.abs(A.T @ b).max())
    return A, b, lam


@pytest.fixture(scope="session")
def svm_data():
    rng = np.random.default_rng(1)
    m, n = 160, 48
    A = rng.standard_normal((m, n)).astype(np.float32)
    w = rng.standard_normal(n).astype(np.float32)
    b = np.sign(A @ w + 0.1 * rng.standard_normal(m)).astype(np.float32)
    b[b == 0] = 1.0
    return A, b
