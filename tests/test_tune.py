"""The autotuner (repro.tune): the linear-model fit, guard-aware
selection, the cache, and the solve(tune=...) integration."""
import dataclasses
import os

import numpy as np
import pytest

from repro import tune
from repro.api import LassoProblem, SolverConfig, resolve_family
from repro.core import cost_model
from repro.core.cost_model import Machine, ProblemDims


# ---------------------------------------------------------------------------
# cost_model: the calibration-friendly per-term vectors.
# ---------------------------------------------------------------------------

def test_cost_vector_matches_predicted_time():
    """predicted_time IS the dot product of machine_vector and
    cost_vector — the linearity calibration relies on."""
    dims = ProblemDims(m=4096, n=8192, f=0.01)
    mach = Machine.cray_xc30()
    for s, mu in [(1, 1), (8, 4), (64, 8)]:
        costs = cost_model.lasso_costs(dims, 512, mu, s, 64)
        direct = cost_model.predicted_time(costs, mach)
        dot = sum(p * c for p, c in zip(cost_model.machine_vector(mach),
                                        cost_model.cost_vector(costs)))
        assert direct == pytest.approx(dot)
        breakdown = cost_model.time_breakdown(costs, mach)
        assert sum(breakdown.values()) == pytest.approx(direct)
        assert set(breakdown) == set(cost_model.COST_TERMS)


def test_machine_vector_roundtrip():
    mach = Machine.tpu_v5e_pod()
    vec = cost_model.machine_vector(mach)
    back = cost_model.machine_from_vector(vec, name=mach.name)
    assert back == mach


# ---------------------------------------------------------------------------
# calibrate: NNLS and the fit.
# ---------------------------------------------------------------------------

def test_nnls_recovers_nonnegative_solution():
    rng = np.random.default_rng(0)
    C = rng.random((12, 4)) + 0.1
    theta_true = np.array([2.0, 0.0, 1.5, 0.3])
    t = C @ theta_true
    theta = tune.nnls(C, t)
    np.testing.assert_allclose(theta, theta_true, atol=1e-8)
    assert (theta >= 0).all()


def test_nnls_clips_negative_coordinates():
    """A system whose unconstrained solution is negative in one
    coordinate must come back clipped, not negative."""
    C = np.array([[1.0, 1.0], [1.0, 1.01], [1.0, 0.99]])
    t = np.array([1.0, 0.98, 1.02])      # wants theta[1] < 0
    theta = tune.nnls(C, t)
    assert (theta >= 0).all()


def test_fit_machine_recovers_known_machine():
    """Synthetic measurements generated FROM a machine fit back to that
    machine (exact linear recovery — 4 unknowns, 6 equations)."""
    dims = ProblemDims(m=2048, n=8192, f=1.0)
    true = Machine("true", alpha=2e-4, beta=3e-9, gamma=5e-10,
                   kappa=1e-4)
    rows = [cost_model.lasso_costs(dims, 48, mu, s, 1)
            for s, mu in [(1, 1), (1, 8), (4, 4), (8, 1), (16, 8),
                          (32, 2)]]
    times = [cost_model.predicted_time(r, true) for r in rows]
    fitted = tune.fit_machine(rows, times)
    for a, b in zip(cost_model.machine_vector(fitted),
                    cost_model.machine_vector(true)):
        assert a == pytest.approx(b, rel=1e-6)


def _toy_problem(m=64, n=96, seed=0):
    rng = np.random.default_rng(seed)
    A = rng.standard_normal((m, n)).astype(np.float32)
    b = rng.standard_normal(m).astype(np.float32)
    return LassoProblem(A=A, b=b, lam=0.1)


def test_calibrate_with_injected_measurements_reports_fit():
    """calibrate() with a fake measure_fn that IS the model: perfect
    recovery, ratio ~1 at every pilot point, no real solves."""
    prob = _toy_problem()
    fam = resolve_family(prob)
    true = Machine("true", alpha=1e-4, beta=2e-9, gamma=8e-10,
                   kappa=5e-5)
    dims = tune.problem_dims(prob)

    def fake_measure(cfg):
        costs = fam.costs(dims, cfg.iterations, cfg.block_size, cfg.s, 1)
        return cost_model.predicted_time(costs, true)

    rep = tune.calibrate(prob, SolverConfig(), measure_fn=fake_measure,
                         pilot_iters=32)
    assert rep.max_ratio == pytest.approx(1.0, abs=1e-6)
    assert len(rep.points) >= 4
    d = rep.to_dict()
    assert d["machine"]["gamma"] == pytest.approx(8e-10, rel=1e-5)


def test_problem_dims_executed_density():
    """f is the EXECUTED density: 1.0 for dense arrays (stored zeros
    still cost dense flops), stored density for SparseOperands."""
    from repro.core.types import SparseOperand

    rng = np.random.default_rng(1)
    A = rng.standard_normal((32, 48)).astype(np.float32)
    A[rng.random(A.shape) < 0.9] = 0.0
    dense_dims = tune.problem_dims(LassoProblem(A=A, b=A[:, 0], lam=0.1))
    assert dense_dims.f == 1.0
    op = SparseOperand.from_dense(A)
    sp_dims = tune.problem_dims(LassoProblem(A=op, b=A[:, 0], lam=0.1))
    assert sp_dims.f == pytest.approx(op.nnz / (32 * 48))
    assert sp_dims.f < 0.2


# ---------------------------------------------------------------------------
# select: guard-aware, structure-aware.
# ---------------------------------------------------------------------------

def _latency_machine():
    """Latency-dominated machine: pushes the selection to large s."""
    return Machine("lat", alpha=1e-2, beta=1e-12, gamma=1e-13,
                   kappa=1e-9)


def test_select_prefers_large_s_on_latency_bound_machine():
    prob = _toy_problem()
    cfg = tune.select_config(prob, _latency_machine(),
                             SolverConfig(iterations=128))
    assert cfg.s > 8
    assert cfg.iterations == 128            # preserved, not tuned


def test_select_never_recommends_guard_violating_pallas():
    """With Pallas allowed and a latency-bound machine pushing s high,
    any recommended use_pallas=True must satisfy the VMEM guard at the
    solve dtype — a recommendation that silently falls back to ref
    would invalidate the tuner's own model."""
    import jax.numpy as jnp
    from repro.kernels import dispatch

    prob = _toy_problem()
    fam = resolve_family(prob)
    base = SolverConfig(iterations=64, dtype=jnp.float64)
    # grid containing an over-VMEM (s, mu) at f64 that fits at f32
    grid = [(1, 1), (181, 8), (2048, 8)]
    cfg = tune.select_config(prob, _latency_machine(), base, fam,
                             allow_pallas=True, grid=grid)
    if cfg.use_pallas:
        assert dispatch.vmem_ok(cfg.s, cfg.block_size,
                                jnp.dtype(cfg.dtype).itemsize)
    # and directly: the guard helper is dtype-aware
    assert tune.pallas_guards_ok(prob, fam, 181, 8, jnp.float32)
    assert not tune.pallas_guards_ok(prob, fam, 181, 8, jnp.float64)
    assert not tune.pallas_guards_ok(prob, fam, 2048, 8, jnp.float32)


def test_select_keeps_group_block_size():
    """Group lasso: mu is the declared group size — structural, not
    tunable. The sweep may change s but must keep block_size."""
    n, mu = 96, 4
    prob = _toy_problem(n=n)
    prob = dataclasses.replace(prob,
                               groups=np.repeat(np.arange(n // mu), mu))
    cfg = tune.select_config(prob, _latency_machine(),
                             SolverConfig(block_size=mu, iterations=64))
    assert cfg.block_size == mu


def test_candidate_grid_respects_family_tune_space():
    prob = _toy_problem()
    fam = resolve_family(prob)
    grid = tune.candidate_grid(fam, prob, SolverConfig())
    ss = {s for s, _ in grid}
    mus = {mu for _, mu in grid}
    assert ss == set(fam.tune_space["s"])
    assert mus <= set(fam.tune_space["mu"])
    assert all(mu <= prob.A.shape[1] for _, mu in grid)


# ---------------------------------------------------------------------------
# tune / autotune: end to end with injected measurements + the cache.
# ---------------------------------------------------------------------------

def _flop_true_machine():
    return Machine("true", alpha=5e-4, beta=1e-9, gamma=5e-10,
                   kappa=2e-5)


def _fake_measure(prob, fam):
    dims = tune.problem_dims(prob)
    true = _flop_true_machine()

    def measure(cfg):
        costs = fam.costs(dims, cfg.iterations, cfg.block_size, cfg.s, 1)
        return cost_model.predicted_time(costs, true)

    return measure


def test_tune_end_to_end_with_injected_measurements(tmp_path):
    prob = _toy_problem()
    fam = resolve_family(prob)
    base = SolverConfig(block_size=8, s=1, iterations=256,
                        track_objective=False)
    res = tune.tune(prob, base, cache_dir=str(tmp_path),
                    measure_fn=_fake_measure(prob, fam))
    cfg = res.config
    assert isinstance(cfg, SolverConfig)
    assert cfg.iterations == 256            # owned by the caller
    assert cfg.track_objective is False
    assert res.predicted_s <= res.predicted_default_s
    # alpha dominates the injected machine -> SA (s > 1) must win
    assert cfg.s > 1
    # the calibrated machine recovered the injected parameters
    assert res.machine.alpha == pytest.approx(5e-4, rel=1e-4)


def test_tune_cache_roundtrip(tmp_path):
    """Second tune of the same regime loads the calibrated machine from
    results/tuned/ instead of re-measuring."""
    prob = _toy_problem()
    fam = resolve_family(prob)
    calls = []
    measure = _fake_measure(prob, fam)

    def counting_measure(cfg):
        calls.append(cfg)
        return measure(cfg)

    first = tune.tune(prob, SolverConfig(iterations=64),
                      cache_dir=str(tmp_path),
                      measure_fn=counting_measure)
    assert not first.from_cache and calls
    n_calls = len(calls)
    path = tune.cache_path(prob, fam.name, str(tmp_path))
    assert os.path.exists(path)
    second = tune.tune(prob, SolverConfig(iterations=64),
                       cache_dir=str(tmp_path),
                       measure_fn=counting_measure)
    assert second.from_cache
    assert len(calls) == n_calls            # no new measurements
    assert second.machine == first.machine
    # refresh=True forces a re-measure
    third = tune.tune(prob, SolverConfig(iterations=64),
                      cache_dir=str(tmp_path), refresh=True,
                      measure_fn=counting_measure)
    assert not third.from_cache and len(calls) > n_calls


def test_load_cached_machine_tolerates_garbage(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    assert tune.load_cached_machine(str(p)) is None
    assert tune.load_cached_machine(str(tmp_path / "missing.json")) \
        is None


def test_autotune_returns_config(tmp_path):
    prob = _toy_problem()
    fam = resolve_family(prob)
    cfg = tune.autotune(prob, SolverConfig(iterations=32),
                        cache_dir=str(tmp_path),
                        measure_fn=_fake_measure(prob, fam))
    assert isinstance(cfg, SolverConfig)


def test_solve_tune_auto_integration(tmp_path, monkeypatch):
    """api.solve(problem, cfg, tune='auto') tunes then solves; the
    config actually used is surfaced in aux. Real (tiny) measurements —
    the whole loop, no injection."""
    from repro import api

    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    prob = _toy_problem()
    base = SolverConfig(block_size=4, s=2, iterations=12,
                        track_objective=False)
    res = api.solve(prob, base, tune="auto")
    used = res.aux["tuned_config"]
    assert isinstance(used, SolverConfig)
    assert used.iterations == 12
    assert res.x.shape == (prob.A.shape[1],)
    assert np.isfinite(np.asarray(res.x)).all()
    # and the calibrated machine landed in the cache
    fam = resolve_family(prob)
    assert os.path.exists(tune.cache_path(prob, fam.name,
                                          str(tmp_path)))


def test_solve_rejects_unknown_tune_mode():
    from repro import api

    with pytest.raises(ValueError, match="tune mode"):
        api.solve(_toy_problem(), SolverConfig(iterations=4),
                  tune="bogus")


# ---------------------------------------------------------------------------
# Review-found defects (regressions).
# ---------------------------------------------------------------------------

def test_cost_vector_requires_flw_keys():
    """A malformed costs hook must fail loudly — zero-filling F/W/L
    would make the tuner 'prefer' the broken family's configs."""
    good = {"F": 1.0, "W": 2.0, "L": 3.0}
    assert cost_model.cost_vector(good) == (1.0, 2.0, 3.0, 0.0)  # I optional
    with pytest.raises(KeyError):
        cost_model.cost_vector({"W": 2.0, "L": 3.0})


def test_single_group_lasso_calibration_does_not_clamp_mu(tmp_path):
    """Regression: the pilot grid clamped mu to n//2 AFTER forcing the
    structural group size, so a single-group problem (group size ==
    n > n//2) handed the solver a block_size violating the validated
    groups contract and crashed mid-calibration."""
    n, mu = 8, 8                            # ONE group spanning all of n
    prob = _toy_problem(n=n)
    prob = dataclasses.replace(prob, groups=np.zeros(n, np.int64))
    fam = resolve_family(prob)
    base = SolverConfig(block_size=mu, iterations=16,
                        track_objective=False)
    res = tune.tune(prob, base, cache_dir=str(tmp_path),
                    measure_fn=_fake_measure(prob, fam))
    assert res.config.block_size == mu


def _tall_sparse_operand(m=17_000, n=32, nnz=2_000, seed=0):
    from repro.core.types import SparseOperand

    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, n, nnz)
    # dedupe (from_coo requires duplicate-free triplets)
    keys = np.unique(rows.astype(np.int64) * n + cols)
    vals = rng.standard_normal(keys.size).astype(np.float32)
    return SparseOperand.from_coo(keys // n, keys % n, vals, (m, n))


def test_pallas_guard_only_checks_dispatched_spmm_shapes():
    """Regression: the guard rejected sparse linear-SVM configs because
    of the (m, s*mu) cross-block SpMM — a product only the kernelized
    SVM and logreg families dispatch. At m ~ 17k the cross block alone
    busts the VMEM cap, but the linear SVM's row-Gram fits fine."""
    import jax.numpy as jnp
    from repro.api import LogRegProblem, SVMProblem

    op = _tall_sparse_operand()
    b = np.sign(np.random.default_rng(1)
                .standard_normal(op.shape[0])).astype(np.float32)
    b[b == 0] = 1.0
    svm = SVMProblem(A=op, b=b, lam=1.0)            # kernel="linear"
    lr = LogRegProblem(A=op, b=b, lam=1e-3)
    svm_fam = resolve_family(svm)
    lr_fam = resolve_family(lr)
    assert tune.pallas_guards_ok(svm, svm_fam, 4, 2, jnp.float32)
    assert not tune.pallas_guards_ok(lr, lr_fam, 4, 2, jnp.float32)


def test_cache_key_includes_dtype(tmp_path):
    """An f32-calibrated machine must not be reused for f64 solves of
    the same regime (gamma/beta are ~2x off for f64 residents)."""
    import jax.numpy as jnp

    prob = _toy_problem()
    p32 = tune.cache_path(prob, "lasso", str(tmp_path),
                          dtype=jnp.float32)
    p64 = tune.cache_path(prob, "lasso", str(tmp_path),
                          dtype=jnp.float64)
    assert p32 != p64


def test_tune_with_explicit_machine_skips_measurement(tmp_path):
    """machine=<Machine> is pure model evaluation: no calibration, no
    cache file, no solves."""
    prob = _toy_problem()
    res = tune.tune(prob, SolverConfig(iterations=64),
                    machine=_latency_machine(), cache_dir=str(tmp_path),
                    guard_incumbent=False)
    assert res.calibration is None
    assert res.machine == _latency_machine()
    assert not os.listdir(tmp_path)


def test_measure_machine_returns_positive_params():
    """The microbench priors path (tune(machine='micro')): every
    parameter measured on this host is finite and positive."""
    mach = tune.measure_machine(repeats=2)
    vec = cost_model.machine_vector(mach)
    assert all(np.isfinite(v) and v > 0 for v in vec)


def test_symmetric_gram_selection_pays_packing_cost():
    """Regression: sym=True used to be strictly cheaper whenever
    beta > 0 (the 0.5*beta*W saving with no modeled cost), making the
    sweep decorative. The pack/unpack term must keep it OFF on a
    flop-bound (single-host-like) machine and ON on a bandwidth-bound
    one."""
    prob = _toy_problem()
    fam = resolve_family(prob)
    dims = tune.problem_dims(prob)
    base = SolverConfig(block_size=4, s=8, iterations=64)
    sym = dataclasses.replace(base, symmetric_gram=True)
    flop_bound = Machine("host", alpha=1e-6, beta=1e-12, gamma=1e-9,
                         kappa=1e-6)
    assert tune.predicted_solve_time(fam, dims, sym, flop_bound) \
        > tune.predicted_solve_time(fam, dims, base, flop_bound)
    bw_bound = Machine("net", alpha=1e-6, beta=1e-6, gamma=1e-12,
                       kappa=1e-9)
    assert tune.predicted_solve_time(fam, dims, sym, bw_bound) \
        < tune.predicted_solve_time(fam, dims, base, bw_bound)
    cfg = tune.select_config(prob, flop_bound,
                             SolverConfig(iterations=64))
    assert not cfg.symmetric_gram


def test_solve_tune_auto_rejects_sharded_backend():
    """Regression: tune='auto' calibrates with local P=1 pilot solves —
    silently applying it to backend='sharded' would tune for the wrong
    machine/topology, so the combination must be a loud error."""
    from jax.sharding import Mesh
    import jax

    from repro import api

    mesh = Mesh(np.array(jax.devices()), ("data",))
    with pytest.raises(ValueError, match="backend='local'"):
        api.solve(_toy_problem(), SolverConfig(iterations=4),
                  backend="sharded", mesh=mesh, tune="auto")


def test_select_rejects_inexecutable_explicit_grid():
    """An explicit grid is filtered to executable candidates (mu within
    the sampled axis) and an empty result is a loud error, not a None
    the caller dereferences."""
    prob = _toy_problem(n=96)
    cfg = tune.select_config(prob, _latency_machine(),
                             SolverConfig(iterations=32),
                             grid=[(4, 256), (8, 4)])
    assert cfg.block_size == 4              # the oversized mu dropped
    with pytest.raises(ValueError, match="no executable"):
        tune.select_config(prob, _latency_machine(),
                           SolverConfig(iterations=32), grid=[(4, 256)])


def test_explicit_grid_keeps_group_block_size():
    """Regression: an explicit grid used to bypass the structural-mu
    pin, proposing a block_size that violates the validated groups
    contract mid-tune."""
    n, mu = 96, 4
    prob = _toy_problem(n=n)
    prob = dataclasses.replace(prob,
                               groups=np.repeat(np.arange(n // mu), mu))
    cfg = tune.select_config(prob, _latency_machine(),
                             SolverConfig(block_size=mu, iterations=32),
                             grid=[(4, 2), (8, 2)])
    assert cfg.block_size == mu


def test_tune_calibrates_at_p1_even_when_selecting_for_p(tmp_path):
    """Regression: tune(P=8) used to fit P-scaled cost rows against
    pilot measurements that always run unsharded at P=1, corrupting
    the fitted machine. Calibration must fit at P=1; P only changes
    selection."""
    prob = _toy_problem()
    fam = resolve_family(prob)
    true = _flop_true_machine()
    dims = tune.problem_dims(prob)

    def measure_p1(cfg):
        # the pilot solve runs locally: its time follows the P=1 rows
        costs = fam.costs(dims, cfg.iterations, cfg.block_size, cfg.s, 1)
        return cost_model.predicted_time(costs, true)

    res = tune.tune(prob, SolverConfig(iterations=64), P=8,
                    cache_dir=str(tmp_path), measure_fn=measure_p1)
    assert res.machine.alpha == pytest.approx(true.alpha, rel=1e-4)
    assert res.machine.gamma == pytest.approx(true.gamma, rel=1e-4)


def test_cached_tune_runs_no_solves(tmp_path):
    """Regression: the incumbent guard used to run two full measured
    solves on EVERY tune() call, so repeat solve(tune='auto') of a
    cached regime still paid measurements — contradicting the cache's
    whole point. With the default guard mode, a cache hit is pure
    model evaluation."""
    prob = _toy_problem()
    fam = resolve_family(prob)
    tune.tune(prob, SolverConfig(iterations=64),
              cache_dir=str(tmp_path),
              measure_fn=_fake_measure(prob, fam))
    # second call: cache hit, no measure_fn available to fall back on —
    # any attempted real measurement would run actual (slow) solves;
    # instead we assert no guard measurement happened at all.
    second = tune.tune(prob, SolverConfig(iterations=64),
                       cache_dir=str(tmp_path))
    assert second.from_cache
    assert second.guard_times is None


def test_guard_honors_injected_measurements(tmp_path):
    """Regression: guard_incumbent=True with a measure_fn used to be
    silently skipped. The head-to-head must run through the injected
    measurements — and keep the incumbent when the injected timings
    contradict the model's selection."""
    prob = _toy_problem()
    fam = resolve_family(prob)
    model_measure = _fake_measure(prob, fam)
    base = SolverConfig(block_size=8, s=1, iterations=128,
                        track_objective=False)

    def contrarian(cfg):
        # pilot points follow the model (so calibration fits), but the
        # incumbent (s=1, mu=8) is measured as impossibly fast.
        if (cfg.s, cfg.block_size) == (base.s, base.block_size):
            return 1e-9
        return model_measure(cfg)

    res = tune.tune(prob, base, cache_dir=str(tmp_path),
                    guard_incumbent=True, measure_fn=contrarian)
    assert res.guard_times is not None
    assert res.config.s == base.s           # guard kept the incumbent
    assert res.config.block_size == base.block_size


def test_select_raises_on_empty_default_grid():
    """Regression: an empty DEFAULT candidate grid (group block size
    beyond the sampled axis) returned None instead of raising."""
    n = 8
    prob = _toy_problem(n=n)
    prob = dataclasses.replace(prob, groups=np.zeros(n, np.int64))
    with pytest.raises(ValueError, match="no executable"):
        tune.select_config(prob, _latency_machine(),
                           SolverConfig(block_size=16, iterations=8))
