"""Kernel correctness sweeps: Pallas (interpret mode) vs jnp oracle over
shapes and dtypes, per the repo kernel convention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import (attention_chunked,
                                               flash_attention)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gram.ops import gram_and_proj, gram_t
from repro.kernels.gram.ref import gram_and_proj_ref, gram_t_ref
from repro.kernels.sa_inner.ops import sa_inner_loop
from repro.kernels.sa_inner.ref import sa_inner_ref
from repro.kernels import sa_inner, spmm, svm_inner
from repro.kernels.spmm.ref import ell_spmm_ref
from repro.kernels.svm_inner.ops import svm_inner_loop
from repro.kernels.svm_inner.ref import svm_inner_ref

KEY = jax.random.key(0)


@pytest.mark.parametrize("m,p,q", [(300, 65, 33), (1024, 128, 130),
                                   (64, 8, 8), (513, 257, 3),
                                   (129, 16, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_kernel_sweep(m, p, q, dtype):
    x = jax.random.normal(KEY, (m, p), dtype)
    y = jax.random.normal(jax.random.fold_in(KEY, 1), (m, q), dtype)
    out = gram_t(x, y, interpret=True)
    ref = gram_t_ref(x, y)
    tol = 2e-3 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol * float(m) ** 0.5)


def test_gram_and_proj_fused_matches_ref():
    Y = jax.random.normal(KEY, (256, 48))
    V = jax.random.normal(jax.random.fold_in(KEY, 2), (256, 2))
    G1, P1 = gram_and_proj(Y, V, interpret=True)
    G2, P2 = gram_and_proj_ref(Y, V)
    np.testing.assert_allclose(np.asarray(G1), np.asarray(G2), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(P1), np.asarray(P2), rtol=1e-4,
                               atol=1e-3)


@pytest.mark.parametrize("s,mu", [(4, 1), (8, 4), (16, 2), (3, 5)])
def test_sa_inner_kernel_sweep(s, mu):
    n = 64
    G0 = jax.random.normal(KEY, (128, s * mu))
    G = G0.T @ G0
    yp = jax.random.normal(jax.random.fold_in(KEY, 3), (s, mu))
    zp = jax.random.normal(jax.random.fold_in(KEY, 4), (s, mu))
    zv = 0.1 * jax.random.normal(jax.random.fold_in(KEY, 5), (s, mu))
    idx = jax.random.randint(jax.random.fold_in(KEY, 6), (s, mu), 0, n)
    th = jnp.linspace(0.5, 0.1, s)
    coefU = (1.0 - 16 * th) / (th * th)
    dz1, e1 = sa_inner_loop(G, yp, zp, zv, idx, th, coefU, q=16.0,
                            lam1=0.3, interpret=True)
    dz2, e2 = sa_inner_ref(G, yp, zp, zv, idx, th, coefU, 16.0, 0.3)
    np.testing.assert_allclose(np.asarray(dz1), np.asarray(dz2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-4)


@pytest.mark.parametrize("s,mu", [(4, 1), (8, 4), (16, 2), (3, 5)])
@pytest.mark.parametrize("nu", [1.0, float("inf")])
def test_svm_inner_kernel_sweep(s, mu, nu):
    """svm_inner Pallas (interpret) vs jnp oracle, hinge (finite nu) and
    squared hinge (nu = inf), with colliding indices."""
    m = 12                                  # small -> forced collisions
    G0 = jax.random.normal(KEY, (64, s * mu))
    G = G0.T @ G0 + 0.5 * jnp.eye(s * mu)
    proj = jax.random.normal(jax.random.fold_in(KEY, 3), (s, mu))
    b = jnp.sign(jax.random.normal(jax.random.fold_in(KEY, 4), (s, mu)))
    b = jnp.where(b == 0, 1.0, b)
    a_vals = 0.2 * jax.random.uniform(jax.random.fold_in(KEY, 5), (s, mu))
    idx = jax.random.randint(jax.random.fold_in(KEY, 6), (s, mu), 0, m)
    t1, d1 = svm_inner_loop(G, proj, b, a_vals, idx, gamma=0.3, nu=nu,
                            interpret=True)
    t2, d2 = svm_inner_ref(G, proj, b, a_vals, idx, 0.3, nu)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mod,name", [(sa_inner, "sa_inner"),
                                      (svm_inner, "svm_inner")])
def test_inner_impl_contract(mod, name):
    """The dispatch decision is queryable, and an over-VMEM Pallas
    request warns (once) and falls back to ref instead of silently
    mislabeling the path."""
    from repro.kernels import dispatch

    assert mod.inner_impl(8, 4, False) == "ref"
    assert mod.inner_impl(8, 4, True) == "pallas"
    big_s = 4096                            # (s*mu)^2 * 4 B >> 8 MB cap
    assert not mod.vmem_ok(big_s, 4)
    dispatch._warned.discard((name, big_s, 4, 4))
    with pytest.warns(UserWarning, match="falling back"):
        assert mod.inner_impl(big_s, 4, True) == "ref"
    # one-time: a second query must not warn again.
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert mod.inner_impl(big_s, 4, True) == "ref"


@pytest.mark.parametrize("mod,name", [(sa_inner, "sa_inner"),
                                      (svm_inner, "svm_inner")])
def test_inner_vmem_guard_is_dtype_aware(mod, name):
    """A near-cap Gram block fits at 4 B/element but NOT at 8 B
    (float64) — the guard must count the actual itemsize (regression:
    it hardcoded 4 B, so f64 solves dispatched Pallas with 2x the
    modeled VMEM)."""
    from repro.kernels import dispatch

    s, mu = 181, 8                          # (s*mu)^2 = 1448^2 ~ 2.1e6
    assert mod.vmem_ok(s, mu)               # f32: just under the cap
    assert mod.vmem_ok(s, mu, itemsize=4)
    assert not mod.vmem_ok(s, mu, itemsize=8)
    assert mod.inner_impl(s, mu, True, itemsize=4) == "pallas"
    dispatch._warned.discard((name, s, mu, 8))
    with pytest.warns(UserWarning, match="falling back"):
        assert mod.inner_impl(s, mu, True, itemsize=8) == "ref"


def test_grouped_impl_label_mixed():
    """An over-VMEM s falls back to ref for the full groups while a
    small remainder tail still runs Pallas — the surfaced label must
    report both paths, not just the full groups'."""
    from repro.core.sa_loop import grouped_impl_label
    from repro.kernels.svm_inner import inner_impl

    assert grouped_impl_label(inner_impl, 64, 8, 4, True) == "pallas"
    assert grouped_impl_label(inner_impl, 64, 8, 4, False) == "ref"
    big_s = 4096                            # over-VMEM full groups
    assert grouped_impl_label(inner_impl, big_s + 1, big_s, 4, True) \
        == "ref+pallas"
    assert grouped_impl_label(inner_impl, 3, 8, 1, True) == "pallas"


@pytest.mark.parametrize("R,C,Q,density", [(12, 40, 5, 0.3),
                                           (33, 128, 17, 0.05),
                                           (64, 200, 1, 0.5),
                                           (7, 16, 130, 0.4)])
def test_spmm_kernel_sweep(R, C, Q, density):
    """Blocked-ELL SpMM: Pallas (interpret) vs jnp oracle vs dense,
    including lane-padded Q and rows whose block counts differ."""
    from repro.core.types import SparseOperand

    rng = np.random.default_rng(R + C + Q)
    S = rng.standard_normal((R, C)).astype(np.float32)
    S[rng.random((R, C)) >= density] = 0.0
    op = SparseOperand.from_dense(S)
    D = jnp.asarray(rng.standard_normal((C, Q)).astype(np.float32))
    dense = S @ np.asarray(D)
    ref = spmm.ell_spmm(op.row_vals, op.row_cols, op.row_blocks, D,
                        ell_block=op.ell_block)
    pal = spmm.ell_spmm(op.row_vals, op.row_cols, op.row_blocks, D,
                        ell_block=op.ell_block, interpret=True)
    np.testing.assert_allclose(np.asarray(ref), dense, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_spmm_ref_keeps_caller_dtype():
    """The oracle accumulates in the caller's dtype rather than forcing
    f32 — the f64 sparse-vs-dense 1e-10 tier (tests/test_sparse.py
    subprocess) depends on this; here we pin the no-forced-cast
    behavior in-process via bf16 (f64 needs a subprocess, see DESIGN.md
    test conventions)."""
    vals = jnp.asarray([[1.0, 2.0]], jnp.bfloat16)
    idx = jnp.asarray([[0, 1]], jnp.int32)
    out = ell_spmm_ref(vals, idx, jnp.eye(2, dtype=jnp.bfloat16))
    assert out.dtype == jnp.bfloat16
    out32 = ell_spmm_ref(vals.astype(jnp.float32), idx,
                         jnp.eye(2, dtype=jnp.float32))
    assert out32.dtype == jnp.float32


def test_spmm_impl_contract():
    """The dispatch decision is queryable, and an over-VMEM Pallas
    request warns (once) and falls back to ref — same contract as the
    inner-loop kernels."""
    from repro.kernels import dispatch

    assert spmm.spmm_impl(8, 8, 64, 9, False) == "ref"
    assert spmm.spmm_impl(8, 8, 64, 9, True) == "pallas"
    big = (4096, 64, 100_000, 256)          # resident D >> 8 MB cap
    assert not spmm.spmm_vmem_ok(*big)
    dispatch._warned.discard(("spmm",) + big + (4,))
    with pytest.warns(UserWarning, match="falling back"):
        assert spmm.spmm_impl(*big, True) == "ref"
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert spmm.spmm_impl(*big, True) == "ref"


def test_spmm_vmem_guard_is_dtype_aware():
    """Same dtype-awareness contract for the blocked-ELL SpMM guard: a
    working set just under the cap at 4 B/element must be rejected at
    8 B (the int32 index plane stays 4 B either way)."""
    from repro.kernels import dispatch

    near = (64, 8, 16_000, 1)               # ~8.23 MB at 4 B/element
    assert spmm.spmm_vmem_ok(*near)
    assert spmm.spmm_vmem_ok(*near, itemsize=4)
    assert not spmm.spmm_vmem_ok(*near, itemsize=8)
    assert spmm.spmm_impl(*near, True, itemsize=4) == "pallas"
    dispatch._warned.discard(("spmm",) + near + (8,))
    with pytest.warns(UserWarning, match="falling back"):
        assert spmm.spmm_impl(*near, True, itemsize=8) == "ref"


def test_grouped_spmm_label_mixed():
    """A tail group whose shapes dispatch differently from the full
    groups must surface both labels."""
    shape_ok = lambda g: (g * 4, 8, 64, g * 4 + 1)
    assert spmm.grouped_spmm_label(64, 8, shape_ok, True) == "pallas"
    assert spmm.grouped_spmm_label(64, 8, shape_ok, False) == "ref"

    def shape_mixed(g):                     # full groups over-VMEM
        return (g, 64, 100_000, 256) if g > 4 else (g, 8, 64, g)

    with pytest.warns(UserWarning, match="falling back"):
        from repro.kernels import dispatch
        dispatch._warned.discard(("spmm", 64, 64, 100_000, 256, 4))
        assert spmm.grouped_spmm_label(65, 64, shape_mixed, True) \
            == "ref+pallas"


ATTN_CASES = [
    # B, Hq, Hkv, Sq, Sk, D, causal, window
    (2, 4, 2, 128, 128, 64, True, 0),
    (1, 8, 2, 256, 256, 64, True, 64),
    (1, 4, 4, 100, 100, 32, True, 0),       # padding path
    (1, 2, 1, 1, 384, 64, True, 0),         # decode
    (1, 2, 1, 1, 384, 64, True, 128),       # decode + window
    (2, 2, 2, 64, 64, 128, False, 0),       # bidirectional (encoder)
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel_sweep(case, dtype):
    B, Hq, Hkv, Sq, Sk, D, causal, window = case
    q = (jax.random.normal(KEY, (B, Hq, Sq, D)) * 0.3).astype(dtype)
    k = (jax.random.normal(jax.random.fold_in(KEY, 7),
                           (B, Hkv, Sk, D)) * 0.3).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 8),
                          (B, Hkv, Sk, D)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("Sq,Sk,window", [(256, 256, 0), (256, 256, 64),
                                          (100, 228, 0), (512, 512, 100)])
def test_attention_chunked_matches_ref(Sq, Sk, window):
    B, Hq, Hkv, D = 2, 4, 2, 32
    q = jax.random.normal(KEY, (B, Hq, Sq, D)) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 9), (B, Hkv, Sk, D)) * 0.3
    v = jax.random.normal(jax.random.fold_in(KEY, 10), (B, Hkv, Sk, D))
    out = attention_chunked(q, k, v, causal=True, window=window,
                            q_chunk=64)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3)


def test_flash_attention_backward_matches_ref():
    B, Hq, Hkv, S, D = 1, 2, 1, 64, 32
    q = jax.random.normal(KEY, (B, Hq, S, D)) * 0.3
    k = jax.random.normal(jax.random.fold_in(KEY, 11), (B, Hkv, S, D)) * 0.3
    v = jax.random.normal(jax.random.fold_in(KEY, 12), (B, Hkv, S, D))

    def f_kernel(q, k, v):
        return flash_attention(q, k, v, causal=True, interpret=True).sum()

    def f_ref(q, k, v):
        return attention_ref(q, k, v, causal=True).sum()

    g1 = jax.grad(f_kernel, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)
