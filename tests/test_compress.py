"""repro.optim.compress invariants, deterministically (the
hypothesis-based error-bound property lives in test_optim.py, which
skips wholesale when hypothesis is unavailable — these must always
run): exact int8 roundtrip on the quantization grid, and the
error-feedback identities that make compressed allreduce safe."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.compress import (ErrorFeedback, dequantize_int8,
                                  quantize_int8)


def test_quantize_int8_roundtrip_exact_on_grid():
    """Values already on the quantization grid survive the int8 roundtrip
    exactly, and requantizing a dequantized tensor is idempotent (the
    codec is a projection)."""
    scale0 = 0.5
    x = jnp.asarray([-127, -64, 0, 1, 127], jnp.float32) * scale0
    q, scale = quantize_int8(x)
    back = dequantize_int8(q, scale)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
    q2, scale2 = quantize_int8(back)
    assert np.array_equal(np.asarray(q2), np.asarray(q))
    assert float(scale2) == float(scale)


def test_error_feedback_step_identity():
    """The defining EF invariant, per step and exactly: the corrected
    gradient splits into applied + residual with no leakage —
    (g + r_in) == q * scale + r_out bitwise in f32. This is what makes
    the cumulative applied update track the cumulative true gradient."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim.compress import compressed_psum

    mesh = jax.make_mesh((1,), ("dp",))
    rng = np.random.default_rng(0)

    def body(gg, res):
        out, ef2 = compressed_psum({"g": gg},
                                   ErrorFeedback(residual={"g": res}),
                                   "dp", n_shards=1)
        return out["g"], ef2.residual["g"]

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                   out_specs=(P(), P()), check_rep=False)
    r = jnp.zeros(16)
    for i in range(8):
        g = jnp.asarray(rng.standard_normal(16), jnp.float32) * (10.0 ** (i - 4))
        applied, r_new = fn(g, r)
        np.testing.assert_array_equal(np.asarray(g + r),
                                      np.asarray(applied + r_new))
        # residual bounded by half a quantization step of the corrected
        # tensor (the EF contraction property).
        step = float(jnp.max(jnp.abs(g + r))) / 127.0
        assert float(jnp.max(jnp.abs(r_new))) <= 0.5 * step + 1e-7
        r = r_new


def test_error_feedback_accumulation_drains():
    """A constant gradient too small to survive quantization alone is
    NOT lost: the residual accumulates until it crosses a quantization
    step and drains into the applied update (EF's raison d'etre)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim.compress import compressed_psum

    mesh = jax.make_mesh((1,), ("dp",))

    def body(gg, res):
        out, ef2 = compressed_psum({"g": gg},
                                   ErrorFeedback(residual={"g": res}),
                                   "dp", n_shards=1)
        return out["g"], ef2.residual["g"]

    fn = shard_map(body, mesh=mesh, in_specs=(P(), P()),
                   out_specs=(P(), P()), check_rep=False)
    # one dominant coordinate sets the scale; the tiny coordinate is far
    # below scale/2 and would round to zero every step without EF.
    g = jnp.asarray([1.0, 1e-3], jnp.float32)
    r = jnp.zeros(2)
    applied_tiny = 0.0
    for _ in range(40):
        applied, r = fn(g, r)
        applied_tiny += float(applied[1])
    # without EF: applied_tiny == 0 after every step. With EF the
    # cumulative applied value tracks 40 * 1e-3 to one quantization step.
    assert abs(applied_tiny - 40e-3) <= 1.0 / 127.0 + 1e-6
    assert applied_tiny > 0.0
