"""The generic SA engine (repro.core.engine) in isolation: grouped
scheduling, remainder tails, and the schedule-window contract that every
momentum family (accelerated Lasso's theta, CA-SFISTA's t-sequence)
relies on. Uses a minimal probe FamilyProgram so the invariants are
checked independently of any real solver's arithmetic."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolverConfig
from repro.core.engine import (Ctx, FamilyProgram, grouped_impl_label,
                               run_grouped, run_program)
from repro.core.linalg import fista_t_schedule, sample_block


def _probe_program():
    """A do-nothing family whose ``defer`` emits the schedule window's
    t_cur slice as the per-iteration 'objective' — so the (H,) trace IS
    the schedule prefix the engine actually delivered to the family."""
    def setup(problem, cfg, axis_name, x0, carry0):
        x = jnp.zeros((4,), cfg.dtype) if carry0 is None \
            else jnp.asarray(carry0["x"], cfg.dtype)
        return Ctx(n=4), (x,)

    return FamilyProgram(
        name="probe",
        setup=setup,
        sample=lambda ctx, key: sample_block(key, ctx.n, 1),
        assemble=lambda ctx, carry, idxs, s: (None, jnp.zeros((1, 1))),
        reduce=lambda ctx, local, idxs, s: local,
        inner=lambda ctx, carry, handle, payload, idxs, win, s: (carry,
                                                                 None),
        defer=lambda ctx, carry, handle, out, payload, idxs, win, s: (
            carry, win[1]),
        finalize=lambda ctx, carry, sched: (carry[0], {}),
        carry_names=("x",),
        schedule=lambda ctx, cfg, total: fista_t_schedule(total, cfg.dtype),
    )


@pytest.mark.parametrize("H,s", [(12, 4), (10, 4), (3, 8), (13, 5)])
def test_tail_window_preserves_schedule_prefix(H, s):
    """Remainder-tail regression (the momentum-carry audit): the tail
    group at H mod s must read the SAME precomputed schedule array at
    its global offset — iteration h always sees t_h, bitwise, no matter
    how H splits into groups."""
    prog = _probe_program()
    cfg = SolverConfig(block_size=1, iterations=H, s=s)
    res = run_program(prog, None, cfg)
    ts = np.asarray(fista_t_schedule(H, cfg.dtype))
    assert np.array_equal(np.asarray(res.objective), ts[1:H + 1])


def test_resumed_tail_window_continues_schedule():
    """A resume from a SolveState mid-horizon keeps reading the global
    schedule: windows are sliced at start + group offset, so the resumed
    trace equals the uninterrupted one's suffix bitwise — including when
    the split leaves the resumed run a remainder tail."""
    prog = _probe_program()
    H1, H2, s = 6, 7, 4            # both legs end in a tail group
    a = run_program(prog, None, SolverConfig(block_size=1, iterations=H1,
                                             s=s))
    assert int(a.aux["state"].iteration) == H1
    b = run_program(prog, None, SolverConfig(block_size=1, iterations=H2,
                                             s=s), state=a.aux["state"])
    full = np.asarray(fista_t_schedule(H1 + H2, jnp.float32))
    assert np.array_equal(np.asarray(a.objective), full[1:H1 + 1])
    assert np.array_equal(np.asarray(b.objective), full[H1 + 1:H1 + H2 + 1])


def test_run_grouped_trip_structure():
    """floor(H/s) full groups + one H mod s tail, exactly H iterations;
    each group call sees its global start offset."""
    calls = []

    def group(carry, start, s_grp):
        calls.append((int(start) if not hasattr(start, "shape") else None,
                      s_grp))
        return carry, jnp.zeros((s_grp,), jnp.float32)

    _, objs = run_grouped(group, (), H=11, s=4, dtype=jnp.float32)
    # one traced scan call for the full groups + one tail call of 3
    assert [s for _, s in calls] == [4, 3]
    assert objs.shape == (11,)


def test_grouped_impl_label_mixed_tail():
    """A tail that dispatches differently from the full groups is
    surfaced, not silently mislabeled."""
    impl = lambda s, mu, use_pallas, itemsize: \
        "pallas" if s * mu <= 8 else "ref"
    assert grouped_impl_label(impl, H=32, s=4, mu=2,
                              use_pallas=True) == "pallas"
    assert grouped_impl_label(impl, H=34, s=16, mu=2,
                              use_pallas=True) == "ref+pallas"
    assert grouped_impl_label(impl, H=3, s=16, mu=2,
                              use_pallas=True) == "pallas"
