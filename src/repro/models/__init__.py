"""Model zoo: composable JAX blocks covering the assigned architectures."""
