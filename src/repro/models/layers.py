"""Shared model layers: norms, rotary embeddings, GQA attention (full /
sliding-window, train & cached-decode paths), SwiGLU MLP, and the
capacity-based MoE block with expert-parallel sharding.

Functional style: ``init_*`` returns a param dict; ``apply``-style
functions are pure. All matmuls run in the config dtype (bf16) with f32
for norms, softmax, router logits and attention accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.flash_attention.ops import flash_attention
from repro.parallel.sharding import get_abstract_mesh as _get_abstract_mesh


def maybe_shard(x, spec: P):
    """with_sharding_constraint that degrades to identity when no mesh (or
    a mesh lacking the named axes) is in context — so model code runs
    unchanged on a single CPU device and under the production mesh."""
    try:
        mesh = _get_abstract_mesh()
        if mesh.empty:
            return x
        names = set()
        for part in spec:
            if part is None:
                continue
            names.update((part,) if isinstance(part, str) else part)
        if not names.issubset(set(mesh.axis_names)):
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def init_norm(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (S,) or (B, S) absolute positions."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                  # (D/2,)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
        ang = ang[None, None]                           # (1, 1, S, D/2)
    else:
        ang = positions[:, None, :, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., ::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, n_heads * head_dim), dtype),
        "wk": _dense_init(ks[1], (d_model, n_kv_heads * head_dim), dtype),
        "wv": _dense_init(ks[2], (d_model, n_kv_heads * head_dim), dtype),
        "wo": _dense_init(ks[3], (n_heads * head_dim, d_model), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim).transpose(0, 2, 1, 3)
    k = k.reshape(B, S, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = v.reshape(B, S, n_kv_heads, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attention_train(params, x, *, n_heads, n_kv_heads, head_dim,
                    rope_theta, window: int = 0, causal: bool = True,
                    positions=None, use_pallas: bool = False,
                    kv_override=None):
    """Full-sequence attention (training / prefill). Returns (out, (k, v))
    so prefill can seed the decode cache. ``kv_override`` supplies
    externally computed (k, v) — used by cross-attention."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    if kv_override is not None:
        k, v = kv_override
    elif rope_theta > 0:
        if positions is None:
            positions = jnp.arange(S)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    elif positions is None:
        pass
    o = flash_attention(q, k, v, causal=causal, window=window,
                        use_pallas=use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, n_heads * head_dim)
    return o @ params["wo"], (k, v)


# Perf-iteration flag (EXPERIMENTS.md §Perf): the baseline decode
# materializes the GQA head repeat of the cache (matching the reference);
# the grouped path contracts (Hkv, g) without the repeat — on the sharded
# split-KV cache the repeat forces an involuntary full rematerialization
# in GSPMD (observed in the dry-run logs).
DECODE_GROUPED_GQA = False


def attention_decode(params, x, cache_k, cache_v, pos, *, n_heads,
                     n_kv_heads, head_dim, rope_theta, window: int = 0):
    """Single-token decode against a KV cache.

    x: (B, 1, D); cache_k/v: (B, Hkv, S, D); pos: scalar — the position of
    the new token (cache entries [0, pos) are valid; the new KV is written
    at index pos, or at pos % window for sliding-window ring caches).
    Returns (out, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    S = cache_k.shape[2]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    if rope_theta > 0:
        posv = jnp.asarray(pos)[None]
        q = apply_rope(q, posv, rope_theta)
        k = apply_rope(k, posv, rope_theta)
    slot = pos % S if window > 0 else jnp.minimum(pos, S - 1)
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, 0, slot, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, 0, slot, 0))

    group = n_heads // n_kv_heads
    kpos = jnp.arange(S)
    valid = kpos <= pos if window <= 0 else \
        (kpos <= pos) | (pos >= S)       # ring cache: all slots live once full
    if DECODE_GROUPED_GQA:
        qg = q.reshape(B, n_kv_heads, group, head_dim).astype(jnp.float32)
        kf = cache_k.astype(jnp.float32)
        vf = cache_v.astype(jnp.float32)
        scores = jnp.einsum("bhgd,bhkd->bhgk", qg, kf) / (head_dim ** 0.5)
        scores = jnp.where(valid[None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhgk,bhkd->bhgd", probs, vf).astype(x.dtype)
        o = o.reshape(B, 1, n_heads * head_dim)
        return o @ params["wo"], cache_k, cache_v
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(cache_k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(cache_v.astype(jnp.float32), group, axis=1)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) / (head_dim ** 0.5)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", probs, vf).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(B, 1, n_heads * head_dim)
    return o @ params["wo"], cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype,
             mlp_type: str = "swiglu") -> Dict:
    ks = jax.random.split(key, 3)
    p = {
        "w_up": _dense_init(ks[1], (d_model, d_ff), dtype),
        "w_down": _dense_init(ks[2], (d_ff, d_model), dtype),
    }
    if mlp_type == "swiglu":
        p["w_gate"] = _dense_init(ks[0], (d_model, d_ff), dtype)
    return p


def mlp(params, x, act: str = "silu"):
    if "w_gate" in params:          # gated (SwiGLU-style)
        h = act_fn(act)(x @ params["w_gate"]) * (x @ params["w_up"])
    else:                           # plain 2-matrix MLP (whisper)
        h = act_fn(act)(x @ params["w_up"])
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# MoE (capacity-based dispatch, expert-parallel)
# ---------------------------------------------------------------------------

# Perf-iteration flag (EXPERIMENTS.md §Perf): shard the dispatch buffers'
# capacity dim over BOTH data and model axes (256-way instead of 16-way)
# when EP is unavailable — hypothesis: smaller resident buffers and less
# resharding traffic around the expert GEMMs.
MOE_BUF_2D = False


def _moe_buffer_spec(n_experts: int, ep_axis: Optional[str]):
    """Sharding for the (E, C, D) dispatch buffers: experts over the axis
    when divisible (EP), else capacity over the axis (keeps the all-to-all
    local while expert-TP splits the FFN dims)."""
    if ep_axis is None:
        return None
    try:
        mesh = _get_abstract_mesh()
        if mesh.empty or ep_axis not in mesh.axis_names:
            return None
        size = mesh.shape[ep_axis]
    except Exception:
        return None
    if n_experts % size == 0:
        return P(ep_axis, None, None)
    if MOE_BUF_2D and "data" in mesh.axis_names:
        return P(None, ("data", ep_axis), None)
    return P(None, ep_axis, None)


def init_moe(key, d_model: int, d_ff: int, n_experts: int, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    shape = (n_experts, d_model, d_ff)

    def einit(k, s):
        return (jax.random.normal(k, s, jnp.float32)
                * (s[1] ** -0.5)).astype(dtype)

    return {
        "router": _dense_init(ks[0], (d_model, n_experts), jnp.float32),
        "w_gate": einit(ks[1], shape),
        "w_up": einit(ks[2], shape),
        "w_down": einit(ks[3], (n_experts, d_ff, d_model)),
    }


def _moe_tokens(params, xf, *, n_experts: int, top_k: int,
                capacity_factor: float, act: str,
                ep_axis: Optional[str]):
    """Capacity-based top-k MoE over a flat token block (T, D)."""
    T, D = xf.shape
    K = top_k

    logits = (xf.astype(jnp.float32) @ params["router"])      # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, tope = jax.lax.top_k(probs, K)                      # (T, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((n_experts,), jnp.float32).at[tope.reshape(-1)].add(
        1.0 / (T * K))
    aux = n_experts * jnp.sum(me * ce)

    C = max(int(T * K / n_experts * capacity_factor), 4)
    e_flat = tope.reshape(T * K)
    onehot = jax.nn.one_hot(e_flat, n_experts, dtype=jnp.int32)
    # position-in-expert via log-depth associative scan (a plain cumsum
    # lowers to reduce-window, which XLA's cost model charges O(n^2)).
    cum = jax.lax.associative_scan(jnp.add, onehot, axis=0)
    pos_flat = (cum - 1)[jnp.arange(T * K), e_flat]           # (T*K,)
    keep = (pos_flat < C)
    pos_c = jnp.where(keep, pos_flat, 0)

    x_rep = jnp.repeat(xf, K, axis=0)                         # (T*K, D)
    buf = jnp.zeros((n_experts, C, D), xf.dtype)
    buf = buf.at[e_flat, pos_c].add(
        jnp.where(keep[:, None], x_rep, 0).astype(xf.dtype))
    # EP when experts divide the axis, otherwise shard token capacity
    # (expert-TP handles the FFN dims through the weight shardings).
    ep_spec = _moe_buffer_spec(n_experts, ep_axis)
    if ep_spec is not None:
        buf = maybe_shard(buf, ep_spec)

    h = act_fn(act)(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    if ep_spec is not None:
        out_buf = maybe_shard(out_buf, ep_spec)

    gathered = out_buf[e_flat, pos_c]                         # (T*K, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    w_flat = topw.reshape(T * K, 1).astype(gathered.dtype)
    out = (gathered * w_flat).reshape(T, K, D).sum(axis=1)
    return out, aux


# token blocks larger than this are processed by a scan over chunks —
# bounds the dispatch buffers (x_rep, (E, C, D)) at chunk granularity.
MOE_CHUNK_TOKENS = 1 << 17


def moe(params, x, *, n_experts: int, top_k: int,
        capacity_factor: float = 1.25, act: str = "silu",
        ep_axis: Optional[str] = "model",
        chunk_tokens: Optional[int] = None):
    """GShard-style capacity-based top-k MoE (see _moe_tokens).

    Token blocks beyond ``chunk_tokens`` are processed chunkwise (capacity
    applies per chunk — slightly different drop behaviour, recorded in
    DESIGN.md). Returns (out, aux_loss).
    """
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    if chunk_tokens is None:
        chunk_tokens = MOE_CHUNK_TOKENS   # read at call time (perf knob)
    kwargs = dict(n_experts=n_experts, top_k=top_k,
                  capacity_factor=capacity_factor, act=act,
                  ep_axis=ep_axis)
    if chunk_tokens and T > chunk_tokens and T % chunk_tokens == 0:
        nch = T // chunk_tokens

        def body(_, xc):
            out, aux = _moe_tokens(params, xc, **kwargs)
            return None, (out, aux)

        from repro.kernels.flash_attention import ops as _fops
        _, (outs, auxs) = jax.lax.scan(
            body, None, xf.reshape(nch, chunk_tokens, D),
            unroll=nch if _fops._COST_EXACT else 1)
        return outs.reshape(B, S, D), jnp.mean(auxs)
    out, aux = _moe_tokens(params, xf, **kwargs)
    return out.reshape(B, S, D), aux
