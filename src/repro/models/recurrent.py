"""Recurrent / state-space layers: a chunkwise gated linear-recurrence
primitive (the TPU-native form of Mamba-2/SSD, GLA, RetNet and mLSTM) plus
the blocks built on it, and the strictly sequential sLSTM.

TPU adaptation note (DESIGN.md §Hardware adaptation): CUDA Mamba uses a
fused selective-scan kernel over a diagonal SSM state. The TPU-native
equivalent is the *chunkwise* algorithm: within a chunk of length C the
recurrence is computed in closed form with an MXU-friendly (C x C)
decay-masked matmul; across chunks a (d_k x d_v) state is carried by a
scan over T/C steps. States materialize only at chunk boundaries, bounding
activation memory at T/C * d_k * d_v instead of T * d_k * d_v.

  o_t = q_t . S_t,   S_t = a_t * S_{t-1} + k_t v_t^T          (per head)

with input-dependent scalar-per-head decay a_t in (0, 1] — the Mamba-2 /
SSD simplification of Mamba-1's per-channel decay (recorded as an
assumption change). ``ssm_state`` from the configs is the key dim d_k.

mLSTM (xLSTM) is the same recurrence with exponential input gates folded
into k and a normalizer row n_t = a_t n_{t-1} + k_t tracked alongside
(output h = (S q) / max(|n . q|, 1)); the log-domain max-stabilizer of the
paper is replaced by f32 accumulation (assumption change, DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, act_fn


# ---------------------------------------------------------------------------
# Chunkwise gated linear recurrence (shared primitive)
# ---------------------------------------------------------------------------

def chunked_gla(q, k, v, log_a, *, chunk: int = 128,
                normalize: bool = False, state0=None, norm0=None):
    """q, k: (B, H, T, dk); v: (B, H, T, dv); log_a: (B, H, T) <= 0.

    Returns (o (B, H, T, dv), final_state (B, H, dk, dv), final_norm).
    ``normalize=True`` adds the mLSTM normalizer denominator.
    """
    B, H, T, dk = q.shape
    dv = v.shape[-1]
    C = min(chunk, T)
    if T % C != 0:
        raise ValueError(f"sequence length {T} not a multiple of chunk {C}")
    N = T // C
    f32 = jnp.float32

    qc = q.astype(f32).reshape(B, H, N, C, dk)
    kc = k.astype(f32).reshape(B, H, N, C, dk)
    vc = v.astype(f32).reshape(B, H, N, C, dv)
    la = log_a.astype(f32).reshape(B, H, N, C)

    cum = jnp.cumsum(la, axis=-1)                     # within-chunk cumsum
    total = cum[..., -1]                              # (B, H, N)

    # ---- intra-chunk: decay-masked (C x C) attention matmul ------------
    # scores[i, j] = (q_i . k_j) * exp(cum_i - cum_j)  for j <= i
    rel = cum[..., :, None] - cum[..., None, :]       # (B, H, N, C, C)
    causal = jnp.tril(jnp.ones((C, C), bool))
    decay = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jnp.einsum("bhnid,bhnjd->bhnij", qc, kc) * decay
    o_intra = jnp.einsum("bhnij,bhnjv->bhniv", scores, vc)
    # normalizer: q_i . n_i = sum_j decay_ij (q_i . k_j) = row-sum of scores
    n_intra = scores.sum(-1) if normalize else None

    # ---- inter-chunk: scan over chunk boundaries ------------------------
    # contribution of state S entering the chunk:  o_i += exp(cum_i) q_i S
    # state update: S' = exp(total) S + sum_j exp(total - cum_j) k_j v_j^T
    k_scaled = kc * jnp.exp(total[..., None, None] - cum[..., None])
    kv = jnp.einsum("bhnjd,bhnjv->bhndv", k_scaled, vc)   # per-chunk outer
    ksum = k_scaled.sum(axis=-2) if normalize else None   # (B, H, N, dk)
    q_scaled = qc * jnp.exp(cum[..., None])

    S0 = jnp.zeros((B, H, dk, dv), f32) if state0 is None \
        else state0.astype(f32)
    n0 = jnp.zeros((B, H, dk), f32) if norm0 is None else norm0.astype(f32)

    def body(carry, xs):
        S, n = carry
        qs, kv_n, tot, ks = xs
        o_inter = jnp.einsum("bhid,bhdv->bhiv", qs, S)
        n_inter = jnp.einsum("bhid,bhd->bhi", qs, n)
        S = jnp.exp(tot)[..., None, None] * S + kv_n
        n = jnp.exp(tot)[..., None] * n + ks
        return (S, n), (o_inter, n_inter)

    xs = (q_scaled.transpose(2, 0, 1, 3, 4), kv.transpose(2, 0, 1, 3, 4),
          total.transpose(2, 0, 1),
          (ksum if normalize else jnp.zeros((B, H, N, dk), f32))
          .transpose(2, 0, 1, 3))
    # NOTE: no cost-exact unroll here — the O(T*C) intra-chunk matmuls
    # are batched OUTSIDE this scan (counted exactly); the per-chunk
    # boundary terms inside are O(dk*dv) and negligible (DESIGN.md).
    (S, n), (o_inter, n_inter) = jax.lax.scan(body, (S0, n0), xs)
    o = o_intra + o_inter.transpose(1, 2, 0, 3, 4)

    if normalize:
        denom = n_intra + n_inter.transpose(1, 2, 0, 3).reshape(B, H, N, C)
        denom = jnp.maximum(jnp.abs(denom), 1.0)[..., None]
        o = o / denom
    return (o.reshape(B, H, T, dv).astype(q.dtype),
            S.astype(f32), n.astype(f32))


def gla_step(q, k, v, log_a, state, norm=None, *, normalize: bool = False):
    """Single-token recurrence step (decode). q/k: (B, H, dk); v: (B, H, dv);
    log_a: (B, H); state: (B, H, dk, dv). Returns (o, state', norm')."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    state = a * state + jnp.einsum("bhd,bhv->bhdv", k.astype(f32),
                                   v.astype(f32))
    o = jnp.einsum("bhd,bhdv->bhv", q.astype(f32), state)
    if normalize:
        norm = a[..., 0] * norm + k.astype(f32)
        denom = jnp.maximum(jnp.abs(
            jnp.einsum("bhd,bhd->bh", q.astype(f32), norm)), 1.0)[..., None]
        o = o / denom
    return o.astype(q.dtype), state, norm


# ---------------------------------------------------------------------------
# Mamba-style SSM heads (used standalone and inside the hymba hybrid block)
# ---------------------------------------------------------------------------

def init_ssm_heads(key, d_model: int, n_heads: int, dk: int, dtype) -> Dict:
    dv = d_model // n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d_model, n_heads * dk), dtype),
        "wk": _dense_init(ks[1], (d_model, n_heads * dk), dtype),
        "wv": _dense_init(ks[2], (d_model, n_heads * dv), dtype),
        "w_decay": _dense_init(ks[3], (d_model, n_heads), jnp.float32),
        "b_decay": jnp.full((n_heads,), 2.0, jnp.float32),
        "w_gate": _dense_init(ks[4], (d_model, n_heads * dv), dtype),
        "wo": _dense_init(ks[5], (n_heads * dv, d_model), dtype),
    }


def _ssm_qkva(params, x, n_heads: int, dk: int):
    B, S, D = x.shape
    dv = D // n_heads
    q = (x @ params["wq"]).reshape(B, S, n_heads, dk).transpose(0, 2, 1, 3)
    k = (x @ params["wk"]).reshape(B, S, n_heads, dk).transpose(0, 2, 1, 3)
    v = (x @ params["wv"]).reshape(B, S, n_heads, dv).transpose(0, 2, 1, 3)
    # input-dependent decay in (0, 1):  a = sigmoid(w x + b)
    la = jax.nn.log_sigmoid(
        x.astype(jnp.float32) @ params["w_decay"] + params["b_decay"])
    la = la.transpose(0, 2, 1)                           # (B, H, S)
    return q, k, v, la


def ssm_heads_train(params, x, *, n_heads: int, dk: int, chunk: int = 128):
    """Full-sequence SSM heads. Returns (out, final_state)."""
    B, S, D = x.shape
    q, k, v, la = _ssm_qkva(params, x, n_heads, dk)
    o, state, _ = chunked_gla(q, k, v, la, chunk=chunk)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, D)
    gate = act_fn("silu")(x @ params["w_gate"])
    return (o * gate) @ params["wo"], state


def ssm_heads_step(params, x, state, *, n_heads: int, dk: int):
    """Single-token SSM step: x (B, 1, D); state (B, H, dk, dv)."""
    B, _, D = x.shape
    q, k, v, la = _ssm_qkva(params, x, n_heads, dk)
    o, state, _ = gla_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                           la[:, :, 0], state)
    o = o.reshape(B, 1, D)
    gate = act_fn("silu")(x @ params["w_gate"])
    return (o * gate) @ params["wo"], state


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (parallel) and sLSTM (sequential) blocks
# ---------------------------------------------------------------------------

def init_mlstm(key, d_model: int, n_heads: int, dtype) -> Dict:
    dh = d_model // n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": _dense_init(ks[0], (d_model, d_model), dtype),
        "wk": _dense_init(ks[1], (d_model, d_model), dtype),
        "wv": _dense_init(ks[2], (d_model, d_model), dtype),
        "w_i": _dense_init(ks[3], (d_model, n_heads), jnp.float32),
        "w_f": _dense_init(ks[4], (d_model, n_heads), jnp.float32),
        "b_f": jnp.full((n_heads,), 3.0, jnp.float32),
        "w_gate": _dense_init(ks[5], (d_model, d_model), dtype),
        "wo": _dense_init(ks[6], (d_model, d_model), dtype),
    }


def _mlstm_qkvifa(params, x, n_heads: int):
    B, S, D = x.shape
    dh = D // n_heads

    def heads(w):
        return (x @ w).reshape(B, S, n_heads, dh).transpose(0, 2, 1, 3)

    q = heads(params["wq"]) / (dh ** 0.5)
    k = heads(params["wk"])
    v = heads(params["wv"])
    xf = x.astype(jnp.float32)
    # exponential input gate folded into k (sigmoid-bounded for stability —
    # stands in for the paper's log-domain stabilizer, DESIGN.md).
    i_gate = jax.nn.sigmoid(xf @ params["w_i"]).transpose(0, 2, 1)
    la = jax.nn.log_sigmoid(xf @ params["w_f"] + params["b_f"])
    la = la.transpose(0, 2, 1)
    k = k * i_gate[..., None].astype(k.dtype)
    return q, k, v, la


def mlstm_train(params, x, *, n_heads: int, chunk: int = 128):
    B, S, D = x.shape
    q, k, v, la = _mlstm_qkvifa(params, x, n_heads)
    o, state, norm = chunked_gla(q, k, v, la, chunk=chunk, normalize=True)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, D)
    gate = act_fn("silu")(x @ params["w_gate"])
    return (o * gate) @ params["wo"], (state, norm)


def mlstm_step(params, x, state, norm, *, n_heads: int):
    B, _, D = x.shape
    q, k, v, la = _mlstm_qkvifa(params, x, n_heads)
    o, state, norm = gla_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                              la[:, :, 0], state, norm, normalize=True)
    o = o.reshape(B, 1, D)
    gate = act_fn("silu")(x @ params["w_gate"])
    return (o * gate) @ params["wo"], (state, norm)


def init_slstm(key, d_model: int, n_heads: int, dtype) -> Dict:
    """sLSTM with block-diagonal (per-head) recurrent weights."""
    dh = d_model // n_heads
    ks = jax.random.split(key, 9)
    p = {"wo": _dense_init(ks[8], (d_model, d_model), dtype)}
    for i, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = _dense_init(ks[i], (d_model, d_model), dtype)
        p[f"r_{g}"] = _dense_init(ks[4 + i], (n_heads, dh, dh), jnp.float32,
                                  scale=dh ** -0.5)
    return p


def slstm_train(params, x, *, n_heads: int, state0=None):
    """Strictly sequential sLSTM scan over time (memory mixing forbids a
    parallel form — xLSTM paper Sec. 2). x: (B, S, D)."""
    B, S, D = x.shape
    dh = D // n_heads
    f32 = jnp.float32

    pre = {g: (x @ params[f"w_{g}"]).astype(f32)
           .reshape(B, S, n_heads, dh) for g in ("z", "i", "f", "o")}

    if state0 is None:
        # all-zero initial state, matching the decode cache's zero init
        # (the h = c / max(|n|, 1) normalizer is well-defined at n = 0).
        c0 = jnp.zeros((B, n_heads, dh), f32)
        n0 = jnp.zeros((B, n_heads, dh), f32)
        h0 = jnp.zeros((B, n_heads, dh), f32)
        m0 = jnp.zeros((B, n_heads, dh), f32)
    else:
        c0, n0, h0, m0 = state0

    R = {g: params[f"r_{g}"].astype(f32) for g in ("z", "i", "f", "o")}

    def step(carry, xs):
        c, n, h, m = carry
        pz, pi, pf, po = xs

        def rec(g):
            return jnp.einsum("bhd,hde->bhe", h, R[g])

        zt = jnp.tanh(pz + rec("z"))
        it_ = pi + rec("i")                      # log-domain input gate
        ft_ = pf + rec("f")
        # log-domain stabilizer (xLSTM Eq. 15):
        m_new = jnp.maximum(ft_ + m, it_)
        i_s = jnp.exp(it_ - m_new)
        f_s = jnp.exp(ft_ + m - m_new)
        c = f_s * c + i_s * zt
        n = f_s * n + i_s
        ot = jax.nn.sigmoid(po + rec("o"))
        h = ot * c / jnp.maximum(jnp.abs(n), 1.0)
        return (c, n, h, m_new), h

    xs = tuple(pre[g].transpose(1, 0, 2, 3) for g in ("z", "i", "f", "o"))
    (c, n, h, m), hs = jax.lax.scan(step, (c0, n0, h0, m0), xs)
    out = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    return out @ params["wo"], (c, n, h, m)


def slstm_step(params, x, state, *, n_heads: int):
    """Single-token sLSTM step via the train path with S=1."""
    out, state = slstm_train(params, x, n_heads=n_heads, state0=state)
    return out, state
