"""Decoder LM (+ optional encoder for enc-dec archs) covering every
assigned architecture through the per-layer block pattern:

    attn_mlp  — GQA attention + SwiGLU MLP            (dense family)
    swa_mlp   — sliding-window attention + MLP
    moe       — GQA/SWA attention + top-k MoE FFN      (mixtral, granite)
    mamba_mlp — SSM heads + MLP
    hybrid    — parallel attention ∥ SSM heads + MLP   (hymba)
    mlstm     — xLSTM matrix-memory block (no separate MLP)
    slstm     — xLSTM scalar-memory block (sequential scan)

Layers are scanned over the block-pattern period (homogeneous stacks keep
the HLO small for the 512-device dry-run lowering); per-slot params are
stacked along a leading group axis. Three entry points:

    train_loss(params, arch, batch)   -> scalar loss
    prefill(params, arch, tokens,...) -> (logits_last, cache)
    decode_step(params, arch, batch)  -> (logits, new_cache)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import recurrent as R
from repro.parallel.sharding import get_abstract_mesh as _get_abstract_mesh


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, arch: ArchConfig, kind: str) -> Dict:
    D, F = arch.d_model, arch.d_ff
    dt = arch.jnp_dtype
    Hd = arch.head_dim_
    ks = jax.random.split(key, 6)
    p: Dict = {"norm1": L.init_norm(D, dt)}
    if kind in ("attn_mlp", "swa_mlp", "moe", "hybrid"):
        p["attn"] = L.init_attention(ks[0], D, arch.n_heads, arch.n_kv_heads,
                                     Hd, arch.qkv_bias, dt)
    if kind in ("mamba_mlp", "hybrid"):
        p["ssm"] = R.init_ssm_heads(ks[1], D, arch.ssm_heads or arch.n_heads,
                                    arch.ssm_state, dt)
    if kind == "mlstm":
        p["mlstm"] = R.init_mlstm(ks[2], D, arch.n_heads, dt)
    elif kind == "slstm":
        p["slstm"] = R.init_slstm(ks[3], D, arch.n_heads, dt)
    else:
        p["norm2"] = L.init_norm(D, dt)
        if kind == "moe":
            p["moe"] = L.init_moe(ks[4], D, F, arch.n_experts, dt)
        else:
            p["mlp"] = L.init_mlp(ks[5], D, F, dt, arch.mlp_type)
    if arch.is_encdec:
        p["norm_x"] = L.init_norm(D, dt)
        p["xattn"] = L.init_attention(ks[0] if kind != "attn_mlp" else ks[1],
                                      D, arch.n_heads, arch.n_kv_heads, Hd,
                                      False, dt)
    return p


def init_params(arch: ArchConfig, key) -> Dict:
    dt = arch.jnp_dtype
    D, V = arch.d_model, arch.vocab_size
    keys = jax.random.split(key, arch.n_layers + 8)
    period = len(arch.block_pattern)
    if arch.n_layers % period != 0:
        raise ValueError(
            f"{arch.name}: n_layers={arch.n_layers} not a multiple of "
            f"the block pattern period {period}")
    groups = arch.n_layers // period

    # stack each pattern slot's params over the groups.
    layer_params = {}
    for slot, kind in enumerate(arch.block_pattern):
        per_group = [_init_block(keys[g * period + slot], arch, kind)
                     for g in range(groups)]
        layer_params[f"slot{slot}_{kind}"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *per_group)

    params = {
        "embed": (jax.random.normal(keys[-1], (V, D), jnp.float32)
                  * 0.02).astype(dt),
        "final_norm": L.init_norm(D, dt),
        "layers": layer_params,
    }
    if not arch.tie_embeddings:
        params["unembed"] = (jax.random.normal(keys[-2], (D, V), jnp.float32)
                             * D ** -0.5).astype(dt)
    if arch.meta_tokens:
        params["meta"] = (jax.random.normal(
            keys[-3], (arch.meta_tokens, D), jnp.float32) * 0.02).astype(dt)
    if arch.is_encdec:
        enc_layers = [_init_block(keys[-4 - i], ArchConfig(
            **{**dataclasses.asdict(arch), "encoder_layers": 0,
               "block_pattern": ("attn_mlp",)}), "attn_mlp")
            for i in range(arch.encoder_layers)]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
            "final_norm": L.init_norm(D, dt),
            "pos_embed": (jax.random.normal(
                keys[-5], (arch.encoder_seq, D), jnp.float32) * 0.02
            ).astype(dt),
        }
    return params


def param_specs(arch: ArchConfig):
    """ShapeDtypeStruct tree of the params — zero allocation (dry-run)."""
    return jax.eval_shape(lambda: init_params(arch, jax.random.key(0)))


def param_count(arch: ArchConfig, include_embed: bool = True) -> int:
    import math
    specs = param_specs(arch)
    if not include_embed:
        specs = dict(specs)
        specs.pop("embed", None)
        specs.pop("unembed", None)
    return sum(math.prod(l.shape) for l in jax.tree.leaves(specs))


# ---------------------------------------------------------------------------
# Forward (full-sequence: train / prefill)
# ---------------------------------------------------------------------------

def _block_forward(p, x, arch: ArchConfig, kind: str, *,
                   enc_out=None, use_pallas: bool = False):
    """One block, full sequence. Returns (x, cache_entries)."""
    window = arch.window if kind in ("swa_mlp", "moe", "hybrid") else 0
    cache = {}
    h = L.rmsnorm(p["norm1"], x)
    if kind in ("attn_mlp", "swa_mlp", "moe"):
        a, (ck, cv) = L.attention_train(
            p["attn"], h, n_heads=arch.n_heads, n_kv_heads=arch.n_kv_heads,
            head_dim=arch.head_dim_, rope_theta=arch.rope_theta,
            window=window, use_pallas=use_pallas)
        cache["k"], cache["v"] = ck, cv
        x = x + a
    elif kind == "mamba_mlp":
        a, state = R.ssm_heads_train(p["ssm"], h,
                                     n_heads=arch.ssm_heads or arch.n_heads,
                                     dk=arch.ssm_state)
        cache["ssm_state"] = state
        x = x + a
    elif kind == "hybrid":
        a, (ck, cv) = L.attention_train(
            p["attn"], h, n_heads=arch.n_heads, n_kv_heads=arch.n_kv_heads,
            head_dim=arch.head_dim_, rope_theta=arch.rope_theta,
            window=window, use_pallas=use_pallas)
        s, state = R.ssm_heads_train(p["ssm"], h,
                                     n_heads=arch.ssm_heads or arch.n_heads,
                                     dk=arch.ssm_state)
        cache["k"], cache["v"], cache["ssm_state"] = ck, cv, state
        x = x + 0.5 * (a + s)
    elif kind == "mlstm":
        a, (state, norm) = R.mlstm_train(p["mlstm"], h, n_heads=arch.n_heads)
        cache["mlstm_state"], cache["mlstm_norm"] = state, norm
        return x + a, cache
    elif kind == "slstm":
        a, state = R.slstm_train(p["slstm"], h, n_heads=arch.n_heads)
        cache["slstm_state"] = state
        return x + a, cache

    if arch.is_encdec and enc_out is not None:
        hx = L.rmsnorm(p["norm_x"], x)
        cx, _ = L.attention_train(
            p["xattn"], hx, n_heads=arch.n_heads,
            n_kv_heads=arch.n_kv_heads, head_dim=arch.head_dim_,
            rope_theta=0.0, causal=False,
            kv_override=_cross_kv(p["xattn"], enc_out, arch))
        x = x + cx

    h2 = L.rmsnorm(p["norm2"], x)
    if kind == "moe":
        f, aux = L.moe(p["moe"], h2, n_experts=arch.n_experts,
                       top_k=arch.top_k,
                       capacity_factor=arch.capacity_factor, act=arch.act)
        cache["moe_aux"] = aux
    else:
        f = L.mlp(p["mlp"], h2, act=arch.act)
    return x + f, cache


def _cross_kv(xattn_params, enc_out, arch: ArchConfig):
    """Project encoder output to cross-attention K/V (no rope)."""
    B, Se, _ = enc_out.shape
    k = (enc_out @ xattn_params["wk"]).reshape(
        B, Se, arch.n_kv_heads, arch.head_dim_).transpose(0, 2, 1, 3)
    v = (enc_out @ xattn_params["wv"]).reshape(
        B, Se, arch.n_kv_heads, arch.head_dim_).transpose(0, 2, 1, 3)
    return k, v


def _scan_layers(params, x, arch: ArchConfig, fn, remat: str = "none",
                 shard_acts: bool = False, unroll_layers: int = 0):
    """Scan ``fn(slot_params, x, kind) -> (x, per_layer_out)`` over the
    layer groups; the pattern period is unrolled inside the body.

    remat: "none" | "full" | "dots" — activation checkpointing policy for
    the block body. shard_acts: apply the sequence-parallel layer-boundary
    sharding constraint. unroll_layers > 0 replaces the scan with a python
    loop over that many groups (roofline cost extraction — see
    repro.roofline: XLA's cost_analysis counts a scan body once).
    """
    slots = [f"slot{i}_{k}" for i, k in enumerate(arch.block_pattern)]

    def body(x, group_params):
        outs = {}
        for slot, kind in zip(slots, arch.block_pattern):
            x, out = fn(group_params[slot], x, kind)
            outs[slot] = out
        if shard_acts:
            from repro.parallel.sharding import activation_spec
            mesh = _get_abstract_mesh()
            if not mesh.empty:
                x = L.maybe_shard(x, activation_spec(mesh.axis_names))
        return x, outs

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)

    if unroll_layers:
        outs = []
        for g in range(unroll_layers):
            gp = jax.tree.map(lambda a: a[g], params["layers"])
            x, out = body(x, gp)
            outs.append(out)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        return x, stacked
    return jax.lax.scan(body, x, params["layers"])


def _sinusoid(positions, d: int):
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _embed(params, arch: ArchConfig, tokens, extras: Dict, pos0=0):
    x = params["embed"][tokens].astype(arch.jnp_dtype)
    mesh = _get_abstract_mesh()
    if not mesh.empty:
        from repro.parallel.sharding import activation_spec
        x = L.maybe_shard(x, activation_spec(mesh.axis_names))
    if arch.pos_embed == "sinusoidal":
        positions = pos0 + jnp.arange(tokens.shape[1])
        x = x + _sinusoid(positions, arch.d_model)[None].astype(x.dtype)
    if arch.frontend == "vision_stub" and "patches" in extras:
        x = jnp.concatenate([extras["patches"].astype(x.dtype), x], axis=1)
    if arch.meta_tokens:
        B = x.shape[0]
        meta = jnp.broadcast_to(params["meta"][None],
                                (B, arch.meta_tokens, arch.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    return x


def _encoder_forward(params, arch: ArchConfig, frames, use_pallas=False,
                     remat: str = "none"):
    """Whisper-style encoder over (stub) frame embeddings."""
    enc = params["encoder"]
    x = frames.astype(arch.jnp_dtype) + enc["pos_embed"][None]

    def body(x, lp):
        h = L.rmsnorm(lp["norm1"], x)
        a, _ = L.attention_train(
            lp["attn"], h, n_heads=arch.n_heads, n_kv_heads=arch.n_kv_heads,
            head_dim=arch.head_dim_, rope_theta=0.0, causal=False,
            use_pallas=use_pallas)
        x = x + a
        h2 = L.rmsnorm(lp["norm2"], x)
        return x + L.mlp(lp["mlp"], h2, act=arch.act), None

    if remat == "full":
        body = jax.checkpoint(body)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return L.rmsnorm(enc["final_norm"], x)


def forward(params, arch: ArchConfig, tokens, extras: Optional[Dict] = None,
            use_pallas: bool = False, return_cache: bool = False,
            remat: str = "none", shard_acts: bool = False,
            unroll_layers: int = 0):
    """Full-sequence forward. Returns (logits, aux, cache)."""
    extras = extras or {}
    enc_out = None
    if arch.is_encdec:
        enc_out = _encoder_forward(params, arch, extras["frames"],
                                   use_pallas, remat=remat)
    x = _embed(params, arch, tokens, extras)

    def fn(slot_params, x, kind):
        return _block_forward(slot_params, x, arch, kind, enc_out=enc_out,
                              use_pallas=use_pallas)

    x, caches = _scan_layers(params, x, arch, fn, remat=remat,
                             shard_acts=shard_acts,
                             unroll_layers=unroll_layers)
    x = L.rmsnorm(params["final_norm"], x)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = x @ unembed
    aux = jnp.float32(0)
    for slot_out in caches.values():
        if "moe_aux" in slot_out:
            aux = aux + jnp.sum(slot_out["moe_aux"])
    return logits, aux, (caches if return_cache else None)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def train_loss(params, arch: ArchConfig, batch: Dict,
               use_pallas: bool = False, aux_weight: float = 0.01,
               remat: str = "none", shard_acts: bool = False,
               unroll_layers: int = 0):
    tokens, targets = batch["tokens"], batch["targets"]
    extras = {k: v for k, v in batch.items()
              if k not in ("tokens", "targets")}
    logits, aux, _ = forward(params, arch, tokens, extras,
                             use_pallas=use_pallas, remat=remat,
                             shard_acts=shard_acts,
                             unroll_layers=unroll_layers)
    # prefix tokens (patches / meta) carry no loss.
    n_prefix = logits.shape[1] - targets.shape[1]
    if n_prefix:
        logits = logits[:, n_prefix:]
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # vocab-parallel gold-logit extraction (Megatron-style): a masked
    # reduction over the sharded vocab dim instead of take_along_axis —
    # the gather would force an all-gather of the V-sharded logits.
    v_iota = jnp.arange(logits.shape[-1])
    gold = jnp.sum(jnp.where(v_iota[None, None, :] == targets[..., None],
                             logits, 0.0), axis=-1)
    loss = jnp.mean(logz - gold)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# KV / state cache: specs, prefill, decode
# ---------------------------------------------------------------------------

def _cache_len(arch: ArchConfig, kind: str, seq_len: int) -> int:
    if kind in ("swa_mlp", "hybrid") and arch.window > 0:
        return min(seq_len, arch.window)
    if kind == "moe" and arch.window > 0:
        return min(seq_len, arch.window)
    return seq_len


def cache_specs(arch: ArchConfig, batch: int, seq_len: int):
    """ShapeDtypeStruct tree of the decode cache (leading group axis
    matches the layer scan)."""
    dt = arch.jnp_dtype
    f32 = jnp.float32
    period = len(arch.block_pattern)
    G = arch.n_layers // period
    Hd = arch.head_dim_
    Hkv = arch.n_kv_heads
    H = arch.n_heads
    dh = arch.d_model // H
    Hs = arch.ssm_heads or arch.n_heads
    dv_ssm = arch.d_model // Hs

    def sd(shape, dtype=dt):
        return jax.ShapeDtypeStruct((G,) + shape, dtype)

    out = {}
    for slot, kind in enumerate(arch.block_pattern):
        entry = {}
        if kind in ("attn_mlp", "swa_mlp", "moe", "hybrid"):
            Sc = _cache_len(arch, kind, seq_len)
            entry["k"] = sd((batch, Hkv, Sc, Hd))
            entry["v"] = sd((batch, Hkv, Sc, Hd))
        if kind in ("mamba_mlp", "hybrid"):
            entry["ssm_state"] = sd((batch, Hs, arch.ssm_state, dv_ssm), f32)
        if kind == "mlstm":
            entry["mlstm_state"] = sd((batch, H, Hd, dh), f32)
            entry["mlstm_norm"] = sd((batch, H, Hd), f32)
        if kind == "slstm":
            for s in ("c", "n", "h", "m"):
                entry[f"slstm_{s}"] = sd((batch, H, dh), f32)
        out[f"slot{slot}_{kind}"] = entry
    if arch.is_encdec:
        out["cross"] = {"k": sd((batch, Hkv, arch.encoder_seq, Hd)),
                        "v": sd((batch, Hkv, arch.encoder_seq, Hd))}
    return out


def _block_decode(p, x, arch: ArchConfig, kind: str, cache: Dict, pos,
                  cross_kv=None):
    window = arch.window if kind in ("swa_mlp", "moe", "hybrid") else 0
    new_cache = {}
    h = L.rmsnorm(p["norm1"], x)
    if kind in ("attn_mlp", "swa_mlp", "moe", "hybrid"):
        a, ck, cv = L.attention_decode(
            p["attn"], h, cache["k"], cache["v"], pos,
            n_heads=arch.n_heads, n_kv_heads=arch.n_kv_heads,
            head_dim=arch.head_dim_, rope_theta=arch.rope_theta,
            window=window)
        new_cache["k"], new_cache["v"] = ck, cv
        if kind == "hybrid":
            s, state = R.ssm_heads_step(
                p["ssm"], h, cache["ssm_state"],
                n_heads=arch.ssm_heads or arch.n_heads, dk=arch.ssm_state)
            new_cache["ssm_state"] = state
            a = 0.5 * (a + s)
        x = x + a
    elif kind == "mamba_mlp":
        a, state = R.ssm_heads_step(
            p["ssm"], h, cache["ssm_state"],
            n_heads=arch.ssm_heads or arch.n_heads, dk=arch.ssm_state)
        new_cache["ssm_state"] = state
        x = x + a
    elif kind == "mlstm":
        a, (state, norm) = R.mlstm_step(
            p["mlstm"], h, cache["mlstm_state"], cache["mlstm_norm"],
            n_heads=arch.n_heads)
        return x + a, {"mlstm_state": state, "mlstm_norm": norm}
    elif kind == "slstm":
        st = tuple(cache[f"slstm_{s}"] for s in ("c", "n", "h", "m"))
        a, st = R.slstm_step(p["slstm"], h, st, n_heads=arch.n_heads)
        return x + a, {f"slstm_{s}": v for s, v in zip("cnhm", st)}

    if arch.is_encdec and cross_kv is not None:
        hx = L.rmsnorm(p["norm_x"], x)
        B = hx.shape[0]
        q = (hx @ p["xattn"]["wq"]).reshape(
            B, 1, arch.n_heads, arch.head_dim_).transpose(0, 2, 1, 3)
        ck, cv = cross_kv
        group = arch.n_heads // arch.n_kv_heads
        scores = jnp.einsum(
            "bhqd,bhkd->bhqk", q.astype(jnp.float32),
            jnp.repeat(ck.astype(jnp.float32), group, axis=1)) \
            / (arch.head_dim_ ** 0.5)
        probs = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", probs,
                       jnp.repeat(cv.astype(jnp.float32), group, axis=1))
        o = o.astype(x.dtype).transpose(0, 2, 1, 3).reshape(
            B, 1, arch.n_heads * arch.head_dim_)
        x = x + o @ p["xattn"]["wo"]

    h2 = L.rmsnorm(p["norm2"], x)
    if kind == "moe":
        f, _ = L.moe(p["moe"], h2, n_experts=arch.n_experts,
                     top_k=arch.top_k,
                     capacity_factor=arch.capacity_factor, act=arch.act)
    else:
        f = L.mlp(p["mlp"], h2, act=arch.act)
    return x + f, new_cache


def decode_step(params, arch: ArchConfig, batch: Dict,
                use_pallas: bool = False, unroll_layers: int = 0):
    """One decode step: batch = {tokens (B,1), cache, pos [, frames]}.
    Returns (logits (B, 1, V), new_cache). ``unroll_layers`` mirrors
    _scan_layers (roofline cost extraction)."""
    tokens, cache, pos = batch["tokens"], batch["cache"], batch["pos"]
    x = params["embed"][tokens].astype(arch.jnp_dtype)
    if arch.pos_embed == "sinusoidal":
        x = x + _sinusoid(jnp.asarray(pos)[None],
                          arch.d_model)[None].astype(x.dtype)
    slots = [f"slot{i}_{k}" for i, k in enumerate(arch.block_pattern)]
    layer_cache = {k: v for k, v in cache.items() if k != "cross"}

    if arch.is_encdec:
        # per-layer cross K/V rides the scan (each decoder layer projects
        # the encoder output with its own weights).
        def body(x, group):
            group_params, group_cache, cross = group
            new = {}
            for slot, kind in zip(slots, arch.block_pattern):
                x, nc = _block_decode(group_params[slot], x, arch, kind,
                                      group_cache[slot], pos,
                                      (cross["k"], cross["v"]))
                new[slot] = nc
            return x, new

        xs = (params["layers"], layer_cache, cache["cross"])
    else:
        def body(x, group):
            group_params, group_cache = group
            new = {}
            for slot, kind in zip(slots, arch.block_pattern):
                x, nc = _block_decode(group_params[slot], x, arch, kind,
                                      group_cache[slot], pos, None)
                new[slot] = nc
            return x, new

        xs = (params["layers"], layer_cache)

    if unroll_layers:
        news = []
        for g in range(unroll_layers):
            xs_g = jax.tree.map(lambda a: a[g], xs)
            x, new = body(x, xs_g)
            news.append(new)
        new_cache = jax.tree.map(lambda *vs: jnp.stack(vs), *news)
    else:
        x, new_cache = jax.lax.scan(body, x, xs)
    x = L.rmsnorm(params["final_norm"], x)
    unembed = params.get("unembed")
    if unembed is None:
        unembed = params["embed"].T
    logits = x @ unembed
    if arch.is_encdec:
        new_cache["cross"] = cache["cross"]
    return logits, new_cache


def prefill(params, arch: ArchConfig, tokens,
            extras: Optional[Dict] = None, use_pallas: bool = False):
    """Prefill: forward over the prompt, returning last-position logits and
    a seeded cache is intentionally NOT materialized here — prefill lowers
    the forward pass (the dry-run measures it); serving then re-runs
    decode_step against cache_specs-shaped buffers."""
    logits, aux, _ = forward(params, arch, tokens, extras,
                             use_pallas=use_pallas)
    return logits[:, -1:]
