"""Pure-jnp oracle for blocked causal / sliding-window GQA attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: float | None = None):
    """Reference attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0 (GQA).
    window > 0 enables sliding-window masking (Mistral-style): query i may
    attend keys j with  i - window < j <= i  (positions aligned at the
    sequence end: query i corresponds to absolute position
    i + (Sk - Sq), e.g. decode with a long KV cache).
    Computation in f32 regardless of input dtype; output cast back.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)

    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / jnp.maximum(probs.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)
