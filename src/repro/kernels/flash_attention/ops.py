"""Public wrapper for flash attention: padding, dispatch, custom_vjp.

Forward: Pallas kernel (TPU target / interpret validation) or jnp
reference (CPU, dry-run lowering). Backward: reference-path VJP — the
kernel serves the inference hot path; training backward goes through
XLA's differentiable attention (DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref as _ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _pad_seq(x, mult: int):
    pad = (-x.shape[2]) % mult
    if pad == 0:
        return x, 0
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0))), pad


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, scale, use_pallas, interpret):
    if not (use_pallas or interpret):
        return _ref.attention_ref(q, k, v, causal=causal, window=window,
                                  scale=scale)
    Sq, Sk = q.shape[2], k.shape[2]
    bq = min(128, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(128, max(8, 1 << (Sk - 1).bit_length()))
    qp, pq = _pad_seq(q, bq)
    kp, _ = _pad_seq(k, bk)
    vp, _ = _pad_seq(v, bk)
    # padded keys sit at positions > every real query and are causally
    # masked out; padded queries produce garbage rows that are sliced off.
    # The position offset is computed from the UNPADDED lengths so padding
    # never shifts the causal/window band.
    out = flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                 scale=scale, block_q=bq, block_k=bk,
                                 offset=Sk - Sq, interpret=interpret)
    return out[:, :, :Sq]


def _flash_fwd(q, k, v, causal, window, scale, use_pallas, interpret):
    return _flash(q, k, v, causal, window, scale, use_pallas, interpret), \
        (q, k, v)


def _flash_bwd(causal, window, scale, use_pallas, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: _ref.attention_ref(q, k, v, causal=causal,
                                           window=window, scale=scale),
        q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


import contextlib

# Cost-exact mode: XLA's cost_analysis counts a scan body once, so the
# roofline cost-extraction lowerings unroll the chunk scan (shapes stay
# chunk-sized; nothing is ever executed). See repro.launch.dryrun.
_COST_EXACT = False


@contextlib.contextmanager
def cost_exact_mode():
    global _COST_EXACT
    prev = _COST_EXACT
    _COST_EXACT = True
    try:
        yield
    finally:
        _COST_EXACT = prev


def attention_chunked(q, k, v, *, causal: bool = True, window: int = 0,
                      scale: float | None = None, q_chunk: int = 1024):
    """Memory-bounded jnp attention: scan over query chunks so the live
    score block is (B, H, q_chunk, Sk) instead of (B, H, Sq, Sk).

    This is the XLA path the models use for long sequences when the
    Pallas kernel is unavailable (CPU tests, dry-run lowering): same math
    as ref.attention_ref, O(Sq/q_chunk) scan steps, fully differentiable.
    With ``window`` > 0 each chunk slices only the (q_chunk + window) keys
    it can see — sliding-window attention costs O(S * window), not O(S^2).
    GQA is computed grouped (no materialized head repeat).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    bq = min(q_chunk, Sq)
    pad = (-Sq) % bq
    offset = Sk - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = q.shape[2] // bq
    qg = q.reshape(B, Hkv, g, nc * bq, D)

    use_kslice = window > 0 and window + bq < Sk
    kwin = min(window + bq, Sk)

    def body(_, i):
        qs = jax.lax.dynamic_slice_in_dim(qg, i * bq, bq, axis=3)
        qpos = i * bq + jnp.arange(bq) + offset
        if use_kslice:
            # keys visible to this chunk: [q_start - window + 1, q_end]
            start = jnp.clip(i * bq + offset - window + 1, 0, Sk - kwin)
            ks = jax.lax.dynamic_slice_in_dim(k, start, kwin, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(v, start, kwin, axis=2)
            kpos = start + jnp.arange(kwin)
        else:
            ks, vs = k, v
            kpos = jnp.arange(Sk)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qs.astype(jnp.float32),
                       ks.astype(jnp.float32)) * scale
        mask = jnp.ones((bq, kpos.shape[0]), bool)
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        if CHUNKED_BF16_PROBS:
            o = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(jnp.bfloat16),
                           vs.astype(jnp.bfloat16))
        else:
            o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vs.astype(jnp.float32))
        return None, o.astype(q.dtype)

    _, chunks = jax.lax.scan(body, None, jnp.arange(nc),
                             unroll=nc if _COST_EXACT else 1)
    # chunks: (nc, B, Hkv, g, bq, D) -> (B, Hq, Sq, D)
    out = chunks.transpose(1, 2, 3, 0, 4, 5).reshape(
        B, Hq, nc * bq, D)
    return out[:, :, :Sq]


# sequences at or above this length use the chunked path on non-Pallas
# backends (the S x S score tensor would dominate memory otherwise).
CHUNKED_THRESHOLD = 2048

# Perf-iteration flag (EXPERIMENTS.md §Perf): cast the post-softmax
# probabilities to bf16 before the PV contraction — halves the largest
# live buffer in the chunked path and puts both big matmuls on the bf16
# MXU path. Softmax itself stays f32 (stability).
CHUNKED_BF16_PROBS = False


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, use_pallas: bool = False,
                    interpret: bool = False):
    """Blocked GQA attention. q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D).

    ``causal`` masks the future; ``window`` > 0 adds a sliding window
    (queries attend at most the last ``window`` keys). Dispatch: Pallas
    kernel (TPU / interpret), chunked-scan jnp for long sequences
    (CPU & dry-run lowering), dense reference for short ones.
    """
    if not causal and window == 0 and (use_pallas or interpret):
        Sq, Sk = q.shape[2], k.shape[2]
        if Sq % min(128, Sq) or Sk % min(128, Sk):
            raise ValueError("bidirectional pallas path needs divisible "
                             "sequence lengths (padding would unmask)")
    if not (use_pallas or interpret) and q.shape[2] >= CHUNKED_THRESHOLD:
        return attention_chunked(q, k, v, causal=causal, window=window,
                                 scale=scale)
    return _flash(q, k, v, causal, window, scale, use_pallas, interpret)
