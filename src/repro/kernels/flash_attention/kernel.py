"""Pallas TPU kernel: FlashAttention-style blocked attention with online
softmax, causal and sliding-window masking, and GQA head grouping.

TPU mapping:
  * grid = (B*Hq, Sq/bq, Sk/bk); the key axis is innermost/"arbitrary" so
    the f32 running (m, l, acc) state lives in VMEM scratch across its
    steps. Query/output tiles are (bq, D) — MXU-aligned for D in
    {64, 128, 256}.
  * GQA: the kv BlockSpec index map folds the query head onto its kv
    group — no materialized head repeat (the jnp path repeats).
  * Block-level skipping: key blocks entirely outside the causal /
    sliding window band are skipped with @pl.when — the kernel does no
    work for them (this is the structural win over masked dense attention
    that makes sliding-window decode O(window), used by the hymba and
    mixtral configs).

The backward pass is delegated to the jnp reference via custom_vjp in
ops.py: the kernel targets the serving/prefill hot path; training uses
XLA's fused attention from the reference path (see DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

_NEG_INF = -1e30


def _make_kernel(block_q: int, block_k: int, seq_q: int, seq_k: int,
                 causal: bool, window: int, scale: float,
                 offset: int | None = None):
    num_k = seq_k // block_k
    if offset is None:
        offset = seq_k - seq_q   # query i sits at absolute position i + offset

    def kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
        iq, ik = pl.program_id(1), pl.program_id(2)

        @pl.when(ik == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)
            m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)

        # ---- block-level skip test (static bounds per (iq, ik)) ----
        q_lo = iq * block_q + offset
        q_hi = q_lo + block_q - 1
        k_lo = ik * block_k
        k_hi = k_lo + block_k - 1
        live = jnp.bool_(True)
        if causal:
            live = live & (k_lo <= q_hi)
        if window > 0:
            live = live & (k_hi > q_lo - window)

        @pl.when(live)
        def _body():
            q = q_ref[0].astype(jnp.float32)              # (bq, D)
            k = k_ref[0].astype(jnp.float32)              # (bk, D)
            v = v_ref[0].astype(jnp.float32)              # (bk, D)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale   # (bq, bk)

            qpos = q_lo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), dtype=bool)
            if causal:
                mask = mask & (kpos <= qpos)
            if window > 0:
                mask = mask & (kpos > qpos - window)
            s = jnp.where(mask, s, _NEG_INF)

            m_prev = m_ref[...]                            # (bq, 1)
            m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_ref[...] = alpha * l_ref[...] + p.sum(axis=1, keepdims=True)
            acc_ref[...] = alpha * acc_ref[...] + jnp.dot(
                p, v, preferred_element_type=jnp.float32)
            m_ref[...] = m_new

        @pl.when(ik == num_k - 1)
        def _flush():
            l = jnp.maximum(l_ref[...], 1e-30)
            o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)

    return kernel


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           offset: int | None = None,
                           interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Shapes must divide the
    blocks (ops.py pads and passes the *unpadded* position ``offset`` so
    padding never shifts the causal/window band). Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    if Hq % Hkv != 0:
        raise ValueError(f"query heads {Hq} not a multiple of kv heads {Hkv}")
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)

    qf = q.reshape(B * Hq, Sq, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)

    def kv_index(bh, iq, ik):
        # fold query head bh = b*Hq + h onto kv head b*Hkv + h//group.
        return (bh // Hq) * Hkv + (bh % Hq) // group, ik, 0

    kernel = _make_kernel(block_q, block_k, Sq, Sk, causal, window, scale,
                          offset)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, Sq // block_q, Sk // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, Sq, D)
