"""Pure-jnp oracle for the blocked-ELL SpMM.

The contraction runs over the padded ELL width K in its storage order
(ascending index within each row), one lane-sweep per ELL slot — a scan
rather than a materialized (R, K, Q) gather so the oracle stays exact in
the caller's dtype (f64 for the equivalence tier) without blowing memory
when Q is large (the warm-start K(A, A) path has Q = m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ell_spmm_ref(vals, idx, D):
    """out[r, q] = sum_k vals[r, k] * D[idx[r, k], q].

    vals: (R, K) gathered ELL values (padded slots hold 0).
    idx:  (R, K) int32 indices into D's rows (padded slots hold 0 — they
          contribute vals == 0 and are exact by construction).
    D:    (C, Q) dense right operand.
    Returns (R, Q) in the promoted input dtype (no forced f32).
    """
    R = vals.shape[0]
    Q = D.shape[1]
    out_dtype = jnp.promote_types(vals.dtype, D.dtype)

    def body(acc, k):
        return acc + vals[:, k, None] * D[idx[:, k]], None

    out, _ = jax.lax.scan(body, jnp.zeros((R, Q), out_dtype),
                          jnp.arange(vals.shape[1]))
    return out
