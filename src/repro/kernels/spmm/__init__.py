from repro.kernels.spmm.ops import (ell_spmm, grouped_spmm_label,
                                    scatter_add, scatter_dense,
                                    scatter_steps, spmm_impl, spmm_vmem_ok)

__all__ = ["ell_spmm", "grouped_spmm_label", "scatter_add",
           "scatter_dense", "scatter_steps", "spmm_impl", "spmm_vmem_ok"]
