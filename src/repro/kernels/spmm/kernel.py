"""Pallas TPU kernel: blocked-ELL SpMM with a VMEM-resident dense
right operand.

This executes the paper's sparse-operand flop terms (Table I's density
factor f) instead of merely modeling them: the left operand is a padded
blocked-ELL matrix — per row, nonzero values and their indices padded to
a common width K that is a multiple of the ELL block ``bk``, plus the
per-row count of *active* K-blocks — and the right operand D is a small
dense matrix held entirely in VMEM. The two hot solver products both
have this shape:

  * Lasso (SA-)BCD:   A_h^T [A_h | r]   — rows = the s*mu sampled
    columns of A (gathered straight out of the column-major ELL arrays),
    D = the densified sample plus the residual-like vectors,
    (s*mu, s*mu + k) out;
  * SVM / K-SVM / logreg cross block:  A Y^T  — rows = all m data
    points (the row-major ELL arrays as stored), D = the densified
    (n_loc, s*mu) sample, (m, s*mu) out.

TPU mapping: grid = (R, K / bk) with the K-blocks innermost, so each
output row tile stays resident while its ELL blocks accumulate; the
per-row block count (the blocked-ELL nnz metadata) gates a ``pl.when``
that skips fully-padded blocks. The row gathers from D use dynamic
slices whose starts come from the index array, which is passed through
``PrefetchScalarGridSpec`` scalar prefetch (SMEM) so the starts are
available to address generation. Accumulation is f32.

VMEM budget: D at (C, Q) * 4 B dominates; ``dispatch.spmm_vmem_ok``
rejects configurations above ~8 MB (half of v5e's ~16 MB VMEM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_kernel(K: int, bk: int, Q: int):
    def kernel(idx_ref, nnb_ref, vals_ref, D_ref, o_ref):
        r, kb = pl.program_id(0), pl.program_id(1)

        @pl.when(kb == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        # blocked-ELL skip: K-blocks at or past this row's active count
        # are pure padding (zero values by construction).
        @pl.when(kb < nnb_ref[r])
        def _accumulate():
            def body(t, acc):
                c = idx_ref[r * K + kb * bk + t]
                row = pl.load(D_ref, (pl.dslice(c, 1), slice(None)))
                return acc + vals_ref[0, t] * row

            o_ref[...] += jax.lax.fori_loop(
                0, bk, body, jnp.zeros((1, Q), jnp.float32))

    return kernel


def ell_spmm_pallas(vals, idx, blocks, D, *, ell_block: int,
                    interpret: bool = False):
    """out = S @ D for S in padded blocked-ELL form; see ref.py for the
    semantics. ``blocks`` is the per-row active K-block count; K must be
    a multiple of ``ell_block`` (ops.py guarantees both). Returns f32."""
    R, K = vals.shape
    C, Q = D.shape
    if K % ell_block != 0:
        raise ValueError(f"K={K} is not a multiple of ell_block={ell_block}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # flat indices + per-row block counts
        grid=(R, K // ell_block),
        in_specs=[
            pl.BlockSpec((1, ell_block), lambda r, kb, *_: (r, kb)),
            pl.BlockSpec((C, Q), lambda r, kb, *_: (0, 0)),  # resident
        ],
        out_specs=pl.BlockSpec((1, Q), lambda r, kb, *_: (r, 0)),
    )
    return pl.pallas_call(
        _make_kernel(K, ell_block, Q),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, Q), jnp.float32),
        interpret=interpret,
    )(idx.reshape(-1).astype(jnp.int32), blocks.astype(jnp.int32),
      vals.astype(jnp.float32), D.astype(jnp.float32))
