"""Public wrapper for the blocked-ELL SpMM plus the gather/scatter
companions the sparse solver paths are built from.

Dispatch policy lives in ``repro.kernels.dispatch`` (shared with
``sa_inner`` / ``svm_inner``): ``spmm_impl(R, K, C, Q, use_pallas)``
returns the path that will actually run, warning once per shape about a
forced Pallas -> ref fallback; the solvers stash the per-solve label in
``SolverResult.aux["spmm_impl"]`` (``grouped_spmm_label`` handles the
SA remainder group, whose shapes can dispatch differently).

Padding contract (see ``repro.core.types.SparseOperand``): padded ELL
slots hold index 0 and value 0, so every operation below is exact with
no masking — padded slots gather row 0 of D scaled by 0, and padded
scatter slots add 0 to position 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import spmm_vmem_ok
from repro.kernels.spmm import ref as _ref
from repro.kernels.spmm.kernel import ell_spmm_pallas


def spmm_impl(R: int, K: int, C: int, Q: int, use_pallas: bool,
              itemsize: int = 4) -> str:
    return dispatch.choose_spmm_impl(R, K, C, Q, use_pallas, itemsize)


def grouped_spmm_label(H: int, s: int, shape_fn, use_pallas: bool,
                       itemsize: int = 4) -> str:
    """The SpMM implementation(s) an SA grouped schedule actually runs:
    ``shape_fn(s_grp) -> (R, K, C, Q)`` maps a group size to the SpMM
    shape; the tail group (H mod s) can dispatch differently from the
    full groups, in which case the label is "main+tail"-joined — same
    convention as ``sa_loop.grouped_impl_label``."""
    full, rem = divmod(H, s)
    labels = ([spmm_impl(*shape_fn(s), use_pallas, itemsize)]
              if full else []) \
        + ([spmm_impl(*shape_fn(rem), use_pallas, itemsize)]
           if rem else [])
    if len(set(labels)) == 1:
        return labels[0]
    return "+".join(labels)


def _pad_lanes(D, mult: int = 128):
    pad = (-D.shape[1]) % mult
    if pad == 0:
        return D
    return jnp.pad(D, ((0, 0), (0, pad)))


@functools.partial(jax.jit, static_argnames=("ell_block", "use_pallas",
                                             "interpret"))
def ell_spmm(vals, idx, blocks, D, ell_block: int = 8,
             use_pallas: bool = False, interpret: bool = False):
    """out[r, q] = sum_k vals[r, k] * D[idx[r, k], q].

    vals/idx: (R, K) padded ELL rows (K a multiple of ``ell_block``);
    blocks: (R,) active K-block counts; D: (C, Q) dense. The ref path
    accumulates in the promoted input dtype (f64-exact for the
    equivalence tier); the Pallas path accumulates in f32 and pads D's
    lane dimension to the MXU multiple (exact: padded lanes are sliced
    back off).
    """
    R, K = vals.shape
    C, Q = D.shape
    if spmm_impl(R, K, C, Q, use_pallas or interpret,
                 jnp.dtype(vals.dtype).itemsize) == "pallas":
        out = ell_spmm_pallas(vals, idx, blocks, _pad_lanes(D),
                              ell_block=ell_block, interpret=interpret)
        return out[:, :Q]
    return _ref.ell_spmm_ref(vals, idx, D)


def scatter_dense(idx, vals, size: int):
    """Densify gathered ELL rows: idx/vals (r, K) -> (size, r) whose
    column j is the j-th gathered sparse row scattered into R^size —
    the dense right-operand block the fused products append vectors to
    (costs O(r * K) scatter-adds, not O(size * r) reads)."""
    r = idx.shape[0]
    return jnp.zeros((size, r), vals.dtype).at[
        idx, jnp.arange(r)[:, None]].add(vals)


def scatter_add(vec, idx, vals, coef):
    """vec + sum_j coef[j] * (j-th gathered sparse row): the ELL form of
    the deferred updates r += A_B dx / x += Y^T (b theta) — O(r * K)
    scatter-adds instead of a dense GEMV."""
    return vec.at[idx].add(vals * coef[:, None])


def scatter_steps(idx, vals, coef, size: int):
    """Per-step deferred vectors for the SA solvers: idx/vals
    (s, mu, K), coef (s, mu) -> (s, size) whose row t is block t's
    m-dimensional update  A_{B_t} dx_t  (the sparse analogue of the
    dense ``einsum("msc,sc->sm", ...)``)."""
    s = idx.shape[0]
    return jnp.zeros((s, size), vals.dtype).at[
        jnp.arange(s)[:, None, None], idx].add(vals * coef[..., None])
