from repro.kernels.svm_inner.ops import inner_impl, svm_inner_loop, vmem_ok

__all__ = ["inner_impl", "svm_inner_loop", "vmem_ok"]
