"""Public wrapper for the fused SVM inner s-loop.

Dispatch policy lives in ``repro.kernels.dispatch`` (shared with
``sa_inner``): ``inner_impl(s, mu, use_pallas)`` returns the path that
will actually run, warning once per (s, mu) about a forced Pallas -> ref
fallback; the SA solvers stash it in ``SolverResult.aux["inner_impl"]``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import vmem_ok
from repro.kernels.svm_inner import ref as _ref
from repro.kernels.svm_inner.kernel import svm_inner_pallas


def inner_impl(s: int, mu: int, use_pallas: bool,
               itemsize: int = 4) -> str:
    return dispatch.choose_inner_impl("svm_inner", s, mu, use_pallas,
                                      itemsize)


@functools.partial(jax.jit, static_argnames=(
    "gamma", "nu", "power_iters", "use_pallas", "interpret"))
def svm_inner_loop(G, proj, b_sel, a_vals, idx, gamma: float, nu: float,
                   power_iters: int = 32, use_pallas: bool = False,
                   interpret: bool = False):
    """Dispatch the s-step SVM inner loop (see ref.py for semantics)."""
    s, mu = proj.shape
    if inner_impl(s, mu, use_pallas or interpret,
                  jnp.dtype(G.dtype).itemsize) == "pallas":
        return svm_inner_pallas(G, proj, b_sel, a_vals, idx, gamma=gamma,
                                nu=nu, power_iters=power_iters,
                                interpret=interpret)
    return _ref.svm_inner_ref(G, proj, b_sel, a_vals, idx, gamma, nu,
                              power_iters)
