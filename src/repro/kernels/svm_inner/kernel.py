"""Pallas TPU kernel: the SVM s-step inner loop, entirely in VMEM.

Same TPU rethinking as ``repro.kernels.sa_inner``: the paper's
"redundantly execute the s inner iterations on every processor"
(Sec. III) becomes ONE kernel launch holding all replicated
O((s*mu)^2) state — the regularized block matrix G (linear Gram or
kernel block), the projections, labels, gathered duals and the growing
theta history — in VMEM, with zero intermediate HBM round-trips. Per
step: the t<j cross-term GEMV against G's off-diagonal blocks, the
power-iteration step size on the diagonal block (skipped for mu = 1,
where the (1, 1) block IS the eigenvalue), and the clipped dual update.

VMEM budget: the dominant resident is G at (s*mu)^2 * 4 bytes; ops.py
rejects configurations above ~8 MB (half of v5e's ~16 MB VMEM).

Single grid point — the loop is inherently sequential; these flops are
the SA trade's latency-free replicated work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import power_iter_max_eig


def _make_kernel(s: int, mu: int, gamma: float, nu: float,
                 power_iters: int):
    smu = s * mu
    finite_nu = nu == nu and nu != float("inf")

    def _clip(x):
        lo = jnp.maximum(x, 0.0)
        return jnp.minimum(lo, nu) if finite_nu else lo

    def kernel(G_ref, proj_ref, b_ref, avals_ref, idx_ref,
               theta_ref, dual_ref):
        theta_ref[...] = jnp.zeros_like(theta_ref)
        dual_ref[...] = jnp.zeros_like(dual_ref)
        idx_flat = idx_ref[...].reshape(1, smu)

        def body(j, _):
            b_j = b_ref[j, :]
            Gj = pl.load(G_ref, (pl.dslice(j * mu, mu), slice(None)))
            # (mu, s*mu)

            th_flat = theta_ref[...].reshape(1, smu)
            bt_flat = b_ref[...].reshape(1, smu) * th_flat
            t_ids = jax.lax.broadcasted_iota(jnp.int32, (s, mu), 0)
            mask = (t_ids < j).astype(jnp.float32).reshape(1, smu)

            cross = jnp.dot(Gj, (mask * bt_flat).reshape(smu, 1),
                            preferred_element_type=jnp.float32)   # (mu, 1)
            rj = proj_ref[j, :] + cross[:, 0]

            Gjj = pl.load(G_ref, (pl.dslice(j * mu, mu),
                                  pl.dslice(j * mu, mu)))
            # mu = 1: the diagonal "block" is the eigenvalue itself.
            vmax = Gjj[0, 0] if mu == 1 \
                else power_iter_max_eig(Gjj, power_iters)

            # collision-corrected alpha at this block's rows.
            idx_j = pl.load(idx_ref, (pl.dslice(j, 1), slice(None)))
            eq = (idx_j.reshape(mu, 1) == idx_flat).astype(jnp.float32)
            beta = avals_ref[j, :] + jnp.dot(
                eq, (mask * th_flat).reshape(smu, 1),
                preferred_element_type=jnp.float32)[:, 0]

            g = b_j * rj - 1.0 + gamma * beta
            gbar = jnp.abs(_clip(beta - g) - beta)
            theta = jnp.where(gbar != 0.0, _clip(beta - g / vmax) - beta,
                              0.0)

            bt = b_j * theta
            w = jnp.dot(bt.reshape(1, mu), Gjj,
                        preferred_element_type=jnp.float32)        # (1, mu)
            delta = jnp.sum(theta * g) + 0.5 * jnp.sum(w[0, :] * bt)

            pl.store(theta_ref, (pl.dslice(j, 1), slice(None)),
                     theta.reshape(1, mu))
            pl.store(dual_ref, (pl.dslice(j, 1), slice(None)),
                     delta.reshape(1, 1))
            return 0

        jax.lax.fori_loop(0, s, body, 0)

    return kernel


def svm_inner_pallas(G, proj, b_sel, a_vals, idx, *, gamma: float,
                     nu: float, power_iters: int = 32,
                     interpret: bool = False):
    """Run the s-step SVM inner loop in one kernel launch. All inputs are
    the replicated post-Allreduce quantities; see ref.py for shapes."""
    s, mu = proj.shape
    kernel = _make_kernel(s, mu, float(gamma), float(nu), power_iters)
    theta, duals = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((s, mu), jnp.float32),
                   jax.ShapeDtypeStruct((s, 1), jnp.float32)),
        interpret=interpret,
    )(G.astype(jnp.float32), proj.astype(jnp.float32),
      b_sel.astype(jnp.float32), a_vals.astype(jnp.float32),
      idx.astype(jnp.int32))
    return theta, duals[:, 0]
