"""Pure-jnp oracle for the SVM s-step inner loop (paper Alg. 4 lines
11-20, generalized to blocks and to kernel blocks).

Given the replicated outputs of the single Allreduce — the regularized
(s*mu, s*mu) block matrix G (Y Y^T + gamma*I for the linear solver,
K(Y, Y) + gamma*I for the kernel solver), the projections
proj = Y x_sk (linear) or f_sk[idx] (kernel), the labels / dual values
gathered at the start of the group and the sampled indices — run the s
dependent block updates and return the dual steps. This mirrors exactly
what repro.core.sa_svm / repro.core.kernel_svm used to inline in their
inner scans; the Pallas version (kernel.py) keeps all of it in VMEM.

Collisions: a row index repeating across the s blocks of a group is
corrected with the eq-matrix gather (alpha_j = a_vals[j] + sum over
earlier colliding steps), and the off-diagonal blocks of G carry the raw
cross terms even at repeated indices — together algebraically identical
to the classical method (DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def svm_inner_ref(G, proj, b_sel, a_vals, idx, gamma: float, nu: float,
                  power_iters: int = 32):
    """Reference s-step SVM inner loop.

    G:      (s*mu, s*mu) replicated block matrix, gamma already on the
            global diagonal (diagonal blocks only — the t<j cross-term
            mask never touches them)
    proj:   (s, mu)  Y_j x_sk (linear) / f_sk at block j's rows (kernel)
    b_sel:  (s, mu)  labels at the sampled rows
    a_vals: (s, mu)  alpha_sk gathered at each block's rows (group start)
    idx:    (s, mu)  sampled row ids (for collision corrections)
    Returns (theta (s, mu), dual_deltas (s,)) with dual_deltas[j] the
    j-th step's dual-objective increment
        theta^T g + 1/2 (b theta)^T G_jj (b theta).
    """
    # deferred import: repro.core.sa_svm imports this package, so a
    # module-level core import would close a cycle when this subpackage
    # is the entry point.
    from repro.core.linalg import power_iteration_max_eig

    s, mu = proj.shape
    dt = G.dtype
    G4 = G.reshape(s, mu, s, mu)
    idx_flat = idx.reshape(s * mu)
    nu = jnp.asarray(nu, dt)

    def body(carry, j):
        th_buf = carry                                  # (s, mu) raw theta
        b_j = b_sel[j]
        Gj = G4[j]                                      # (mu, s, mu)
        mask = (jnp.arange(s) < j).astype(dt)
        bt_buf = b_sel * th_buf
        cross = jnp.einsum("ptq,tq->tp", Gj, bt_buf)    # (s, mu)
        rj = proj[j] + jnp.einsum("t,tp->p", mask, cross)
        # collision-corrected alpha at this block's rows.
        eq = (idx[j][:, None] == idx_flat[None, :]).astype(dt)
        beta = a_vals[j] + eq @ (mask[:, None] * th_buf).reshape(s * mu)
        g = b_j * rj - 1.0 + gamma * beta
        Gjj = Gj[:, j, :]                               # (mu, mu) diag block
        # mu = 1: the (1, 1) diagonal block IS the eigenvalue (paper
        # Alg. 4's eta) — skip the power loop entirely.
        v = Gjj[0, 0] if mu == 1 \
            else power_iteration_max_eig(Gjj, power_iters)
        gbar = jnp.abs(jnp.clip(beta - g, 0.0, nu) - beta)
        theta = jnp.where(
            gbar != 0.0,
            jnp.clip(beta - g / v, 0.0, nu) - beta,
            0.0)
        bt = b_j * theta
        delta = jnp.sum(theta * g) + 0.5 * bt @ (Gjj @ bt)
        th_buf = th_buf.at[j].set(theta)
        return th_buf, delta

    th_buf, deltas = jax.lax.scan(
        body, jnp.zeros((s, mu), dt), jnp.arange(s))
    return th_buf, deltas
