"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage follows the repo convention:
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py    — jit'd public wrapper (shape checks, padding, CPU fallback)
    ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
    gram            — fused Gram + projection  Y^T [Y | V]  (paper hot spot)
    sa_inner        — the Lasso s-step SA inner loop, entirely in VMEM
    svm_inner       — the SVM s-step SA inner loop (linear + kernel blocks)
    flash_attention — blocked causal/sliding-window GQA attention

``dispatch`` is the shared Pallas-vs-ref policy; its helpers (and the
warn-once reset the test suite uses) are re-exported here.
"""
from repro.kernels.dispatch import (KernelVmemEntry, choose_inner_impl,
                                    choose_spmm_impl, kernel_vmem_model,
                                    reset_fallback_warnings, spmm_vmem_ok,
                                    vmem_ok)

# Every kernel package under repro.kernels — the enumeration the static
# kernel safety pass (repro.analysis.kernels) must cover: a new package
# added here without a registered describer fails the analyzer.
KERNEL_PACKAGES = ("gram", "spmm", "sa_inner", "svm_inner",
                   "flash_attention")

__all__ = [
    "KERNEL_PACKAGES", "KernelVmemEntry", "choose_inner_impl",
    "choose_spmm_impl", "kernel_vmem_model", "reset_fallback_warnings",
    "spmm_vmem_ok", "vmem_ok",
]
