"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage follows the repo convention:
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py    — jit'd public wrapper (shape checks, padding, CPU fallback)
    ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
    gram            — fused Gram + projection  Y^T [Y | V]  (paper hot spot)
    sa_inner        — the Lasso s-step SA inner loop, entirely in VMEM
    svm_inner       — the SVM s-step SA inner loop (linear + kernel blocks)
    flash_attention — blocked causal/sliding-window GQA attention
"""
