"""Shared Pallas-vs-ref dispatch policy for the fused inner-loop kernels
(``sa_inner`` for Lasso, ``svm_inner`` for SVM/K-SVM).

Both kernels hold the (s*mu, s*mu) replicated Gram/kernel block resident
in VMEM, so they share one budget: reject configurations whose G would
not leave room (~16 MB on v5e; we cap the resident G at half of it).
The chosen implementation is an explicit, queryable decision that warns
ONCE per (kernel, s, mu) when a requested Pallas route has to fall back
— the SA solvers surface it in ``SolverResult.aux["inner_impl"]`` so
benchmarks never mislabel ref timings as Pallas.
"""
from __future__ import annotations

import warnings

_VMEM_G_BYTES_CAP = 8 * 1024 * 1024

_warned = set()


def vmem_ok(s: int, mu: int) -> bool:
    return (s * mu) ** 2 * 4 <= _VMEM_G_BYTES_CAP


def choose_inner_impl(name: str, s: int, mu: int,
                      use_pallas: bool) -> str:
    """"pallas" or "ref", warning once per (name, s, mu) on a forced
    Pallas -> ref fallback."""
    if not use_pallas:
        return "ref"
    if vmem_ok(s, mu):
        return "pallas"
    if (name, s, mu) not in _warned:
        _warned.add((name, s, mu))
        warnings.warn(
            f"{name}: use_pallas=True but (s*mu)^2 Gram "
            f"({(s * mu) ** 2 * 4} B) exceeds the VMEM cap "
            f"({_VMEM_G_BYTES_CAP} B) for s={s}, mu={mu}; "
            f"falling back to the jnp reference path", stacklevel=3)
    return "ref"
