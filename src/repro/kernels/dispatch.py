"""Shared Pallas-vs-ref dispatch policy for the fused solver kernels
(``sa_inner`` for Lasso, ``svm_inner`` for SVM/K-SVM, ``spmm`` for the
sparse-operand products).

The inner-loop kernels hold the (s*mu, s*mu) replicated Gram/kernel
block resident in VMEM; the blocked-ELL SpMM holds its dense right
operand (plus the gathered values/indices and the output tile) resident.
Both share one budget: reject configurations that would not leave room
(~16 MB on v5e; we cap the resident working set at half of it). The
chosen implementation is an explicit, queryable decision that warns
ONCE per configuration when a requested Pallas route has to fall back
— the solvers surface it in ``SolverResult.aux["inner_impl"]`` /
``aux["spmm_impl"]`` so benchmarks never mislabel ref timings as Pallas.
"""
from __future__ import annotations

import warnings

_VMEM_G_BYTES_CAP = 8 * 1024 * 1024

_warned = set()


def vmem_ok(s: int, mu: int, itemsize: int = 4) -> bool:
    """Does the (s*mu)^2 Gram block fit the budget at ``itemsize``
    bytes/element? The guards were historically dtype-blind (hardcoded
    4 B/element) — an f64 solve holds f64 residents, so near-cap configs
    dispatched Pallas with TWICE the modeled VMEM. Callers thread the
    solve dtype's itemsize through."""
    return (s * mu) ** 2 * itemsize <= _VMEM_G_BYTES_CAP


def reset_fallback_warnings() -> None:
    """Forget which fallback configurations have already warned.

    The warn-once memo is process-global, which is right for a solver
    run but leaks across tests: whichever test first trips a fallback
    swallows the warning every later test asserts on (order-dependent
    flakiness). The test suite resets it around every test (see
    tests/conftest.py); long-lived drivers can call it to re-arm the
    warnings after reconfiguring."""
    _warned.clear()


def _warn_fallback(key, message: str) -> None:
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, stacklevel=4)


def choose_inner_impl(name: str, s: int, mu: int,
                      use_pallas: bool, itemsize: int = 4) -> str:
    """"pallas" or "ref", warning once per (name, s, mu, itemsize) on a
    forced Pallas -> ref fallback."""
    if not use_pallas:
        return "ref"
    if vmem_ok(s, mu, itemsize):
        return "pallas"
    _warn_fallback(
        (name, s, mu, itemsize),
        f"{name}: use_pallas=True but (s*mu)^2 Gram "
        f"({(s * mu) ** 2 * itemsize} B at {itemsize} B/element) "
        f"exceeds the VMEM cap ({_VMEM_G_BYTES_CAP} B) for s={s}, "
        f"mu={mu}; falling back to the jnp reference path")
    return "ref"


def spmm_vmem_ok(R: int, K: int, C: int, Q: int,
                 itemsize: int = 4) -> bool:
    """Does the blocked-ELL SpMM working set — the VMEM-resident dense
    right operand (C, Q) (lane-padded), the output (R, Q), and the
    gathered values (R, K), all at ``itemsize`` bytes/element, plus the
    int32 indices (R, K) at 4 B — fit the budget?"""
    qp = -(-Q // 128) * 128
    return (C * qp + R * qp + R * K) * itemsize + R * K * 4 \
        <= _VMEM_G_BYTES_CAP


def choose_spmm_impl(R: int, K: int, C: int, Q: int,
                     use_pallas: bool, itemsize: int = 4) -> str:
    """"pallas" or "ref" for an (R, K) x (C, Q) blocked-ELL SpMM,
    warning once per (shape, itemsize) on a forced Pallas -> ref
    fallback."""
    if not use_pallas:
        return "ref"
    if spmm_vmem_ok(R, K, C, Q, itemsize):
        return "pallas"
    _warn_fallback(
        ("spmm", R, K, C, Q, itemsize),
        f"spmm: use_pallas=True but the blocked-ELL working set for "
        f"R={R}, K={K}, C={C}, Q={Q} at {itemsize} B/element exceeds "
        f"the VMEM cap ({_VMEM_G_BYTES_CAP} B); falling back to the "
        f"jnp reference path")
    return "ref"
