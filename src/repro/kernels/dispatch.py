"""Shared Pallas-vs-ref dispatch policy for the fused solver kernels
(``sa_inner`` for Lasso, ``svm_inner`` for SVM/K-SVM, ``spmm`` for the
sparse-operand products).

The inner-loop kernels hold the (s*mu, s*mu) replicated Gram/kernel
block resident in VMEM; the blocked-ELL SpMM holds its dense right
operand (plus the gathered values/indices and the output tile) resident.
Both share one budget: reject configurations that would not leave room
(~16 MB on v5e; we cap the resident working set at half of it). The
chosen implementation is an explicit, queryable decision that warns
ONCE per configuration when a requested Pallas route has to fall back
— the solvers surface it in ``SolverResult.aux["inner_impl"]`` /
``aux["spmm_impl"]`` so benchmarks never mislabel ref timings as Pallas.

The guards' resident-set formulas live in ONE queryable table,
:func:`kernel_vmem_model` — consumed by the ``vmem_ok`` /
``spmm_vmem_ok`` dispatch guards below AND by the static kernel safety
pass (``repro.analysis.kernels``), which re-derives each package's true
footprint from its BlockSpecs and flags any drift between the two.
Historically the formulas were literals duplicated here, which is
exactly how the f64 2x-VMEM dispatch bug (PR 5) crept in.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Mapping, Tuple

_VMEM_G_BYTES_CAP = 8 * 1024 * 1024

_warned = set()


@dataclasses.dataclass(frozen=True)
class KernelVmemEntry:
    """One kernel package's modeled VMEM residency.

    resident_bytes: keyword-only callable mapping the package's
        configuration parameters (named in ``params``) to the modeled
        resident working set in bytes. This is the number the dispatch
        guard compares against ``cap`` — and the number the kernel
        safety pass cross-checks against the footprint it derives from
        the package's BlockSpecs/operand shapes.
    params: the keyword names ``resident_bytes`` accepts, documented so
        callers can introspect the table.
    cap: admission threshold in bytes (the shared budget).
    doc: what the model counts (and deliberately over-counts).
    """

    kernel: str
    params: Tuple[str, ...]
    resident_bytes: Callable[..., float]
    cap: int = _VMEM_G_BYTES_CAP
    doc: str = ""

    def ok(self, **kw) -> bool:
        return self.resident_bytes(**kw) <= self.cap


def _inner_bytes(s: int, mu: int, itemsize: int = 4) -> float:
    return float((s * mu) ** 2 * itemsize)


def _spmm_bytes(R: int, K: int, C: int, Q: int,
                itemsize: int = 4) -> float:
    qp = -(-Q // 128) * 128
    return float((C * qp + R * qp + R * K) * itemsize + R * K * 4)


def _gram_bytes(block_m: int = 256, block_i: int = 128,
                block_j: int = 128, itemsize: int = 4) -> float:
    # double-buffered input tiles + the output tile + the f32 scratch
    # accumulator (scratch is always f32 regardless of input dtype).
    return float((2 * (block_m * block_i + block_m * block_j)
                  + block_i * block_j) * itemsize
                 + block_i * block_j * 4)


def _flash_bytes(block_q: int = 128, block_k: int = 128,
                 head_dim: int = 128, itemsize: int = 4) -> float:
    # double-buffered q/k/v tiles + the output tile + the f32 running
    # (acc, m, l) online-softmax scratch.
    return float((2 * (block_q + 2 * block_k) * head_dim
                  + block_q * head_dim) * itemsize
                 + (block_q * head_dim + 2 * block_q) * 4)


_VMEM_MODEL: Dict[str, KernelVmemEntry] = {
    "sa_inner": KernelVmemEntry(
        "sa_inner", ("s", "mu", "itemsize"), _inner_bytes,
        doc="the dominant resident: the (s*mu)^2 Gram block (the "
            "O(s*mu) projections/schedule arrays ride within the "
            "budget's 2x headroom)"),
    "svm_inner": KernelVmemEntry(
        "svm_inner", ("s", "mu", "itemsize"), _inner_bytes,
        doc="the (s*mu)^2 regularized Gram/kernel block, as sa_inner"),
    "spmm": KernelVmemEntry(
        "spmm", ("R", "K", "C", "Q", "itemsize"), _spmm_bytes,
        doc="the lane-padded dense right operand (C, Qp), the output "
            "(R, Qp), the gathered values (R, K) at itemsize, plus "
            "int32 indices (R, K) — conservatively counting ALL R row "
            "tiles although only one is block-resident at a time"),
    "gram": KernelVmemEntry(
        "gram", ("block_m", "block_i", "block_j", "itemsize"),
        _gram_bytes,
        doc="double-buffered (block_m, block_i)/(block_m, block_j) "
            "input tiles, the (block_i, block_j) output tile and its "
            "f32 accumulator scratch"),
    "flash_attention": KernelVmemEntry(
        "flash_attention", ("block_q", "block_k", "head_dim",
                            "itemsize"), _flash_bytes,
        doc="double-buffered (block_q, D) query and (block_k, D) "
            "key/value tiles, the output tile and the f32 online-"
            "softmax (acc, m, l) scratch"),
}


def kernel_vmem_model() -> Mapping[str, KernelVmemEntry]:
    """The queryable VMEM residency table: one
    :class:`KernelVmemEntry` per kernel package under ``repro.kernels``.
    The SINGLE source of the guard formulas — dispatch admission
    (:func:`vmem_ok`, :func:`spmm_vmem_ok`) and the static kernel
    safety pass (``repro.analysis.kernels``) both read it, so a formula
    edit cannot drift the two apart."""
    return dict(_VMEM_MODEL)


def vmem_ok(s: int, mu: int, itemsize: int = 4) -> bool:
    """Does the (s*mu)^2 Gram block fit the budget at ``itemsize``
    bytes/element? The guards were historically dtype-blind (hardcoded
    4 B/element) — an f64 solve holds f64 residents, so near-cap configs
    dispatched Pallas with TWICE the modeled VMEM. Callers thread the
    solve dtype's itemsize through."""
    return _VMEM_MODEL["sa_inner"].ok(s=s, mu=mu, itemsize=itemsize)


def reset_fallback_warnings() -> None:
    """Forget which fallback configurations have already warned.

    The warn-once memo is process-global, which is right for a solver
    run but leaks across tests: whichever test first trips a fallback
    swallows the warning every later test asserts on (order-dependent
    flakiness). The test suite resets it around every test (see
    tests/conftest.py); long-lived drivers can call it to re-arm the
    warnings after reconfiguring."""
    _warned.clear()


def _warn_fallback(key, message: str) -> None:
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, stacklevel=4)


def choose_inner_impl(name: str, s: int, mu: int,
                      use_pallas: bool, itemsize: int = 4) -> str:
    """"pallas" or "ref", warning once per (name, s, mu, itemsize) on a
    forced Pallas -> ref fallback."""
    if not use_pallas:
        return "ref"
    if vmem_ok(s, mu, itemsize):
        return "pallas"
    _warn_fallback(
        (name, s, mu, itemsize),
        f"{name}: use_pallas=True but (s*mu)^2 Gram "
        f"({(s * mu) ** 2 * itemsize} B at {itemsize} B/element) "
        f"exceeds the VMEM cap ({_VMEM_G_BYTES_CAP} B) for s={s}, "
        f"mu={mu}; falling back to the jnp reference path")
    return "ref"


def spmm_vmem_ok(R: int, K: int, C: int, Q: int,
                 itemsize: int = 4) -> bool:
    """Does the blocked-ELL SpMM working set — the VMEM-resident dense
    right operand (C, Q) (lane-padded), the output (R, Q), and the
    gathered values (R, K), all at ``itemsize`` bytes/element, plus the
    int32 indices (R, K) at 4 B — fit the budget?"""
    return _VMEM_MODEL["spmm"].ok(R=R, K=K, C=C, Q=Q, itemsize=itemsize)


def choose_spmm_impl(R: int, K: int, C: int, Q: int,
                     use_pallas: bool, itemsize: int = 4) -> str:
    """"pallas" or "ref" for an (R, K) x (C, Q) blocked-ELL SpMM,
    warning once per (shape, itemsize) on a forced Pallas -> ref
    fallback."""
    if not use_pallas:
        return "ref"
    if spmm_vmem_ok(R, K, C, Q, itemsize):
        return "pallas"
    _warn_fallback(
        ("spmm", R, K, C, Q, itemsize),
        f"spmm: use_pallas=True but the blocked-ELL working set for "
        f"R={R}, K={K}, C={C}, Q={Q} at {itemsize} B/element exceeds "
        f"the VMEM cap ({_VMEM_G_BYTES_CAP} B); falling back to the "
        f"jnp reference path")
    return "ref"
