from repro.kernels.gram.ops import gram_and_proj, gram_t
