"""Public wrapper for the fused Gram + projection kernel.

Pads inputs to MXU-aligned block multiples, dispatches to the Pallas
kernel on TPU (or interpret mode when requested) and to the jnp reference
otherwise. Zero padding is exact: padded rows/columns contribute zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram import ref as _ref
from repro.kernels.gram.kernel import gram_t_pallas


def _pad_axis(x, mult: int, axis: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_blocks(m: int, p: int, q: int):
    """VMEM-aware block choice: keep (bm*bi + bm*bj + bi*bj) * 4B well
    under ~16 MB VMEM while keeping lane dims MXU-aligned (128)."""
    bi = 128 if p >= 128 else max(8, 1 << (p - 1).bit_length())
    bj = 128 if q >= 128 else max(8, 1 << (q - 1).bit_length())
    bm = 512 if m >= 512 else max(8, 1 << (m - 1).bit_length())
    return bm, bi, bj


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gram_t(x, y, use_pallas: bool = False, interpret: bool = False):
    """x^T @ y with f32 accumulation. x (m, p), y (m, q) -> (p, q)."""
    if not (use_pallas or interpret):
        return _ref.gram_t_ref(x, y)
    m, p = x.shape
    q = y.shape[1]
    bm, bi, bj = _pick_blocks(m, p, q)
    xp = _pad_axis(_pad_axis(x, bm, 0), bi, 1)
    yp = _pad_axis(_pad_axis(y, bm, 0), bj, 1)
    out = gram_t_pallas(xp, yp, block_m=bm, block_i=bi, block_j=bj,
                        interpret=interpret)
    return out[:p, :q]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def gram_and_proj(Y, V, use_pallas: bool = False, interpret: bool = False):
    """Fused  Y^T [Y | V]  ->  (G, P)  — paper Alg. 2 lines 11-12.

    One pass over Y (per outer iteration) produces both the (c, c) Gram
    matrix and the (c, k) projections; the caller follows with a single
    Allreduce of the concatenated result.
    """
    c = Y.shape[1]
    out = gram_t(Y, jnp.concatenate([Y, V], axis=1),
                 use_pallas=use_pallas, interpret=interpret)
    return out[:, :c], out[:, c:]
