"""Pallas TPU kernel: blocked transpose-GEMM  out = x^T @ y.

This is the paper's dominant flop term — the (s*mu) x (s*mu) Gram matrix
G = Y^T Y plus the fused projections Y^T [ytil | ztil] (Alg. 2 lines
11-12), computed in ONE pass over Y per outer iteration.

TPU mapping:
  * grid = (p/bi, q/bj, m/bm); the m (reduction) axis is the innermost,
    "arbitrary" dimension so the f32 VMEM accumulator persists across its
    steps while (i, j) output tiles parallelize.
  * Block shapes (bm, bi)/(bm, bj) are chosen MXU-aligned (multiples of
    128 in the lane dim, 8 in the sublane dim) by ops.py.
  * Accumulation is always f32 (preferred_element_type), independent of
    the input dtype — bf16 inputs hit the MXU, f32 accumulate, matching
    how the paper's MKL GEMM accumulates in higher precision.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _gram_kernel(x_ref, y_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], y_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),   # contract over m
        preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gram_t_pallas(x, y, *, block_m: int = 256, block_i: int = 128,
                  block_j: int = 128, interpret: bool = False):
    """out[p, q] = sum_m x[m, p] * y[m, q]; shapes must divide the blocks
    (ops.py pads)."""
    m, p = x.shape
    m2, q = y.shape
    if m != m2:
        raise ValueError(f"contraction dims differ: {x.shape} vs {y.shape}")
    if m % block_m or p % block_i or q % block_j:
        raise ValueError(
            f"shapes ({m}, {p}) x ({m2}, {q}) do not divide blocks "
            f"({block_m}, {block_i}, {block_j})")

    grid = (p // block_i, q // block_j, m // block_m)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_i), lambda i, j, k: (k, i)),
            pl.BlockSpec((block_m, block_j), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_i, block_j), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, q), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_i, block_j), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, y)
