"""Pure-jnp oracle for the fused Gram + projection kernel."""
import jax.numpy as jnp


def gram_t_ref(x, y):
    """x^T @ y with f32 accumulation: x (m, p), y (m, q) -> (p, q)."""
    return jnp.dot(x.T.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def gram_and_proj_ref(Y, V):
    """Fused  Y^T [Y | V]  ->  (G, P): the paper Alg. 2 lines 11-12 pair.

    Y: (m, c) sampled columns; V: (m, k) residual-like vectors.
    Returns G (c, c) and P (c, k), both f32.
    """
    out = gram_t_ref(Y, jnp.concatenate([Y, V], axis=1))
    c = Y.shape[1]
    return out[:, :c], out[:, c:]
