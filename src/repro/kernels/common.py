"""In-kernel helpers shared by the fused inner-loop Pallas kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def power_iter_max_eig(Gjj, iters: int):
    """Largest eigenvalue of a (mu, mu) PSD block via fixed-count power
    iteration, row-vector form (TPU-friendly shapes). Runs inside a
    Pallas kernel body."""
    mu = Gjj.shape[0]
    v = jnp.full((1, mu), 1.0 / jnp.sqrt(jnp.float32(mu)), jnp.float32)

    def body(_, v):
        w = jnp.dot(v, Gjj, preferred_element_type=jnp.float32)
        nrm = jnp.sqrt(jnp.sum(w * w))
        return w / jnp.maximum(nrm, 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return jnp.sum(jnp.dot(v, Gjj, preferred_element_type=jnp.float32) * v) \
        / jnp.maximum(jnp.sum(v * v), 1e-30)
