"""Pure-jnp oracle for the SA accelerated inner loop (paper Alg. 2 lines
13-22, specialized to Lasso / elastic-net prox).

Given the replicated outputs of the single Allreduce — the Gram matrix G,
the projections y_proj = A_j^T ytil_sk / z_proj = A_j^T ztil_sk, the
sampled-coordinate values z_vals = z_sk[idx] and the theta schedule — run
the s dependent inner steps and return (dz, etas). This mirrors exactly
what repro.core.sa_lasso does inside its inner scan; the kernel version
keeps all of it in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.linalg import floor_eig, power_iteration_max_eig


def sa_inner_ref(G, y_proj, z_proj, z_vals, idx, th_prev, coefU,
                 q: float, lam1: float, lam2: float = 0.0,
                 power_iters: int = 32):
    """Reference s-step inner loop.

    G:      (s*mu, s*mu) replicated Gram matrix Y^T Y
    y_proj: (s, mu)   A_j^T ytil_sk
    z_proj: (s, mu)   A_j^T ztil_sk
    z_vals: (s, mu)   z_sk gathered at each block's coordinates
    idx:    (s, mu)   sampled coordinate ids (for collision corrections)
    th_prev:(s,)      theta_{sk+j-1}
    coefU:  (s,)      (1 - q*theta_{sk+j-1}) / theta_{sk+j-1}^2
    Returns (dz (s, mu), etas (s,)).
    """
    s, mu = y_proj.shape
    G4 = G.reshape(s, mu, s, mu)
    idx_flat = idx.reshape(s * mu)

    def body(carry, j):
        dz_buf = carry
        thp = th_prev[j]
        Gj = G4[j]                                     # (mu, s, mu)
        cross = jnp.einsum("ptq,tq->tp", Gj, dz_buf)   # (s, mu)
        coef_t = thp * thp * coefU - 1.0
        mask = (jnp.arange(s) < j).astype(G.dtype)
        rj = thp * thp * y_proj[j] + z_proj[j] \
            - jnp.einsum("t,t,tp->p", mask, coef_t, cross)
        v = power_iteration_max_eig(Gj[:, j, :], power_iters)
        eta = 1.0 / floor_eig(q * thp * v)  # floored: zero block -> no-op
        # collision-corrected current z at this block's coordinates.
        eq = (idx[j][:, None] == idx_flat[None, :]).astype(G.dtype)
        w = (mask[:, None] * dz_buf).reshape(s * mu)
        zj = z_vals[j] + eq @ w
        g = zj - eta * rj
        shrunk = jnp.sign(g) * jnp.maximum(jnp.abs(g) - lam1 * eta, 0.0)
        dz = shrunk / (1.0 + 2.0 * eta * lam2) - zj
        dz_buf = dz_buf.at[j].set(dz)
        return dz_buf, eta

    dz_buf, etas = jax.lax.scan(
        body, jnp.zeros((s, mu), G.dtype), jnp.arange(s))
    return dz_buf, etas
