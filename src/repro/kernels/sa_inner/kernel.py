"""Pallas TPU kernel: the SA accelerated inner loop, entirely in VMEM.

TPU-native rethinking of the paper's "redundantly execute the s inner
iterations on every processor" (Sec. III): on MPI every rank runs scalar
code between HBM-resident vectors; on TPU we place the replicated
O((s*mu)^2) state — the Gram matrix, projections, theta schedule and the
growing dz history — in VMEM once and run all s dependent steps inside a
single kernel launch with zero intermediate HBM round-trips.

VMEM budget: the dominant resident is G at (s*mu)^2 * 4 bytes; ops.py
rejects configurations above ~8 MB (half of v5e's ~16 MB VMEM), which
still admits e.g. s=128, mu=8 or s=1024, mu=1 — the paper's largest
settings.

Single grid point (the loop is inherently sequential — that is the SA
trade: these flops are latency-free replicated work).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import power_iter_max_eig

_F32_TINY = float(jnp.finfo(jnp.float32).tiny)


def _make_kernel(s: int, mu: int, q: float, lam1: float, lam2: float,
                 power_iters: int):
    smu = s * mu

    def kernel(G_ref, yproj_ref, zproj_ref, zvals_ref, idx_ref,
               thprev_ref, coefU_ref, dz_ref, eta_ref):
        dz_ref[...] = jnp.zeros_like(dz_ref)
        eta_ref[...] = jnp.zeros_like(eta_ref)
        idx_flat = idx_ref[...].reshape(1, smu)
        coefU = coefU_ref[...].reshape(s)

        def body(j, _):
            thp = thprev_ref[j, 0]
            Gj = pl.load(G_ref, (pl.dslice(j * mu, mu), slice(None)))
            # (mu, s*mu)

            dz_flat = dz_ref[...].reshape(1, smu)
            # per-t weights, broadcast over the mu columns of each block.
            t_ids = jax.lax.broadcasted_iota(jnp.int32, (s, mu), 0)
            mask = (t_ids < j).astype(jnp.float32).reshape(1, smu)
            coef = (thp * thp * coefU - 1.0)
            coef_rep = jnp.repeat(coef, mu).reshape(1, smu)

            cross = jnp.dot(Gj, (mask * coef_rep * dz_flat).reshape(smu, 1),
                            preferred_element_type=jnp.float32)   # (mu, 1)
            rj = thp * thp * yproj_ref[j, :] + zproj_ref[j, :] - cross[:, 0]

            Gjj = pl.load(G_ref, (pl.dslice(j * mu, mu),
                                  pl.dslice(j * mu, mu)))
            # mu = 1: the diagonal "block" is the eigenvalue itself.
            vmax = Gjj[0, 0] if mu == 1 \
                else power_iter_max_eig(Gjj, power_iters)
            # same floor as linalg.floor_eig at the kernel's f32 compute
            # dtype: an all-zero block otherwise yields eta = inf and
            # inf * 0 = NaN against its zero projection.
            eta = 1.0 / jnp.maximum(q * thp * vmax, _F32_TINY)

            # collision-corrected z at this block's coordinates.
            idx_j = pl.load(idx_ref, (pl.dslice(j, 1), slice(None)))  # (1, mu)
            eq = (idx_j.reshape(mu, 1) == idx_flat).astype(jnp.float32)
            zj = zvals_ref[j, :] + jnp.dot(
                eq, (mask * dz_flat).reshape(smu, 1),
                preferred_element_type=jnp.float32)[:, 0]

            g = zj - eta * rj
            shrunk = jnp.sign(g) * jnp.maximum(jnp.abs(g) - lam1 * eta, 0.0)
            dz = shrunk / (1.0 + 2.0 * eta * lam2) - zj

            pl.store(dz_ref, (pl.dslice(j, 1), slice(None)),
                     dz.reshape(1, mu))
            pl.store(eta_ref, (pl.dslice(j, 1), slice(None)),
                     eta.reshape(1, 1))
            return 0

        jax.lax.fori_loop(0, s, body, 0)

    return kernel


def sa_inner_pallas(G, y_proj, z_proj, z_vals, idx, th_prev, coefU,
                    *, q: float, lam1: float, lam2: float = 0.0,
                    power_iters: int = 32, interpret: bool = False):
    """Run the s-step inner loop in one kernel. All inputs are the
    replicated post-Allreduce quantities; see ref.py for shapes."""
    s, mu = y_proj.shape
    kernel = _make_kernel(s, mu, float(q), float(lam1), float(lam2),
                          power_iters)
    dz, etas = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((s, mu), jnp.float32),
                   jax.ShapeDtypeStruct((s, 1), jnp.float32)),
        interpret=interpret,
    )(G.astype(jnp.float32), y_proj.astype(jnp.float32),
      z_proj.astype(jnp.float32), z_vals.astype(jnp.float32),
      idx.astype(jnp.int32), th_prev.reshape(s, 1).astype(jnp.float32),
      coefU.reshape(s, 1).astype(jnp.float32))
    return dz, etas[:, 0]
