"""Public wrapper for the fused SA inner loop."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sa_inner import ref as _ref
from repro.kernels.sa_inner.kernel import sa_inner_pallas

# Reject configurations whose Gram matrix would not leave room in VMEM
# (~16 MB on v5e; we cap the resident G at half of it).
_VMEM_G_BYTES_CAP = 8 * 1024 * 1024


def vmem_ok(s: int, mu: int) -> bool:
    return (s * mu) ** 2 * 4 <= _VMEM_G_BYTES_CAP


@functools.partial(jax.jit, static_argnames=(
    "q", "lam1", "lam2", "power_iters", "use_pallas", "interpret"))
def sa_inner_loop(G, y_proj, z_proj, z_vals, idx, th_prev, coefU,
                  q: float, lam1: float, lam2: float = 0.0,
                  power_iters: int = 32,
                  use_pallas: bool = False, interpret: bool = False):
    """Dispatch the s-step SA inner loop (see ref.py for semantics)."""
    s, mu = y_proj.shape
    if (use_pallas or interpret) and vmem_ok(s, mu):
        return sa_inner_pallas(
            G, y_proj, z_proj, z_vals, idx, th_prev, coefU,
            q=q, lam1=lam1, lam2=lam2, power_iters=power_iters,
            interpret=interpret)
    return _ref.sa_inner_ref(G, y_proj, z_proj, z_vals, idx, th_prev,
                             coefU, q, lam1, lam2, power_iters)
