"""Public wrapper for the fused SA inner loop.

Dispatch policy lives in ``repro.kernels.dispatch`` (shared with
``svm_inner``): ``inner_impl(s, mu, use_pallas)`` returns the path that
will actually run, warning once per (s, mu) about a forced Pallas -> ref
fallback, so benchmarks never mislabel ref timings as Pallas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.dispatch import vmem_ok
from repro.kernels.sa_inner import ref as _ref
from repro.kernels.sa_inner.kernel import sa_inner_pallas


def inner_impl(s: int, mu: int, use_pallas: bool,
               itemsize: int = 4) -> str:
    return dispatch.choose_inner_impl("sa_inner", s, mu, use_pallas,
                                      itemsize)


@functools.partial(jax.jit, static_argnames=(
    "q", "lam1", "lam2", "power_iters", "use_pallas", "interpret"))
def sa_inner_loop(G, y_proj, z_proj, z_vals, idx, th_prev, coefU,
                  q: float, lam1: float, lam2: float = 0.0,
                  power_iters: int = 32,
                  use_pallas: bool = False, interpret: bool = False):
    """Dispatch the s-step SA inner loop (see ref.py for semantics)."""
    s, mu = y_proj.shape
    if inner_impl(s, mu, use_pallas or interpret,
                  jnp.dtype(G.dtype).itemsize) == "pallas":
        return sa_inner_pallas(
            G, y_proj, z_proj, z_vals, idx, th_prev, coefU,
            q=q, lam1=lam1, lam2=lam2, power_iters=power_iters,
            interpret=interpret)
    return _ref.sa_inner_ref(G, y_proj, z_proj, z_vals, idx, th_prev,
                             coefU, q, lam1, lam2, power_iters)
