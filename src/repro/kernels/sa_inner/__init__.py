from repro.kernels.sa_inner.ops import sa_inner_loop
