from repro.kernels.sa_inner.ops import (inner_impl, sa_inner_loop,
                                        vmem_ok)

__all__ = ["inner_impl", "sa_inner_loop", "vmem_ok"]
