"""Sharding rules for the model zoo over the production mesh.

Mesh axes: ('data', 'model') single-pod, ('pod', 'data', 'model')
multi-pod. Strategy (DESIGN.md):

* TP  — attention heads / FFN hidden / vocab over 'model' (Megatron-style:
  column-parallel in-projections, row-parallel out-projections).
* FSDP — the remaining weight dim over 'data' (XLA all-gathers per layer).
  Replicated across pods: intra-pod FSDP + cross-pod gradient reduction is
  the hierarchical schedule (cross-pod traffic = one gradient allreduce).
* EP  — MoE expert dim over 'model' (detected by the 'moe' path segment).
* DP  — batch over ('pod', 'data').
* SP  — layer-boundary activations sharded over 'model' on the sequence
  dim (sequence parallelism), bounding saved-activation memory.
* decode — KV cache sequence dim over 'model' (split-KV / flash-decode
  style partial attention; XLA inserts the softmax reduction).

Rules are path+shape based so they apply uniformly across the zoo,
including scan-stacked params (leading group axes get None). Every spec is
SANITIZED against the actual mesh: any named axis that does not evenly
divide its dim falls back to replication for that dim (e.g. odd vocab
sizes like 49155, batch=1 decode, 25-head hymba projections).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def get_abstract_mesh():
    """The mesh currently in context (``repro.launch.mesh.set_mesh``).

    ``jax.sharding.get_abstract_mesh`` where available; on older jax the
    ``with mesh:`` context populates the legacy thread-resources env,
    whose physical mesh exposes the same ``empty`` / ``axis_names`` /
    ``shape`` surface the callers need.
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_lib
    return _mesh_lib.thread_resources.env.physical_mesh

_COL = ("wq", "wk", "wv", "w_gate", "w_up", "w_z", "w_i", "w_f", "w_o")
_ROW = ("wo", "w_down")
_REPL = ("scale", "b_decay", "b_f", "router", "w_decay",
         "r_z", "r_i", "r_f", "r_o", "meta", "pos_embed")


def _rule_for(name: str, shape: Tuple[int, ...], in_moe: bool,
              fsdp: str, tp: str, tp_size: int = 0) -> P:
    nd = len(shape)

    def pad(spec_tail):
        return P(*([None] * (nd - len(spec_tail))), *spec_tail)

    if name == "embed":
        return P(tp, fsdp)                     # (V, D): vocab-parallel
    if name == "unembed":
        return P(fsdp, tp)                     # (D, V)
    if name in _REPL:
        return P(*([None] * nd))
    if name in ("bq", "bk", "bv"):
        return pad((tp,))
    if in_moe and nd >= 3:
        n_experts = shape[nd - 3]
        ep_ok = tp_size > 0 and n_experts % tp_size == 0
        if name in ("w_gate", "w_up"):
            # EP when the expert count divides the TP axis (granite 32e);
            # otherwise expert-TP: split each expert's FFN over 'model'
            # (mixtral 8e on a 16-wide axis).
            return pad((tp, fsdp, None)) if ep_ok else pad((None, fsdp, tp))
        if name == "w_down":
            return pad((tp, None, fsdp)) if ep_ok else pad((None, tp, fsdp))
    if name in _COL and nd >= 2:
        return pad((fsdp, tp))                 # (D_in, D_out) column-par
    if name in _ROW and nd >= 2:
        return pad((tp, fsdp))                 # row-parallel
    if name.startswith("w_") and nd >= 2:      # misc projections
        return pad((fsdp, tp))
    return P(*([None] * nd))


def sanitize_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop named axes that don't exist on the mesh or don't divide the
    dim; jit requires exact divisibility for explicit in_shardings."""
    parts = []
    for dim, part in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(spec))):
        if part is None:
            parts.append(None)
            continue
        names = (part,) if isinstance(part, str) else tuple(part)
        names = tuple(n for n in names if n in mesh.axis_names)
        size = int(np.prod([mesh.shape[n] for n in names])) if names else 1
        if not names or size == 0 or dim % size != 0:
            parts.append(None)
        else:
            parts.append(names if len(names) > 1 else names[0])
    return P(*parts)


def param_partition_specs(param_tree, mesh: Optional[Mesh] = None,
                          fsdp: str = "data", tp: str = "model"):
    """PartitionSpec tree matching ``param_tree`` (arrays or SDS)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_tree)
    specs = []
    tp_size = int(mesh.shape[tp]) if mesh is not None \
        and tp in mesh.axis_names else 0
    for path, leaf in flat:
        keys = [str(e.key) for e in path
                if isinstance(e, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        in_moe = "moe" in keys[:-1]
        spec = _rule_for(name, leaf.shape, in_moe, fsdp, tp, tp_size)
        if mesh is not None:
            spec = sanitize_spec(spec, leaf.shape, mesh)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes present on this mesh ('pod' first)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_partition_specs(batch_tree, mesh: Mesh, kind: str = "train"):
    """Input sharding: batch dim over the DP axes; decode caches shard the
    KV sequence dim over 'model' (split-KV)."""
    dp = dp_axes(mesh)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def spec_for(path, leaf):
        nd = len(leaf.shape)
        keys = [str(e.key) for e in path
                if isinstance(e, jax.tree_util.DictKey)]
        name = keys[-1] if keys else ""
        if nd == 0:
            return P()
        if name in ("k", "v") and nd == 5:
            # stacked KV cache (G, B, Hkv, S, D): batch over DP, cache
            # sequence over 'model' (split-KV decode).
            spec = P(None, dp_spec, None, "model", None)
        elif name.startswith(("ssm_", "mlstm_", "slstm_")):
            spec = P(None, dp_spec, *([None] * (nd - 2)))
        else:
            # tokens/targets/frames/patches: batch first.
            spec = P(dp_spec, *([None] * (nd - 1)))
        return sanitize_spec(spec, leaf.shape, mesh)

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for(p, l) for p, l in flat])


def named_shardings(tree, specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def activation_spec(mesh_axis_names) -> P:
    """Layer-boundary residual sharding: batch over DP, sequence over
    'model' (sequence parallelism)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh_axis_names)
    dp_spec = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(dp_spec, "model", None)
