from repro.parallel.sharding import (param_partition_specs,
                                     batch_partition_specs, dp_axes,
                                     named_shardings)
