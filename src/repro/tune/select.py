"""Guard-aware config selection: sweep the registry-declared ``costs``
hook over the family's ``tune_space`` with a (calibrated) ``Machine``
and return the complete tuned ``SolverConfig``.

Selection is pure model evaluation — no solves — so it reruns cheaply
for any H once a machine is calibrated. Three constraints make the
result an *executable* recommendation rather than a paper number:

* **VMEM guards** (``repro.kernels.dispatch``): ``use_pallas`` is only
  recommended when the fused inner kernel's (s*mu)^2 Gram block — and,
  for sparse operands, every blocked-ELL SpMM the solve would dispatch
  — fits the budget at the solve dtype's itemsize. A recommendation
  that silently falls back to ref would make the tuner's own
  measurements lies.
* **Structural blocks**: group-lasso problems have mu fixed to the
  declared group size; the sweep only varies s.
* **symmetric_gram** is only proposed for families whose SA solvers
  honor it (registry flag), and only when the halved Gram message
  actually wins under the calibrated beta.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cost_model
from repro.core.cost_model import Machine
from repro.core.types import SolverConfig, SparseOperand
from repro.kernels import dispatch
from repro.tune.calibrate import problem_dims, sampled_axis

__all__ = ["select_config", "candidate_grid", "pallas_guards_ok",
           "predicted_solve_time"]


def candidate_grid(fam, problem, base_cfg: SolverConfig
                   ) -> List[Tuple[int, int]]:
    """(s, mu) candidates: the family's declared tune_space, clamped to
    the sampled axis and to the structural group size when present."""
    space = dict(fam.tune_space)
    axis = sampled_axis(fam, problem)
    if getattr(problem, "groups", None) is not None:
        mus: Iterable[int] = (base_cfg.block_size,)
    else:
        mus = space.get("mu", (1, 2, 4, 8, 16))
    ss = space.get("s", (1, 2, 4, 8, 16, 32, 64))
    out = []
    for mu in mus:
        if mu > axis:
            continue
        for s in ss:
            if (s, mu) not in out:
                out.append((s, mu))
    return out


def _spmm_shapes(problem, fam, s: int, mu: int,
                 accelerated: bool = True):
    """(R, K, C, Q) of every blocked-ELL SpMM a sparse solve at (s, mu)
    dispatches — mirrors ``sparse_exec.spmm_aux``'s shape derivations,
    including which ONE product each family actually issues (guarding a
    shape the solve never dispatches would wrongly withhold Pallas:
    the (m, s*mu) cross block alone exceeds the cap for large m, but
    the linear SVM never communicates it)."""
    A = problem.A
    if fam.partition == "row":              # Lasso fused col-Gram
        K, C = A.col_rows.shape[1], A.shape[0]
        # appended-vector count: the accelerated variant appends 2
        # residual-like columns (ytil, ztil), the plain one appends 1.
        return [(s * mu, K, C, s * mu + (2 if accelerated else 1))]
    K, C = A.row_cols.shape[1], A.shape[1]
    if getattr(problem, "kernel", None) == "linear":
        return [(s * mu, K, C, s * mu + 1)]     # linear-SVM row-Gram
    return [(A.shape[0], K, C, s * mu)]         # ksvm/logreg cross


def pallas_guards_ok(problem, fam, s: int, mu: int,
                     dtype=jnp.float32,
                     accelerated: bool = True) -> bool:
    """Would a Pallas dispatch at (s, mu) actually run, or silently fall
    back? Checks the inner-kernel Gram budget and — for sparse
    operands — every SpMM shape the solve would issue (``accelerated``
    picks the lasso variant's appended-column count; the conservative
    default covers both)."""
    itemsize = jnp.dtype(dtype).itemsize
    if not dispatch.vmem_ok(s, mu, itemsize):
        return False
    if isinstance(problem.A, SparseOperand):
        for shape in _spmm_shapes(problem, fam, s, mu, accelerated):
            if not dispatch.spmm_vmem_ok(*shape, itemsize=itemsize):
                return False
    return True


def predicted_solve_time(fam, dims, cfg: SolverConfig, machine: Machine,
                         P: int = 1, kernel: str = "linear") -> float:
    """Model time of a full solve under ``cfg``; symmetric_gram halves
    the Gram words W (paper footnote 3) when the family executes it —
    but pays the O(s^2 mu^2)-per-outer-iteration triangle pack/unpack
    as local element work (~2 passes), so on a machine whose beta is
    tiny relative to gamma (a single host) the packed message loses
    and the tuner keeps symmetric_gram off."""
    costs = fam.costs(dims, cfg.iterations, cfg.block_size, cfg.s, P,
                      kernel=kernel)
    t = cost_model.predicted_time(costs, machine)
    if cfg.symmetric_gram and fam.supports_symmetric_gram and cfg.s > 1:
        t -= 0.5 * machine.beta * costs["W"]
        t += 2.0 * machine.gamma * cfg.iterations * cfg.s \
            * cfg.block_size ** 2
    return t


def select_config(problem, machine: Machine, base_cfg: SolverConfig,
                  family=None, *, P: int = 1,
                  allow_pallas: Optional[bool] = None,
                  grid=None, certified: bool = False) -> SolverConfig:
    """The tuned SolverConfig: argmin of the calibrated model over the
    candidate grid, preserving everything the tuner does not own
    (iterations, dtype, seed, accelerated, track_objective, ...).

    allow_pallas=None auto-detects: Pallas is only proposed on TPU
    backends (on CPU the kernels run in interpret mode — strictly
    slower than the jnp reference paths).

    certified=True first runs the static cost certifier
    (``repro.analysis.check_costs``) on the family and refuses to fit
    the machine model against a cost hook the certifier rejects — a
    hook whose counted flops/bytes/messages disagree with the traced
    solve would make every "tuned" recommendation a fit to fiction.
    """
    from repro.core.api import resolve_family

    fam = resolve_family(problem, family)
    if certified:
        from repro.analysis.costs import check_costs
        diags, _ = check_costs(fam)
        errors = [d for d in diags if d.severity == "error"]
        if errors:
            detail = "; ".join(f"{d.where}: {d.message}" for d in errors)
            raise ValueError(
                f"refusing to tune against an uncertified cost model "
                f"for family {fam.name!r}: the static cost certifier "
                f"reports {len(errors)} error(s) — {detail}")
    dims = problem_dims(problem)
    kernel = getattr(problem, "kernel", "linear")
    if allow_pallas is None:
        allow_pallas = jax.default_backend() == "tpu"
    if grid is not None:
        # an explicit grid still has to be executable: pin mu to the
        # structural group size when present, drop mu beyond the
        # sampled axis (the default candidate_grid does both).
        axis = sampled_axis(fam, problem)
        if getattr(problem, "groups", None) is not None:
            grid = [(s, base_cfg.block_size) for s, _ in grid]
        candidates = []
        for c in grid:
            if c[1] <= axis and c not in candidates:
                candidates.append(c)
        if not candidates:
            raise ValueError(
                f"no executable (s, mu) candidates in the provided "
                f"grid {list(grid)!r} (sampled axis size {axis})")
    else:
        candidates = candidate_grid(fam, problem, base_cfg)

    best_cfg, best_t = None, float("inf")
    for s, mu in candidates:
        for sym in ((False, True) if fam.supports_symmetric_gram
                    and s > 1 else (False,)):
            cfg = dataclasses.replace(
                base_cfg, s=s, block_size=mu, symmetric_gram=sym,
                use_pallas=bool(
                    allow_pallas
                    and pallas_guards_ok(problem, fam, s, mu,
                                         base_cfg.dtype,
                                         base_cfg.accelerated)))
            t = predicted_solve_time(fam, dims, cfg, machine, P=P,
                                     kernel=kernel)
            if t < best_t:
                best_cfg, best_t = cfg, t
    if best_cfg is None:
        raise ValueError(
            f"no executable (s, mu) candidates for family "
            f"{fam.name!r} (sampled axis size "
            f"{sampled_axis(fam, problem)}, "
            f"block_size={base_cfg.block_size})")
    return best_cfg
