"""Measure the alpha-beta-gamma-kappa ``Machine`` parameters on the
current host.

The cost model (``repro.core.cost_model``) assigns a configuration the
time ``T = gamma F + beta W + alpha L + kappa I``. Its built-in machines
(``Machine.cray_xc30``, ``Machine.tpu_v5e_pod``) are paper-derived
constants; this module produces a ``Machine`` for the host we actually
run on, so ``best_s``-style sweeps stop answering for someone else's
hardware:

* **gamma** (s/flop) — timed square GEMMs at a couple of sizes; the
  flop rate of the dense Gram products that dominate F.
* **beta** (s/word, 8 B words) — timed Allreduce of a large vector:
  ``psum`` over a real mesh axis when more than one device is present,
  otherwise a memory-bound elementwise pass (the single-device proxy
  for moving one word through the reduction).
* **alpha** (s/message) — the time of the SAME reduction on a tiny
  (1-element) vector: pure launch/collective latency, the term SA
  trades against.
* **kappa** (s/inner-iteration) — the slope of a tiny pilot Lasso solve
  in H at negligible flop volume: per-iteration serial overhead that
  unrolling does NOT remove.

These are *priors*: ``repro.tune.calibrate`` refines all four by
fitting predicted to measured times over a pilot (s, mu) grid, which
absorbs constant factors the analytical counts drop.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import Machine

__all__ = ["measure_machine", "measure_gamma", "measure_alpha_beta",
           "measure_kappa", "time_best"]


def time_best(fn: Callable, repeats: int = 5) -> float:
    """Best-of-``repeats`` wall-clock seconds of ``fn()`` (after one
    warmup call, so compile time never lands in the measurement).
    Best-of suppresses scheduler noise, which one-shot timings on a
    shared CPU host drown in."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def measure_gamma(sizes=(256, 512), repeats: int = 5,
                  dtype=jnp.float32) -> float:
    """s/flop from timed n x n GEMMs (2 n^3 flops each); the larger
    size usually wins (amortized dispatch) — take the best rate."""
    best = float("inf")
    for n in sizes:
        a = jnp.ones((n, n), dtype)
        f = jax.jit(lambda x: x @ x)
        t = time_best(lambda: f(a), repeats)
        best = min(best, t / (2.0 * n ** 3))
    return best


def _reduce_fn(n: int):
    """A jitted reduction of an (n,) vector: a real psum over a 1D mesh
    when several devices are present, an elementwise memory pass (the
    single-device bandwidth proxy) otherwise."""
    devs = jax.devices()
    if len(devs) > 1:
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        mesh = Mesh(np.array(devs), ("d",))
        fn = jax.jit(shard_map(lambda x: jax.lax.psum(x, "d"),
                               mesh=mesh, in_specs=P(), out_specs=P()))
        return fn
    return jax.jit(lambda x: x * 1.0 + 1.0)


def measure_alpha_beta(big: int = 1 << 22, repeats: int = 5):
    """(alpha, beta): latency from a 1-element reduction, inverse
    bandwidth per 8 B word from the marginal cost of a ``big``-element
    one (latency subtracted)."""
    f = _reduce_fn(1)
    alpha = time_best(lambda: f(jnp.ones((1,), jnp.float32)), repeats)
    g = _reduce_fn(big)
    x = jnp.ones((big,), jnp.float32)
    t_big = time_best(lambda: g(x), repeats)
    words = big * 4 / 8.0                     # f32 elements -> 8 B words
    beta = max(t_big - alpha, 1e-12) / words
    return alpha, beta


def measure_kappa(h_small: int = 16, h_big: int = 96,
                  repeats: int = 3) -> float:
    """s/inner-iteration from the slope in H of a tiny (32 x 64, mu=1)
    Lasso solve — at that size the per-iteration flops are sub-us, so
    the slope IS the serial bookkeeping overhead kappa models."""
    from repro.core.lasso import bcd_lasso
    from repro.core.types import LassoProblem, SolverConfig

    rng = np.random.default_rng(0)
    A = rng.standard_normal((32, 64)).astype(np.float32)
    b = rng.standard_normal(32).astype(np.float32)

    def solve_time(H: int) -> float:
        cfg = SolverConfig(block_size=1, iterations=H, accelerated=False,
                           track_objective=False)
        fn = jax.jit(lambda a, bb: bcd_lasso(
            LassoProblem(A=a, b=bb, lam=0.1), cfg).x)
        return time_best(lambda: fn(A, b), repeats)

    slope = (solve_time(h_big) - solve_time(h_small)) / (h_big - h_small)
    return max(slope, 1e-9)


def measure_machine(name: Optional[str] = None, repeats: int = 5
                    ) -> Machine:
    """Measure all four parameters on this host (a few seconds)."""
    alpha, beta = measure_alpha_beta(repeats=repeats)
    gamma = measure_gamma(repeats=repeats)
    kappa = measure_kappa(repeats=max(repeats - 2, 1))
    if name is None:
        import socket
        name = f"{socket.gethostname()}-{jax.default_backend()}"
    return Machine(name=name, alpha=alpha, beta=beta, gamma=gamma,
                   kappa=kappa)
