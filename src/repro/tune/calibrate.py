"""Calibrate the alpha-beta-gamma-kappa machine model against measured
solves.

The model is linear in the machine parameters:
``T(s, mu) = theta . c(s, mu)`` with ``theta = (gamma, beta, alpha,
kappa)`` and ``c = cost_model.cost_vector(fam.costs(...))``. So
calibration is a nonnegative least-squares fit of theta to a handful of
SHORT measured solves over a pilot (s, mu) grid — rows weighted by
1/measured so the fit minimizes RELATIVE error (an absolute-error fit
lets the largest pilot point dominate and leaves the cheap points off
by integer factors).

The microbench priors seed nothing here — the fit stands on its own.
(``tune(machine="micro")`` is the priors-only alternative for problems
too expensive to pilot-solve.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import cost_model
from repro.core.cost_model import Machine, ProblemDims
from repro.tune.microbench import time_best

__all__ = ["CalibrationReport", "calibrate", "fit_machine", "nnls",
           "problem_dims", "measure_solve"]


def problem_dims(problem) -> ProblemDims:
    """Table-I dims (m, n, density f) of a problem's data matrix, with
    f the EXECUTED density: a ``SparseOperand`` executes nnz-only work
    (f = stored density), while a dense array executes full dense
    products no matter how many stored zeros it carries (f = 1) — the
    calibration fits measured times, so its flop term must count the
    flops the solver actually runs, not the ones a sparse format
    would."""
    from repro.core.types import SparseOperand

    A = problem.A
    m, n = A.shape
    if isinstance(A, SparseOperand):
        return ProblemDims(m=m, n=n,
                           f=max(A.nnz / (m * n), 1e-12))
    return ProblemDims(m=m, n=n, f=1.0)


def nnls(C: np.ndarray, t: np.ndarray) -> np.ndarray:
    """Tiny nonnegative least squares (4 unknowns): active-set by
    recursion — solve unconstrained, zero the most negative coordinate,
    repeat on the reduced system. No scipy dependency."""
    C = np.asarray(C, np.float64)
    t = np.asarray(t, np.float64)
    active = list(range(C.shape[1]))
    theta = np.zeros(C.shape[1])
    while active:
        sol, *_ = np.linalg.lstsq(C[:, active], t, rcond=None)
        if (sol >= 0).all():
            theta[active] = sol
            return theta
        drop = active[int(np.argmin(sol))]
        active = [a for a in active if a != drop]
    return theta


def fit_machine(cost_rows: Sequence, measured: Sequence[float],
                name: str = "calibrated") -> Machine:
    """Fit (gamma, beta, alpha, kappa) to measured times given the
    per-configuration cost dicts (or pre-extracted cost vectors).
    Rows are weighted by 1/measured -> relative-error fit."""
    C = np.array([cost_model.cost_vector(r) if isinstance(r, dict) else r
                  for r in cost_rows], np.float64)
    t = np.asarray(measured, np.float64)
    w = 1.0 / np.maximum(t, 1e-12)
    theta = nnls(C * w[:, None], t * w)
    return cost_model.machine_from_vector(theta, name=name)


def measure_solve(problem, fam, cfg, repeats: int = 3) -> float:
    """Steady-state seconds of one jitted solve of ``problem`` under
    ``cfg`` (objective tracking off — the timed work is the solver's
    data path, matching what the model counts)."""
    import dataclasses as dc

    cfg = dc.replace(cfg, track_objective=False)
    A, b = problem.A, problem.b
    fn = jax.jit(lambda a, bb: fam.solve(
        dc.replace(problem, A=a, b=bb), cfg).x)
    b = jax.numpy.asarray(b)
    return time_best(lambda: fn(A, b), repeats)


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """The fitted machine plus the per-pilot-point evidence."""

    machine: Machine
    pilot_iters: int
    points: Tuple[dict, ...]       # {"s", "mu", "measured_s",
                                   #  "predicted_s", "ratio"} per point
    max_ratio: float               # worst max(pred/meas, meas/pred)

    def to_dict(self) -> dict:
        return {"machine": dataclasses.asdict(self.machine),
                "pilot_iters": self.pilot_iters,
                "points": list(self.points),
                "max_ratio": self.max_ratio}


DEFAULT_PILOT_GRID = ((1, 1), (1, 8), (4, 4), (8, 1), (16, 8), (32, 2))


def sampled_axis(fam, problem) -> int:
    """The axis the family's block sampler draws from: columns (n) for
    the row-partitioned Lasso layout, rows (m) for the column-partitioned
    SVM/logreg layout — mu candidates must not exceed it."""
    m, n = problem.A.shape
    return n if fam.partition == "row" else m


def _pilot_points(fam, problem, base_cfg, grid) -> List[Tuple[int, int]]:
    if grid is None:
        grid = DEFAULT_PILOT_GRID
    axis = sampled_axis(fam, problem)
    pts = []
    for s, mu in grid:
        if getattr(problem, "groups", None) is not None:
            # the group size is structural — never clamp it (a clamp
            # would hand the solver a block_size that violates the
            # validated contiguous-mu-blocks contract and raise).
            mu = base_cfg.block_size
        else:
            mu = min(mu, max(axis // 2, 1))
        if (s, mu) not in pts:
            pts.append((s, mu))
    return pts


def calibrate(problem, base_cfg, family=None, *,
              pilot_iters: int = 48, grid=None, P: int = 1,
              repeats: int = 3,
              measure_fn: Optional[Callable] = None) -> CalibrationReport:
    """Fit a ``Machine`` to short measured solves of ``problem`` over a
    pilot (s, mu) grid.

    measure_fn(cfg) -> seconds overrides the real measurement (tests).
    """
    import dataclasses as dc

    from repro.core.api import resolve_family

    fam = resolve_family(problem, family)
    dims = problem_dims(problem)
    kernel = getattr(problem, "kernel", "linear")
    pts = _pilot_points(fam, problem, base_cfg, grid)

    rows, times = [], []
    for s, mu in pts:
        cfg = dc.replace(base_cfg, s=s, block_size=mu,
                         iterations=pilot_iters)
        if measure_fn is not None:
            t = float(measure_fn(cfg))
        else:
            t = measure_solve(problem, fam, cfg, repeats=repeats)
        rows.append(fam.costs(dims, pilot_iters, mu, s, P, kernel=kernel))
        times.append(t)

    machine = fit_machine(rows, times)
    points, worst = [], 1.0
    for (s, mu), costs, t in zip(pts, rows, times):
        pred = cost_model.predicted_time(costs, machine)
        ratio = max(pred / t, t / max(pred, 1e-12)) if t > 0 else 1.0
        worst = max(worst, ratio)
        points.append({"s": s, "mu": mu, "measured_s": t,
                       "predicted_s": pred, "ratio": ratio})
    return CalibrationReport(machine=machine, pilot_iters=pilot_iters,
                             points=tuple(points), max_ratio=worst)
