"""``repro.tune`` — the cost-model-calibrated autotuner.

Closes the model -> measurement loop the paper's companion works
(arXiv:1612.04003, arXiv:1710.08883) run by hand: measure the
alpha-beta-gamma-kappa machine parameters on THIS host
(``microbench``), refine them by least-squares against short measured
pilot solves (``calibrate``), then sweep the registry-declared cost
hook of any family — guard-aware, so every recommendation actually
executes as modeled (``select``) — and hand back a complete tuned
``SolverConfig``.

    from repro import tune
    cfg = tune.autotune(problem)                  # tuned SolverConfig
    res = api.solve(problem, cfg)

or in one step::

    res = api.solve(problem, cfg, tune="auto")

Calibrated machines persist per host/backend/family/regime under
``results/tuned/`` (override with ``cache_dir=`` or the
``REPRO_TUNE_CACHE`` env var), so repeat solves of the same regime skip
the measurement entirely; selection re-runs from the cached machine,
which is pure model evaluation.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
from typing import Optional

import jax

from repro.core.cost_model import Machine
from repro.core.types import SolverConfig
from repro.tune.calibrate import (CalibrationReport, calibrate,
                                  fit_machine, measure_solve, nnls,
                                  problem_dims)
from repro.tune.microbench import measure_machine
from repro.tune.select import (candidate_grid, pallas_guards_ok,
                               predicted_solve_time, select_config)

__all__ = [
    "autotune", "tune", "TuneResult",
    "calibrate", "CalibrationReport", "fit_machine", "nnls",
    "measure_machine", "measure_solve", "problem_dims",
    "select_config", "candidate_grid", "pallas_guards_ok",
    "predicted_solve_time", "cache_path", "load_cached_machine",
]


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """Everything one tuning run decided and why."""

    config: SolverConfig           # the tuned config (use this)
    machine: Machine               # calibrated machine parameters
    calibration: Optional[CalibrationReport]   # None on a cache hit
    predicted_s: float             # model time of the tuned config
    predicted_default_s: float     # model time of the incumbent config
    from_cache: bool
    # measured seconds from the incumbent-guard head-to-head (None when
    # the guard did not run) — callers timing the same configs at the
    # same budget can reuse these instead of re-measuring.
    guard_times: Optional[dict] = None


def _cache_dir(cache_dir: Optional[str]) -> str:
    if cache_dir is not None:
        return cache_dir
    return os.environ.get(
        "REPRO_TUNE_CACHE",
        os.path.join(os.getcwd(), "results", "tuned"))


def cache_path(problem, family_name: str,
               cache_dir: Optional[str] = None,
               dtype=None) -> str:
    """Per-(host, backend, family, regime, dtype) cache file for the
    calibrated machine: the machine is a property of host x problem
    regime x solve dtype (an f32-calibrated gamma/beta is ~2x off for
    f64 residents) — not of one solve's H, and not of P: calibration
    always fits against P=1 pilot measurements (see :func:`tune`), so
    the fitted machine is topology-independent. The key rounds
    density."""
    import jax.numpy as jnp

    dims = problem_dims(problem)
    dt = jnp.dtype(dtype if dtype is not None else jnp.float32).name
    key = (f"{socket.gethostname()}-{jax.default_backend()}-"
           f"{family_name}-m{dims.m}-n{dims.n}-f{dims.f:.1e}-{dt}")
    return os.path.join(_cache_dir(cache_dir), f"{key}.json")


def load_cached_machine(path: str) -> Optional[Machine]:
    try:
        with open(path) as fh:
            payload = json.load(fh)
        return Machine(**payload["machine"])
    except (OSError, KeyError, TypeError, ValueError):
        return None


def _store_cache(path: str, machine: Machine,
                 report: Optional[CalibrationReport]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    payload = {"machine": dataclasses.asdict(machine)}
    if report is not None:
        payload["calibration"] = report.to_dict()
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=1)


def tune(problem, cfg: Optional[SolverConfig] = None, *,
         family=None, machine=None,
         pilot_iters: int = 48, grid=None, P: int = 1,
         allow_pallas: Optional[bool] = None,
         cache: bool = True, cache_dir: Optional[str] = None,
         refresh: bool = False,
         guard_incumbent: Optional[bool] = None,
         guard_iters: Optional[int] = None,
         measure_fn=None) -> TuneResult:
    """Full tuning run: calibrate (or load the cached machine), select,
    and verify the selection against the incumbent ``cfg`` with one
    short measured head-to-head, keeping the incumbent on a loss so
    tuning can never recommend a regression it already measured.

    machine: a ``Machine`` to use as-is, ``"micro"`` to use the
    microbenchmark priors alone (no pilot solves — the cheap path when
    even short solves of the problem are expensive), or None (default)
    for the full pilot-solve calibration.

    P: the processor count used for SELECTION (the L/W terms' log P).
    Calibration always fits against P=1 — the pilot solves run
    unsharded on this host, so fitting P-scaled cost rows to them
    would corrupt the machine. The fitted machine is
    topology-independent; P only changes which config the model picks.

    guard_incumbent: None (default) runs the head-to-head only on a
    FRESH calibration — a cache hit skips all measurement, keeping
    repeat solves of a known regime measurement-free; True forces the
    guard every call, False disables it.

    measure_fn(cfg) -> seconds injects a fake measurement (tests).
    """
    from repro.core.api import resolve_family

    fam = resolve_family(problem, family)
    base = cfg if cfg is not None else SolverConfig(
        block_size=fam.default_mu)

    report, from_cache = None, False
    if machine == "micro":
        machine = measure_machine()
    if machine is None:
        path = cache_path(problem, fam.name, cache_dir,
                          dtype=base.dtype)
        if cache and not refresh:
            machine = load_cached_machine(path)
            from_cache = machine is not None
        if machine is None:
            # always fit at P=1: the pilot solves run unsharded on
            # this host, whatever P the caller wants to SELECT for.
            report = calibrate(problem, base, fam,
                               pilot_iters=pilot_iters, P=1,
                               measure_fn=measure_fn)
            machine = report.machine
            if cache:
                _store_cache(path, machine, report)

    tuned = select_config(problem, machine, base, fam, P=P,
                          allow_pallas=allow_pallas, grid=grid)
    dims = problem_dims(problem)
    kernel = getattr(problem, "kernel", "linear")
    pred_tuned = predicted_solve_time(fam, dims, tuned, machine, P=P,
                                      kernel=kernel)
    pred_base = predicted_solve_time(fam, dims, base, machine, P=P,
                                     kernel=kernel)

    differs = (tuned.s, tuned.block_size, tuned.use_pallas,
               tuned.symmetric_gram) != \
              (base.s, base.block_size, base.use_pallas,
               base.symmetric_gram)
    guard_times = None
    if guard_incumbent is None:
        guard_incumbent = not from_cache    # cache hits stay solve-free
    if guard_incumbent and differs:
        h = guard_iters if guard_iters is not None else pilot_iters
        tuned_h = dataclasses.replace(tuned, iterations=h)
        base_h = dataclasses.replace(base, iterations=h)
        if measure_fn is not None:          # injected measurements
            t_tuned = float(measure_fn(tuned_h))
            t_base = float(measure_fn(base_h))
        else:
            t_tuned = measure_solve(problem, fam, tuned_h)
            t_base = measure_solve(problem, fam, base_h)
        guard_times = {"iterations": h, "selected_s": t_tuned,
                       "incumbent_s": t_base}
        if t_base < t_tuned:
            tuned, pred_tuned = base, pred_base

    return TuneResult(config=tuned, machine=machine, calibration=report,
                      predicted_s=pred_tuned,
                      predicted_default_s=pred_base,
                      from_cache=from_cache,
                      guard_times=guard_times)


def autotune(problem, cfg: Optional[SolverConfig] = None,
             **kwargs) -> SolverConfig:
    """The public one-liner: a complete tuned ``SolverConfig`` for
    ``problem`` (see :func:`tune` for the knobs and the full record)."""
    return tune(problem, cfg, **kwargs).config
