"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

--smoke uses the reduced same-family config (CPU-feasible); the full
configs are exercised via the dry-run. The driver provides checkpointing,
restart, failure handling and elastic re-meshing (repro.runtime.driver).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.driver import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    pipeline = TokenPipeline(vocab_size=arch.vocab_size,
                             global_batch=args.global_batch,
                             seq_len=args.seq_len, seed=args.seed)
    optimizer = AdamW(learning_rate=cosine_schedule(
        args.lr, args.warmup, args.steps))
    cfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                        ckpt_every=args.ckpt_every,
                        microbatches=args.microbatches,
                        remat=args.remat, model_axis=args.model_axis,
                        seed=args.seed)
    trainer = Trainer(arch, optimizer, pipeline, cfg)
    out = trainer.run()
    losses = out["losses"]
    print(f"arch={arch.name} steps={out['final_step']} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    for e in out["events"]:
        print("event:", e)


if __name__ == "__main__":
    main()
