"""Solver launcher: the paper's SA-BCD / SA-SVM on synthetic datasets.

    PYTHONPATH=src python -m repro.launch.solve --problem lasso \
        --dataset news20-like --mu 8 --s 16 --iterations 512 --accelerated
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (LassoProblem, SVMProblem, SolverConfig,
                        solve_lasso, solve_svm)
from repro.data.sparse import make_lasso_dataset, make_svm_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--problem", choices=("lasso", "svm"), default="lasso")
    ap.add_argument("--dataset", default="news20-like")
    # default mu: 8 (lasso, blocked) / 1 (svm, paper Alg. 3-4); pass --mu
    # explicitly for the blocked BDCD / SA-BDCD SVM variants.
    ap.add_argument("--mu", type=int, default=None)
    ap.add_argument("--s", type=int, default=16)
    ap.add_argument("--iterations", type=int, default=512)
    ap.add_argument("--accelerated", action="store_true")
    ap.add_argument("--lam-frac", type=float, default=0.1)
    ap.add_argument("--svm-loss", choices=("l1", "l2"), default="l1")
    # kernel SVM (SA-K-BDCD): anything but "linear" routes through
    # repro.core.kernel_svm with the registered kernel block.
    ap.add_argument("--kernel", choices=("linear", "rbf", "poly"),
                    default="linear")
    ap.add_argument("--kernel-gamma", type=float, default=0.1,
                    help="rbf width parameter")
    ap.add_argument("--kernel-degree", type=int, default=3,
                    help="poly degree")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.mu is None:
        args.mu = 8 if args.problem == "lasso" else 1

    cfg = SolverConfig(block_size=args.mu,
                       s=args.s, iterations=args.iterations,
                       accelerated=args.accelerated, seed=args.seed)
    t0 = time.perf_counter()
    if args.problem == "lasso":
        A, b, lam_max = make_lasso_dataset(args.dataset, args.seed)
        prob = LassoProblem(A=A, b=b, lam=args.lam_frac * lam_max)
        res = solve_lasso(prob, cfg)
        obj = np.asarray(res.objective)
        nnz = int(np.sum(np.abs(np.asarray(res.x)) > 1e-8))
        print(f"lasso {args.dataset} s={args.s} mu={args.mu}: "
              f"obj {obj[0]:.4f} -> {obj[-1]:.4f}, nnz(x)={nnz}, "
              f"{time.perf_counter() - t0:.2f}s")
    else:
        A, b = make_svm_dataset(args.dataset, args.seed)
        kernel_params = {"gamma": args.kernel_gamma} \
            if args.kernel == "rbf" else \
            {"degree": args.kernel_degree} if args.kernel == "poly" \
            else None
        prob = SVMProblem(A=A, b=b, lam=1.0, loss=args.svm_loss,
                          kernel=args.kernel, kernel_params=kernel_params)
        res = solve_svm(prob, cfg)
        obj = np.asarray(res.objective)
        print(f"svm-{args.svm_loss}[{args.kernel}] {args.dataset} "
              f"s={args.s} mu={args.mu}: "
              f"dual {obj[0]:.5f} -> {obj[-1]:.5f}, "
              f"{time.perf_counter() - t0:.2f}s")


if __name__ == "__main__":
    main()
