"""Solver launcher: any registered problem family on synthetic datasets.

    PYTHONPATH=src python -m repro.launch.solve --problem lasso \
        --dataset news20-like --mu 8 --s 16 --iterations 512 --accelerated

``--problem`` enumerates the family registry (``repro.api.FAMILIES``):
lasso, svm, ksvm, logreg, and any family user code registers — each
family supplies its own problem construction (``make_problem``) and
result summary (``describe``), so a new family shows up here with zero
launcher edits.
"""
from __future__ import annotations

import argparse
import time

from repro import api
from repro.api import FAMILIES, KERNELS, SolverConfig


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list-families", action="store_true",
                    help="print every registered problem family (variants, "
                         "sharded partition axis, autotuner grid) and exit")
    ap.add_argument("--problem", choices=sorted(FAMILIES), default="lasso")
    ap.add_argument("--dataset", default="news20-like")
    # default mu: per family (lasso 8, svm 1 = paper Alg. 3-4, ...); pass
    # --mu explicitly for the blocked variants.
    ap.add_argument("--mu", type=int, default=None)
    ap.add_argument("--s", type=int, default=16)
    ap.add_argument("--iterations", type=int, default=512)
    ap.add_argument("--accelerated", action="store_true")
    ap.add_argument("--lam-frac", type=float, default=0.1,
                    help="lasso: lambda as a fraction of ||A^T b||_inf")
    ap.add_argument("--svm-loss", choices=("l1", "l2"), default="l1")
    # kernel SVM (SA-K-BDCD): anything but "linear" routes through
    # repro.core.kernel_svm with the registered kernel block. The default
    # is per family (svm: linear; ksvm: rbf) — None means "unset", so an
    # explicit --kernel linear is honored by the ksvm family (the
    # kernelized linear path is a valid communication-cost choice).
    ap.add_argument("--kernel", choices=sorted(KERNELS), default=None)
    # every registered kernel hyperparameter becomes a --kernel-<name>
    # flag (type and default from KernelSpec.cli_params) and is forwarded
    # via types.build_kernel_params — nothing hardcoded, nothing dropped.
    seen = set()
    for spec in KERNELS.values():
        for pname, default in spec.cli_params.items():
            if pname in seen:
                continue
            seen.add(pname)
            ap.add_argument(f"--kernel-{pname}", type=type(default),
                            default=default,
                            help=f"{spec.name} kernel hyperparameter "
                                 f"(default {default})")
    ap.add_argument("--logreg-l2", type=float, default=1e-3,
                    help="logreg l2 regularization weight")
    # SolverConfig knobs previously unreachable from the CLI:
    ap.add_argument("--use-pallas", action="store_true",
                    help="route the fused Gram/inner-loop hot paths "
                         "through the Pallas TPU kernels")
    ap.add_argument("--symmetric-gram", action="store_true",
                    help="Allreduce only the Gram lower triangle "
                         "(paper footnote 3; SA Lasso/SVM)")
    ap.add_argument("--no-track-objective", dest="track_objective",
                    action="store_false",
                    help="skip the per-iteration objective trace")
    ap.add_argument("--power-iters", type=int, default=32,
                    help="power-method iterations for the block step size")
    ap.add_argument("--tune", action="store_true",
                    help="autotune s/mu/use_pallas/symmetric_gram with "
                         "the calibrated cost model (repro.tune) before "
                         "solving; --s/--mu become the incumbent the "
                         "tuner must beat")
    # elastic fault-tolerant execution (repro.runtime.solve_elastic):
    # --checkpoint-every switches from the plain local solve to the
    # sharded elastic driver with periodic outer-boundary checkpoints.
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint directory for the elastic sharded "
                         "driver (implies --checkpoint-every 1 if that "
                         "flag is unset)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="checkpoint every N OUTER iterations and run "
                         "through the elastic sharded driver (survives "
                         "injected host failures)")
    ap.add_argument("--inject-failure", action="append", default=[],
                    metavar="STEP:HOST",
                    help="kill HOST at inner iteration STEP (repeatable); "
                         "requires the elastic driver "
                         "(--checkpoint-every/--checkpoint-dir)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def _elastic_kwargs(args):
    """Parse the elastic CLI flags into solve_elastic kwargs, or None
    when the plain local path should run."""
    if (args.checkpoint_dir is None and args.checkpoint_every is None
            and not args.inject_failure):
        return None
    from repro.runtime import ElasticConfig, FailureInjector
    if args.checkpoint_dir is None:
        import tempfile
        args.checkpoint_dir = tempfile.mkdtemp(prefix="repro_elastic_")
    failures = {}
    for spec in args.inject_failure:
        step_s, host_s = spec.split(":")
        failures.setdefault(int(step_s), []).append(int(host_s))
    return {
        "elastic": ElasticConfig(
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every or 1),
        "injector": FailureInjector(failures=failures) if failures else None,
    }


def list_families() -> str:
    """One block per registered family, straight from the registry — a
    family added via ``register_family`` shows up with zero launcher
    edits (the same contract as ``--problem`` itself)."""
    lines = []
    for name in sorted(FAMILIES):
        fam = FAMILIES[name]
        variants = ", ".join(f"{k} -> {v}" if isinstance(v, str) else k
                             for k, v in sorted(fam.variants.items()))
        grid = ", ".join(f"{k}={list(v)}"
                         for k, v in sorted(fam.tune_space.items()))
        lines += [f"{name}  ({fam.problem_cls.__name__}, "
                  f"partition={fam.partition}, default_mu={fam.default_mu})",
                  f"    variants:   {variants}",
                  f"    tune_space: {grid or '(autotuner: family default)'}"]
    return "\n".join(lines)


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.list_families:
        print(list_families())
        return
    family = FAMILIES[args.problem]
    if args.mu is None:
        args.mu = family.default_mu

    cfg = SolverConfig(block_size=args.mu,
                       s=args.s, iterations=args.iterations,
                       accelerated=args.accelerated,
                       power_iters=args.power_iters,
                       track_objective=args.track_objective,
                       symmetric_gram=args.symmetric_gram,
                       use_pallas=args.use_pallas,
                       seed=args.seed)
    t0 = time.perf_counter()
    problem = family.make_problem(args)
    if args.tune:
        from repro import tune
        tr = tune.tune(problem, cfg, family=family.name)
        cfg = tr.config
        print(f"tuned[{family.name}]: s={cfg.s} mu={cfg.block_size} "
              f"use_pallas={cfg.use_pallas} "
              f"symmetric_gram={cfg.symmetric_gram} "
              f"(model {tr.predicted_s:.3g}s vs incumbent "
              f"{tr.predicted_default_s:.3g}s"
              f"{', cached machine' if tr.from_cache else ''})")
        args.s, args.mu = cfg.s, cfg.block_size   # describe() reads these
    ekw = _elastic_kwargs(args)
    if ekw is None:
        res = api.solve(problem, cfg, family=family.name)
    else:
        from repro.runtime import solve_elastic
        res = solve_elastic(problem, cfg, family=family.name, **ekw)
        for ev in res.aux["elastic"]["events"]:
            print(f"elastic: {ev}")
    print(family.describe(args, res, time.perf_counter() - t0))


if __name__ == "__main__":
    main()
