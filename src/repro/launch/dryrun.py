import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first lines, before any other import: jax locks the
#   device count on first init. Only the dry-run sees 512 placeholder
#   devices; smoke tests and benches see 1 (no global XLA_FLAGS).

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against ShapeDtypeStruct stand-ins (zero allocation), prove the
sharding config is coherent, and extract the roofline inputs:

  * memory_analysis()      — per-device bytes (proves it fits)
  * cost_analysis()        — per-device FLOPs / bytes accessed
  * compiled.as_text()     — post-SPMD collective schedule (parsed)

Costs of scanned layer stacks are recovered with two-point unrolled fits
(see repro.roofline). Results cache incrementally as JSON under
results/dryrun/ so the 40-cell x 2-mesh matrix is resumable.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --multi-pod
"""
import argparse
import dataclasses
import json
import math
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, input_specs
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.parallel.sharding import (batch_partition_specs, dp_axes,
                                     param_partition_specs)
from repro.roofline.analysis import (HW_V5E, collective_bytes_from_hlo,
                                     cost_analysis_dict, model_flops,
                                     roofline_terms, two_point_fit)

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


# ---------------------------------------------------------------------------
# Step builders (train / prefill / decode) parameterized by arch + options.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DryrunOptions:
    remat: str = "full"
    shard_acts: bool = True
    include_optimizer: bool = True
    unroll_layers: int = 0       # >0: python-unrolled groups (cost fits)
    microbatches: int = 1        # grad-accumulation splits (memory knob;
    #                              ONE deferred gradient reduction per step)
    cost_fit: bool = True        # run the two-point cost lowers (roofline
    #                              terms are single-pod only per the brief;
    #                              multi-pod cells skip them)


# per-(arch, shape) microbatch defaults: the large models need gradient
# accumulation to fit 16 GB/chip at global batch 256 x 4k (the production
# config a real run would use; cost lowers always use 1 — total FLOPs are
# invariant to the split).
MICROBATCH_DEFAULTS = {
    ("mixtral-8x7b", "train_4k"): 8,
    ("llama3-8b", "train_4k"): 4,
    ("stablelm-12b", "train_4k"): 4,
    ("pixtral-12b", "train_4k"): 4,
    ("qwen1.5-4b", "train_4k"): 4,
    ("whisper-large-v3", "train_4k"): 8,
    ("granite-moe-1b-a400m", "train_4k"): 4,
    ("hymba-1.5b", "train_4k"): 8,
    ("tinyllama-1.1b", "train_4k"): 2,
    ("xlstm-350m", "train_4k"): 2,
}


def build_step(arch: ArchConfig, shape: ShapeConfig, mesh,
               opts: DryrunOptions):
    """Returns (fn, example_args_specs, in_shardings)."""
    specs = input_specs(arch, shape)
    pspecs_tree = lm.param_specs(arch)
    ppart = param_partition_specs(pspecs_tree, mesh)
    bpart = batch_partition_specs(specs, mesh, kind=shape.kind)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                                   is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        opt = AdamW(learning_rate=1e-4)
        ostate_tree = jax.eval_shape(opt.init, pspecs_tree)
        opart = opt.state_specs(ppart)

        def train_step(params, opt_state, batch):
            def loss_fn(p, b):
                return lm.train_loss(
                    p, arch, b, remat=opts.remat,
                    shard_acts=opts.shard_acts,
                    unroll_layers=opts.unroll_layers)
            mb = opts.microbatches
            if mb == 1:
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape(
                        (mb, x.shape[0] // mb) + x.shape[1:]), batch)

                def acc(carry, b):
                    tl, tg = carry
                    l, g = jax.value_and_grad(loss_fn)(params, b)
                    return (tl + l, jax.tree.map(jnp.add, tg, g)), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), _ = jax.lax.scan(
                    acc, (jnp.float32(0), zeros), micro)
                loss = loss / mb
                grads = jax.tree.map(lambda g: g / mb, grads)
            if opts.include_optimizer:
                params, opt_state = opt.update(grads, opt_state, params)
            return params, opt_state, loss

        args = (pspecs_tree, ostate_tree, specs)
        in_sh = (ns(ppart), ns(opart), ns(bpart))
        out_sh = (ns(ppart), ns(opart), None)
        # donate params+opt: in-place update, halves resident state.
        return train_step, args, in_sh, out_sh, (0, 1)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            extras = {k: v for k, v in batch.items() if k != "tokens"}
            logits, _, _ = lm.forward(
                params, arch, batch["tokens"], extras,
                shard_acts=opts.shard_acts,
                unroll_layers=opts.unroll_layers)
            return logits[:, -1:]

        args = (pspecs_tree, specs)
        return prefill_step, args, (ns(ppart), ns(bpart)), None, ()

    def serve_step(params, batch):
        return lm.decode_step(params, arch, batch,
                              unroll_layers=opts.unroll_layers)

    args = (pspecs_tree, specs)
    # donate the batch (the KV cache updates in place).
    return serve_step, args, (ns(ppart), ns(bpart)), None, (1,)


def _attention_flops(arch: ArchConfig, shape: ShapeConfig) -> float:
    """Analytic attention FLOPs per step: 4*B*Hq*Dh*sum_attended per layer
    forward (QK^T + PV), x3 for train (bwd). Causal full attention sums
    ~S^2/2 pairs; sliding window ~S*window."""
    B, S = shape.global_batch, shape.seq_len
    Hq, Dh = arch.n_heads, arch.head_dim_
    n_attn_layers = sum(
        1 for i in range(arch.n_layers)
        if arch.block_at(i) in ("attn_mlp", "swa_mlp", "moe", "hybrid"))
    if shape.kind == "decode":
        attended = min(S, arch.window) if arch.window else S
        per_layer = 4.0 * B * Hq * Dh * attended
        return per_layer * n_attn_layers
    if arch.window:
        pairs = S * min(arch.window, S)
    else:
        pairs = S * S / 2.0
    per_layer = 4.0 * B * Hq * Dh * pairs
    mult = 3.0 if shape.kind == "train" else 1.0
    total = per_layer * n_attn_layers * mult
    if arch.is_encdec:
        enc_pairs = arch.encoder_seq ** 2
        total += 4.0 * B * Hq * Dh * enc_pairs * arch.encoder_layers * mult
        total += 4.0 * B * Hq * Dh * S * arch.encoder_seq \
            * arch.n_layers * mult        # cross-attention
    return total


def _reduced(arch: ArchConfig, groups: int) -> ArchConfig:
    period = len(arch.block_pattern)
    kw = {"n_layers": period * groups}
    if arch.encoder_layers:
        kw["encoder_layers"] = max(1, groups)
    return dataclasses.replace(arch, **kw)


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             opts: Optional[DryrunOptions] = None,
             mesh=None, verbose: bool = True) -> Dict:
    opts = opts or DryrunOptions()
    if opts.microbatches == 1:
        mb = MICROBATCH_DEFAULTS.get((arch_name, shape_name), 1)
        if mb != 1:
            opts = dataclasses.replace(opts, microbatches=mb)
    arch = get_config(arch_name)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    result: Dict = {"arch": arch_name, "shape": shape_name,
                    "mesh": mesh_name, "status": "ok",
                    "opts": dataclasses.asdict(opts)}
    if shape_name in arch.skip_shapes:
        result["status"] = "skip"
        result["reason"] = ("pure full-attention arch: long_500k needs "
                            "sub-quadratic attention (DESIGN.md)")
        return result

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.devices.shape)

    t0 = time.time()
    try:
        # ---- full-depth compile: proves sharding + memory fit ----------
        # set_mesh context: the in-model with_sharding_constraint hints
        # (SP activations, EP buffers, split-KV) need a mesh during trace.
        fn, args, in_sh, out_sh, donate = build_step(arch, shape, mesh,
                                                     opts)
        jit_kw = {"in_shardings": in_sh, "donate_argnums": donate}
        if out_sh is not None:
            jit_kw["out_shardings"] = out_sh
        with set_mesh(mesh):
            lowered = jax.jit(fn, **jit_kw).lower(*args)
            compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t0, 1)
        ma = compiled.memory_analysis()
        result["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "total_bytes": int(ma.argument_size_in_bytes
                               + ma.temp_size_in_bytes),
            "fits_hbm": bool(ma.argument_size_in_bytes
                             + ma.temp_size_in_bytes < HW_V5E.hbm_bytes),
        }
        hlo_full = compiled.as_text()
        coll_full = collective_bytes_from_hlo(hlo_full)
        result["collectives_static"] = {
            k: v for k, v in coll_full.items() if k != "counts"}
        result["collective_counts"] = coll_full["counts"]

        # ---- two-point unrolled fits for scan-aware costs ----------------
        if not opts.cost_fit:
            result["wall_s"] = round(time.time() - t0, 1)
            if verbose:
                _print_cell(result)
            return result
        period = len(arch.block_pattern)
        n_groups = arch.n_layers // period
        fit = {}
        for key in ("flops", "bytes", "coll"):
            fit[key] = {}
        pts = {}
        for g in (1, 2):
            red = _reduced(arch, g)
            opts_g = dataclasses.replace(opts, unroll_layers=g,
                                         microbatches=1)
            fng, argsg, in_shg, out_shg, dong = build_step(red, shape,
                                                           mesh, opts_g)
            jkw = {"in_shardings": in_shg, "donate_argnums": dong}
            if out_shg is not None:
                jkw["out_shardings"] = out_shg
            from repro.kernels.flash_attention.ops import cost_exact_mode
            with set_mesh(mesh), cost_exact_mode():
                cg = jax.jit(fng, **jkw).lower(*argsg).compile()
            ca = cost_analysis_dict(cg)
            coll = collective_bytes_from_hlo(cg.as_text())
            pts[g] = {"flops": float(ca.get("flops", 0.0)),
                      "bytes": float(ca.get("bytes accessed", 0.0)),
                      "coll": float(coll["total"])}
        flops_dev = two_point_fit(pts[1]["flops"], pts[2]["flops"], 1, 2,
                                  n_groups)
        bytes_dev = two_point_fit(pts[1]["bytes"], pts[2]["bytes"], 1, 2,
                                  n_groups)
        coll_dev = two_point_fit(pts[1]["coll"], pts[2]["coll"], 1, 2,
                                 n_groups)
        result["cost_fit_points"] = pts
        result["per_device"] = {"flops_macs": flops_dev,
                                "hbm_bytes": bytes_dev,
                                "collective_bytes": coll_dev}

        # ---- roofline terms ---------------------------------------------
        terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
        result["roofline"] = terms
        n_active = arch.active_param_count() if arch.n_experts \
            else lm.param_count(arch)
        # use spec-derived count for non-MoE; MoE active from analytic.
        if arch.n_experts:
            total = lm.param_count(arch)
            analytic_total = arch.param_count()
            # rescale analytic active count by the spec/analytic ratio.
            n_active = int(arch.active_param_count()
                           * total / max(analytic_total, 1))
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(n_active, shape.kind, tokens, shape.global_batch)
        # cost_analysis reports per-device FLOPs in the 2*M*N*K convention
        # (verified in tests/test_roofline.py) -> global = x n_chips.
        hlo_flops_global = flops_dev * n_chips
        result["model_flops"] = mf
        result["useful_ratio"] = mf / hlo_flops_global \
            if hlo_flops_global else 0.0
        # 6*N*D excludes attention score/value matmuls; add the analytic
        # attention term so quadratic-attention cells are judged fairly.
        af = _attention_flops(arch, shape)
        result["attention_flops"] = af
        result["useful_ratio_attn"] = (mf + af) / hlo_flops_global \
            if hlo_flops_global else 0.0
        result["n_chips"] = n_chips
        result["wall_s"] = round(time.time() - t0, 1)
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-3000:]
    if verbose:
        _print_cell(result)
    return result


def _print_cell(r: Dict):
    if r["status"] == "skip":
        print(f"[SKIP] {r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
              f"({r['reason'][:60]})")
        return
    if r["status"] == "error":
        print(f"[FAIL] {r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
              f"{r['error'][:120]}")
        return
    m = r["memory"]
    if "roofline" not in r:
        print(f"[ OK ] {r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
              f"mem/dev={m['total_bytes'] / 1e9:6.2f}GB "
              f"fits={m['fits_hbm']} (compile-only pass) "
              f"({r['wall_s']}s)")
        return
    t = r["roofline"]
    print(f"[ OK ] {r['arch']:22s} {r['shape']:12s} {r['mesh']:10s} "
          f"mem/dev={m['total_bytes'] / 1e9:6.2f}GB "
          f"fits={m['fits_hbm']} "
          f"C={t['compute_s'] * 1e3:8.2f}ms M={t['memory_s'] * 1e3:8.2f}ms "
          f"N={t['collective_s'] * 1e3:8.2f}ms -> {t['dominant']:10s} "
          f"useful={r['useful_ratio']:.2f}/{r.get('useful_ratio_attn', 0):.2f} "
          f"({r['wall_s']}s)")


def cell_path(arch: str, shape: str, mesh: str) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-optimizer", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    base_opts = DryrunOptions(remat=args.remat,
                              include_optimizer=not args.no_optimizer)

    built = {}
    n_fail = 0
    for mp in meshes:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        # roofline terms are reported single-pod only (brief §Roofline);
        # the multi-pod pass proves the 'pod' axis shards + memory.
        opts = dataclasses.replace(base_opts, cost_fit=not mp)
        if mp not in built:
            built[mp] = make_production_mesh(multi_pod=mp)
        for a in archs:
            for s in shapes:
                path = cell_path(a, s, mesh_name)
                if os.path.exists(path) and not args.force:
                    r = json.load(open(path))
                    _print_cell(r)
                    if r["status"] == "error":
                        n_fail += 1
                    continue
                r = run_cell(a, s, mp, opts, mesh=built[mp])
                r.pop("traceback", None) if r["status"] == "ok" else None
                with open(path, "w") as f:
                    json.dump(r, f, indent=1)
                if r["status"] == "error":
                    n_fail += 1
    print(f"\ndone; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
