"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axes: 'data' (DP/FSDP), 'model' (TP/EP/SP), plus 'pod' (hierarchical
    DP) on the multi-pod mesh.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh helper (tests, examples)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def set_mesh(mesh):
    """Context manager installing ``mesh`` for the enclosed traces.

    ``jax.set_mesh`` where available; on older jax the Mesh object itself
    is the context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
