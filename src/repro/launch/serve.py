"""Serving launcher: batched prefill + decode loop with KV/state cache.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --smoke --batch 4 --prompt-len 32 --gen-len 16

Serves the reduced config on CPU; the full-config serving path is proven
by the dry-run's prefill/decode cells. Implements continuous batched
decode over a request queue with per-request lengths.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm


class BatchedServer:
    """Greedy batched decoding with a shared ring/linear cache."""

    def __init__(self, arch, params, max_seq: int):
        self.arch = arch
        self.params = params
        self.max_seq = max_seq
        self._decode = jax.jit(
            lambda p, b: lm.decode_step(p, arch, b))

    def generate(self, prompts: np.ndarray, gen_len: int) -> np.ndarray:
        """prompts: (B, P) int32. Returns (B, gen_len)."""
        B, P = prompts.shape
        cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            lm.cache_specs(self.arch, B, self.max_seq))
        # teacher-forced prefill through the decode path (correct though
        # not the fast path; the bulk prefill path is lm.forward).
        logits = None
        for t in range(P):
            batch = {"tokens": jnp.asarray(prompts[:, t:t + 1]),
                     "cache": cache, "pos": jnp.int32(t)}
            logits, cache = self._decode(self.params, batch)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for t in range(gen_len):
            out.append(np.asarray(tok))
            batch = {"tokens": tok[:, None], "cache": cache,
                     "pos": jnp.int32(P + t)}
            logits, cache = self._decode(self.params, batch)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return np.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    if arch.is_encdec:
        raise SystemExit("use the audio pipeline for enc-dec archs")
    params = lm.init_params(arch, jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, arch.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    server = BatchedServer(arch, params,
                           max_seq=args.prompt_len + args.gen_len)
    t0 = time.perf_counter()
    out = server.generate(prompts, args.gen_len)
    dt = time.perf_counter() - t0
    tps = args.batch * args.gen_len / dt
    print(f"arch={arch.name} generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s); sample: {out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
