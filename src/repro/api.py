"""``repro.api`` — the stable public surface of the solver library.

    from repro import api
    from repro.api import LassoProblem, SolverConfig

    res = api.solve(LassoProblem(A=A, b=b, lam=lam),
                    SolverConfig(block_size=8, s=16, iterations=512))

One ``solve`` call reaches every registered problem family (lasso, svm,
ksvm, logreg, and anything user code registers via ``register_family``)
on every registered backend ("local", "sharded"). The hand-named legacy
entry points in ``repro.core`` remain as thin shims over this facade.

This module's ``__all__`` (together with ``repro.core.__all__``) is the
checked API surface: ``tools/check_api_surface.py`` diffs it against
``api_surface.txt`` in CI, so nothing here disappears silently.
"""
from repro.core.api import (BACKENDS, families, lower_solve,
                            resolve_family, solve, solve_sharded)
from repro.core.sfista import SFISTAProblem
from repro.core.types import (FAMILIES, KERNELS, KernelSpec, LassoProblem,
                              LogRegProblem, ProblemFamily, SVMProblem,
                              SolveState, SolverConfig, SolverResult,
                              SparseOperand, build_kernel_params,
                              register_family, register_kernel)
from repro.runtime.elastic import ElasticConfig, solve_elastic

__all__ = [
    # the facade
    "solve", "solve_sharded", "solve_elastic", "lower_solve",
    "resolve_family", "families", "BACKENDS", "ElasticConfig",
    # the registries
    "FAMILIES", "ProblemFamily", "register_family",
    "KERNELS", "KernelSpec", "register_kernel", "build_kernel_params",
    # problem / config / result types
    "LassoProblem", "SVMProblem", "LogRegProblem", "SFISTAProblem",
    "SolverConfig", "SolverResult", "SolveState", "SparseOperand",
]
