"""Elastic fault-tolerant sharded solves: checkpoint / failure / re-mesh
orchestration around ``repro.core.api.solve_sharded``.

The SA solvers keep s iterations of recurrences in flight between fused
Allreduces, so the ONLY safe checkpoint points are outer-iteration
boundaries (DESIGN.md "Elastic recovery of SA recurrences"). This driver
runs a solve as a sequence of SEGMENTS of ``checkpoint_every`` outer
iterations, each one ``solve_sharded`` call; at every boundary the full
logical :class:`~repro.core.types.SolveState` (recurrence carries + the
global inner-iteration index; the RNG key and θ schedule are
reconstructed from ``cfg.seed``/``cfg``) is checkpointed with
mesh-agnostic PartitionSpecs derived from the family's ``state_layout``.

Failure model (single-process simulation, faithful to the multi-host
code path): each "host" owns one device of the original device list.
When the :class:`~repro.runtime.failures.FailureInjector` schedules a
failure at an inner iteration inside the upcoming segment, that
segment's in-flight work is LOST (exactly what s steps of unsynchronized
recurrences mean), the dead hosts' devices are removed, a smaller 1D
mesh is rebuilt over the survivors, and the latest checkpoint is
restored onto it — ``solve_sharded`` re-pads and re-shards the logical
state through the generic pad/unpad machinery, so no resharding code
exists here. A failure before the first checkpoint restarts from the
initial state. Replay is safe because ``FailureInjector.check`` pops:
a fired failure never fires again.

Straggler policy: after each segment the
:class:`~repro.runtime.stragglers.StragglerMonitor` is fed per-host
times (measured, or simulated via the ``host_times`` hook). "rebalance"
is ADVISORY here — the equal-shard ``shard_map`` layout has no per-host
mu share to shrink, so the suggested ``microbatch_weights`` are surfaced
in the report for a weighted-sharding driver to consume. "evict" is
ENFORCED: the host is dropped through the same re-mesh path as a hard
failure (restoring the checkpoint just written at the boundary, so no
work is lost).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint.ckpt import CheckpointManager
from repro.core import api as core_api
from repro.core.types import SolveState, SolverConfig, SolverResult
from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor

__all__ = ["ElasticConfig", "solve_elastic", "build_1d_mesh"]


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Knobs for :func:`solve_elastic`.

    checkpoint_dir:   where ``step_<inner_iteration>`` checkpoints land.
    checkpoint_every: segment length in OUTER iterations (Allreduce
                      rounds) — the checkpoint cadence. Segment
                      boundaries fall at multiples of ``cfg.s`` inner
                      iterations, preserving s-group alignment, so an
                      undisturbed segmented solve is bit-identical to
                      the monolithic one on the same mesh.
    keep:             checkpoint retention (newest N kept).
    async_save:       overlap npz writes with the next segment (joined
                      before any restore and on exit).
    """

    checkpoint_dir: str = "/tmp/repro_elastic_ckpt"
    checkpoint_every: int = 1
    keep: int = 3
    async_save: bool = True

    def __post_init__(self):
        if self.checkpoint_every < 1:
            raise ValueError(
                f"checkpoint_every must be >= 1 outer iterations, "
                f"got {self.checkpoint_every}")
        if self.keep < 1:
            raise ValueError(f"keep must be >= 1, got {self.keep}")


def build_1d_mesh(devices: List, axis: str) -> Mesh:
    """A 1D mesh over ``devices`` named ``axis`` (the family's default
    sharded axis)."""
    return Mesh(np.array(devices), (axis,))


def _state_specs(layout, axis: str) -> Dict[str, P]:
    """Logical PartitionSpec per state leaf: 'partition' leaves shard on
    the family's mesh axis, 'replicated' leaves on no axis — exactly the
    vocabulary ``ckpt.py`` stores mesh-agnostically."""
    return {name: (P(axis) if lay == "partition" else P())
            for name, lay in layout}


def solve_elastic(problem, cfg: Optional[SolverConfig] = None, *,
                  elastic: Optional[ElasticConfig] = None,
                  family: Optional[object] = None,
                  devices: Optional[List] = None,
                  injector: Optional[FailureInjector] = None,
                  monitor: Optional[StragglerMonitor] = None,
                  host_times: Optional[Callable[[int, List[int]],
                                               Dict[int, float]]] = None,
                  x0=None) -> SolverResult:
    """Sharded solve that survives host failures mid-run.

    problem/cfg/family/x0: as :func:`repro.core.api.solve`.
    elastic:   checkpoint cadence/retention (:class:`ElasticConfig`).
    devices:   the initial device list; each entry is one simulated
               "host" (defaults to ``jax.devices()``).
    injector:  scheduled failures keyed by GLOBAL inner iteration — a
               failure at iteration t kills its hosts mid-segment and
               loses that segment's in-flight work.
    monitor:   straggler monitor; fed after every segment when
               ``host_times`` is given.
    host_times: ``fn(segment_index, live_hosts) -> {host: seconds}`` —
               simulated (or externally measured) per-host step times.
               Without it the monitor is fed the measured wall time for
               every live host (no skew — detection never triggers).

    Returns the final :class:`SolverResult`; ``aux["elastic"]`` holds
    the event log, per-recovery timings, the advisory rebalance weights,
    and the surviving host list. The objective trace covers all
    cfg.iterations inner iterations — replayed segments overwrite the
    work lost to each failure, exactly as the uninterrupted trace would
    read.
    """
    fam = core_api.resolve_family(problem, family)
    if cfg is None:
        cfg = SolverConfig()
    if elastic is None:
        elastic = ElasticConfig()
    if fam.state_layout is None:
        raise ValueError(
            f"family {fam.name!r} declares no state_layout — elastic "
            f"recovery needs checkpointable solver state")
    axis = fam.default_axes if isinstance(fam.default_axes, str) else "data"
    layout = fam.state_layout(cfg)
    specs = _state_specs(layout, axis)

    all_devices = list(devices if devices is not None else jax.devices())
    live = list(range(len(all_devices)))          # host ids = device index
    seg_len = elastic.checkpoint_every * cfg.s    # inner iters per segment

    events: List[str] = []
    recoveries: List[Dict[str, Any]] = []
    rebalances: List[Dict[str, Any]] = []
    traces: List[Dict[str, Any]] = []             # {"start": it, "objs": arr}
    state: Optional[SolveState] = None
    seg_index = 0

    def rebuild_mesh():
        return build_1d_mesh([all_devices[h] for h in live], axis)

    def restore(mgr: CheckpointManager, reason: str):
        """Latest checkpoint -> (state, iteration); falls back to the
        initial state when nothing was checkpointed yet."""
        nonlocal state, traces
        t0 = time.perf_counter()
        try:
            tree, extra = mgr.restore_latest()
        except FileNotFoundError:
            state, it = None, 0
            traces = []
            events.append(f"{reason}: no checkpoint yet — restarting "
                          f"from the initial state")
        else:
            it = int(extra["iteration"])
            state = SolveState(it, dict(tree))
            traces = [t for t in traces if t["start"] < it]
            events.append(f"{reason}: restored iteration {it} onto "
                          f"{len(live)} hosts")
        return it, time.perf_counter() - t0

    with CheckpointManager(elastic.checkpoint_dir, keep=elastic.keep,
                           async_save=elastic.async_save) as mgr:
        mesh = rebuild_mesh()
        it = 0
        while it < cfg.iterations:
            if injector is not None:
                dead = sorted({h for t in range(it + 1, it + seg_len + 1)
                               for h in injector.check(t)
                               if h in live})
                if dead:
                    for h in dead:
                        live.remove(h)
                        if monitor is not None:
                            monitor.drop_host(h)
                    if not live:
                        raise RuntimeError("all hosts lost")
                    events.append(
                        f"hosts {dead} failed in segment after iteration "
                        f"{it} — segment work lost")
                    mgr.wait()
                    it, dt = restore(mgr, f"failure of hosts {dead}")
                    mesh = rebuild_mesh()
                    recoveries.append({
                        "kind": "failure", "hosts": dead,
                        "resumed_iteration": it, "n_hosts": len(live),
                        "restore_seconds": dt})
                    continue

            H_seg = min(seg_len, cfg.iterations - it)
            cfg_seg = dataclasses.replace(cfg, iterations=H_seg)
            t0 = time.perf_counter()
            res = core_api.solve_sharded(
                problem, cfg_seg, mesh, axes=axis, family=fam,
                x0=x0 if (it == 0 and state is None) else None,
                state=state)
            jax.block_until_ready(res.x)
            seg_seconds = time.perf_counter() - t0
            state = res.aux["state"]
            traces.append({"start": it,
                           "objs": np.asarray(res.objective)})
            it = int(state.iteration)
            mgr.save(it, dict(state.carry), specs,
                     extra={"iteration": it, "family": fam.name,
                            "seed": cfg.seed, "s": cfg.s,
                            "accelerated": cfg.accelerated,
                            "n_hosts": len(live)})
            seg_index += 1

            if monitor is not None:
                times = (host_times(seg_index - 1, list(live))
                         if host_times is not None
                         else {h: seg_seconds for h in live})
                actions = monitor.record(times)
                evict = sorted(h for h, a in actions.items()
                               if a == "evict" and h in live)
                if evict and len(evict) < len(live):
                    for h in evict:
                        live.remove(h)
                        monitor.drop_host(h)
                    events.append(
                        f"hosts {evict} evicted as stragglers after "
                        f"iteration {it}")
                    it, dt = restore(mgr, f"eviction of hosts {evict}")
                    mesh = rebuild_mesh()
                    recoveries.append({
                        "kind": "evict", "hosts": evict,
                        "resumed_iteration": it, "n_hosts": len(live),
                        "restore_seconds": dt})
                elif any(a == "rebalance" for a in actions.values()):
                    rebalances.append({
                        "iteration": it,
                        "hosts": sorted(h for h, a in actions.items()
                                        if a == "rebalance"),
                        "microbatch_weights": monitor.microbatch_weights()})

    objective = np.concatenate([t["objs"] for t in traces]) if traces \
        else np.zeros((0,))
    res.aux["state"] = state
    res.aux["elastic"] = {
        "events": events, "recoveries": recoveries,
        "rebalances": rebalances, "live_hosts": list(live),
        "n_hosts_initial": len(all_devices),
        "checkpoint_every": elastic.checkpoint_every,
    }
    return SolverResult(x=res.x, objective=objective, aux=res.aux)
