"""Fault-tolerant training driver.

Responsibilities:
  * build the jitted train step (grad accumulation with ONE deferred
    reduction per step — the trainer-side analogue of the paper's SA
    batching; remat & sequence-parallel options)
  * periodic async checkpoints (params, optimizer state, data-pipeline
    state, RNG)
  * failure handling: on a (simulated or real) host failure, rebuild the
    mesh from the surviving devices, restore the latest checkpoint onto
    the NEW topology (cross-topology restore), rewind the data pipeline,
    recompile, continue — no human in the loop
  * straggler policy: rebalance shares or evict via the same elastic path

The driver is topology-agnostic: meshes are built from whatever device
list is alive, and checkpoints re-shard because PartitionSpecs are
logical (see repro.checkpoint).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.launch.mesh import set_mesh
from repro.configs.base import ArchConfig
from repro.data.tokens import TokenPipeline
from repro.models import lm
from repro.optim.adamw import AdamW
from repro.parallel.sharding import (batch_partition_specs, dp_axes,
                                     param_partition_specs)
from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    ckpt_keep: int = 3
    microbatches: int = 1
    remat: str = "none"
    shard_acts: bool = False
    model_axis: int = 1            # TP degree
    seed: int = 0
    log_every: int = 10


def build_mesh(devices: List, model_axis: int) -> Mesh:
    n = len(devices)
    if n % model_axis != 0:
        raise ValueError(
            f"{n} devices do not divide into model_axis={model_axis}")
    devs = np.array(devices).reshape(n // model_axis, model_axis)
    return Mesh(devs, ("data", "model"))


def make_train_step(arch: ArchConfig, optimizer: AdamW, mesh: Mesh,
                    cfg: TrainerConfig):
    """jit'd (params, opt_state, batch) -> (params, opt_state, loss).

    With cfg.microbatches > 1 the batch is split and gradients accumulate
    locally across microbatches inside ONE jitted step — XLA emits a
    single gradient reduction per step instead of one per microbatch
    (deferred-allreduce; verified structurally by
    benchmarks/collective_count.py)."""
    pspecs = param_partition_specs(lm.param_specs(arch), mesh)
    sspecs = optimizer.state_specs(pspecs)

    def loss_fn(params, batch):
        return lm.train_loss(params, arch, batch, remat=cfg.remat,
                             shard_acts=cfg.shard_acts)

    def step_fn(params, opt_state, batch):
        k = cfg.microbatches
        if k == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]),
                batch)

            def acc(carry, mb):
                tot_loss, tot_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (tot_loss + l,
                        jax.tree.map(jnp.add, tot_grads, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.float32(0), zeros), micro)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    ns = lambda spec: jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
    return jax.jit(step_fn,
                   in_shardings=(ns(pspecs), ns(sspecs), None),
                   out_shardings=(ns(pspecs), ns(sspecs), None),
                   donate_argnums=(0, 1))


class Trainer:
    def __init__(self, arch: ArchConfig, optimizer: AdamW,
                 pipeline: TokenPipeline, cfg: TrainerConfig,
                 devices: Optional[List] = None,
                 failure_injector: Optional[FailureInjector] = None,
                 straggler_monitor: Optional[StragglerMonitor] = None,
                 host_of_device: Optional[Callable[[int], int]] = None):
        self.arch = arch
        self.optimizer = optimizer
        self.pipeline = pipeline
        self.cfg = cfg
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.injector = failure_injector
        self.stragglers = straggler_monitor
        # mapping device index -> host id (for failure simulation).
        self.host_of_device = host_of_device or (lambda i: i)
        self.ckpt = CheckpointManager(cfg.ckpt_dir, keep=cfg.ckpt_keep)
        self.losses: List[float] = []
        self.events: List[str] = []
        self._setup(fresh=True)

    # -- topology / (re)compilation ------------------------------------

    def _usable_devices(self) -> List:
        """Largest prefix of live devices compatible with model_axis and
        the global batch divisibility."""
        n = len(self.devices)
        ma = self.cfg.model_axis
        while n > 0:
            if n % ma == 0 and self.pipeline.global_batch % (n // ma) == 0 \
                    and self.pipeline.global_batch % max(
                        (n // ma) * self.cfg.microbatches, 1) == 0:
                return self.devices[:n]
            n -= 1
        raise RuntimeError("no usable device configuration")

    def _setup(self, fresh: bool):
        devs = self._usable_devices()
        self.mesh = build_mesh(devs, self.cfg.model_axis)
        self.step_fn = make_train_step(self.arch, self.optimizer,
                                       self.mesh, self.cfg)
        self.pspecs = param_partition_specs(lm.param_specs(self.arch),
                                            self.mesh)
        self.sspecs = self.optimizer.state_specs(self.pspecs)
        if fresh:
            with set_mesh(self.mesh):
                params = lm.init_params(self.arch,
                                        jax.random.key(self.cfg.seed))
                params = jax.device_put(params, self._ns(self.pspecs))
                opt_state = self.optimizer.init(params)
                opt_state = jax.device_put(opt_state, self._ns(self.sspecs))
            self.params, self.opt_state = params, opt_state
            self.step = 0

    def _ns(self, specs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    # -- checkpoint / restore -------------------------------------------

    def _save(self):
        state = {"params": self.params, "opt": self.opt_state}
        specs = {"params": self.pspecs, "opt": self.sspecs}
        self.ckpt.save(self.step, state, specs,
                       extra={"pipeline": self.pipeline.checkpoint(),
                              "step": self.step})

    def _restore(self):
        like = {"params": jax.tree.map(lambda x: x, self.params),
                "opt": self.opt_state}
        state, extra = self.ckpt.restore_latest(like, self.mesh)
        self.params = state["params"]
        self.opt_state = state["opt"]
        self.step = extra["step"]
        self.pipeline.state.step = extra["pipeline"]["step"]

    # -- failure path -----------------------------------------------------

    def _handle_failure(self, dead_hosts: List[int]):
        self.events.append(f"step {self.step}: hosts {dead_hosts} failed")
        self.ckpt.wait()
        self.devices = [d for i, d in enumerate(self.devices)
                        if self.host_of_device(i) not in dead_hosts]
        if not self.devices:
            raise RuntimeError("all devices lost")
        # rebuild topology, restore latest checkpoint onto it, rewind data.
        self._setup(fresh=True)      # fresh init to get placement...
        self._restore()              # ...then overwrite from checkpoint
        self.events.append(
            f"re-meshed to {len(self.devices)} devices "
            f"({self.mesh.shape}), resumed at step {self.step}")

    # -- main loop ---------------------------------------------------------

    def run(self) -> Dict:
        while self.step < self.cfg.steps:
            if self.injector:
                dead = self.injector.check(self.step)
                if dead:
                    self._handle_failure(dead)
                    continue
            tokens, targets = self.pipeline.batch_at(self.step)
            batch = {"tokens": tokens, "targets": targets}
            bspecs = batch_partition_specs(batch, self.mesh)
            batch = jax.device_put(batch, self._ns(bspecs))
            t0 = time.perf_counter()
            self.params, self.opt_state, loss = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(loss)
            dt = time.perf_counter() - t0
            self.losses.append(loss)
            if self.stragglers:
                n_hosts = len({self.host_of_device(i)
                               for i in range(len(self.devices))})
                actions = self.stragglers.record(
                    {h: dt for h in range(n_hosts)})
                for h, act in actions.items():
                    if act == "evict":
                        self._handle_failure([h])
                        break
            self.step += 1
            if self.step % self.cfg.ckpt_every == 0 \
                    or self.step == self.cfg.steps:
                self._save()
        self.ckpt.wait()
        return {"losses": self.losses, "events": self.events,
                "final_step": self.step}
