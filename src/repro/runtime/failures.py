"""Deterministic failure injection for fault-tolerance tests/demos.

Schedules host failures at given steps; the Trainer consults the injector
every step and runs its restart/elastic path when a failure fires —
exactly the code path a real coordination-service callback would take.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class FailureInjector:
    """failures: {step: [host_ids]} — hosts that die at that step."""
    failures: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    fired: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    def check(self, step: int) -> List[int]:
        # pop: a failure fires exactly once — after the driver restores to
        # an earlier step and replays past the failure point, the hosts
        # are already gone and must not "die" again.
        hosts = self.failures.pop(step, [])
        for h in hosts:
            self.fired.append((step, h))
        return hosts
