"""Straggler detection and mitigation bookkeeping.

On a real pod the per-host step times come from the coordination service
heartbeats; here they are fed in by the driver (measured or simulated).
Detection: a host is a straggler when its EMA step time exceeds
``threshold`` x the median EMA across hosts for ``patience`` consecutive
steps. Mitigation policy (returned as an action for the driver):

  * "rebalance" — shrink the straggler's microbatch share (gradual skew)
  * "evict"     — persistent straggler: treat as failed, trigger the
                  elastic re-mesh path (same as a hard failure)

This mirrors production practice (e.g. Borg/TPU pod doctors): detection
is centralized and cheap; mitigation reuses the failure machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    ema_decay: float = 0.8
    threshold: float = 1.5
    patience: int = 3
    evict_after: int = 8

    def __post_init__(self):
        if self.n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {self.n_hosts}")
        if not 0.0 < self.ema_decay < 1.0:
            raise ValueError(
                f"ema_decay must be in (0, 1), got {self.ema_decay}")
        if self.threshold < 1.0:
            raise ValueError(
                f"threshold must be >= 1 (a host slower than the median "
                f"by less than 1x is not a straggler), got {self.threshold}")
        if self.patience < 1:
            raise ValueError(f"patience must be >= 1, got {self.patience}")
        if self.evict_after < self.patience:
            raise ValueError(
                f"evict_after ({self.evict_after}) must be >= patience "
                f"({self.patience}): rebalance escalates INTO evict, "
                f"never the other way")
        self._ema: List[Optional[float]] = [None] * self.n_hosts
        self._strikes: List[int] = [0] * self.n_hosts
        self._dropped: Set[int] = set()

    def record(self, host_times: Dict[int, float]) -> Dict[int, str]:
        """Feed one step's per-host times; returns {host: action}.
        Times reported for a dropped host (a late heartbeat racing its
        eviction) are ignored — a dropped host never reappears in the
        EMA table or the returned actions."""
        for h, t in host_times.items():
            if h in self._dropped:
                continue
            prev = self._ema[h]
            self._ema[h] = t if prev is None \
                else self.ema_decay * prev + (1 - self.ema_decay) * t
        live = sorted(e for e in self._ema if e is not None)
        if not live:
            return {}
        median = live[len(live) // 2]
        actions: Dict[int, str] = {}
        for h, e in enumerate(self._ema):
            if e is None:
                continue
            if e > self.threshold * median:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.evict_after:
                actions[h] = "evict"
            elif self._strikes[h] >= self.patience:
                actions[h] = "rebalance"
        return actions

    def drop_host(self, host: int):
        self._dropped.add(host)
        self._ema[host] = None
        self._strikes[host] = 0

    @property
    def live_hosts(self) -> List[int]:
        """Hosts never dropped (tracked or not yet heard from)."""
        return [h for h in range(self.n_hosts) if h not in self._dropped]

    def microbatch_weights(self) -> List[float]:
        """Per-host work shares inversely proportional to EMA step time
        (the 'rebalance' mitigation). Sums to n_live."""
        live = [(h, e) for h, e in enumerate(self._ema) if e is not None]
        if not live:
            return []
        inv = [1.0 / e for _, e in live]
        s = sum(inv)
        n = len(live)
        return [n * x / s for x in inv]
