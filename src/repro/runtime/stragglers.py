"""Straggler detection and mitigation bookkeeping.

On a real pod the per-host step times come from the coordination service
heartbeats; here they are fed in by the driver (measured or simulated).
Detection: a host is a straggler when its EMA step time exceeds
``threshold`` x the median EMA across hosts for ``patience`` consecutive
steps. Mitigation policy (returned as an action for the driver):

  * "rebalance" — shrink the straggler's microbatch share (gradual skew)
  * "evict"     — persistent straggler: treat as failed, trigger the
                  elastic re-mesh path (same as a hard failure)

This mirrors production practice (e.g. Borg/TPU pod doctors): detection
is centralized and cheap; mitigation reuses the failure machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    ema_decay: float = 0.8
    threshold: float = 1.5
    patience: int = 3
    evict_after: int = 8

    def __post_init__(self):
        self._ema: List[Optional[float]] = [None] * self.n_hosts
        self._strikes: List[int] = [0] * self.n_hosts

    def record(self, host_times: Dict[int, float]) -> Dict[int, str]:
        """Feed one step's per-host times; returns {host: action}."""
        for h, t in host_times.items():
            prev = self._ema[h]
            self._ema[h] = t if prev is None \
                else self.ema_decay * prev + (1 - self.ema_decay) * t
        live = sorted(e for e in self._ema if e is not None)
        if not live:
            return {}
        median = live[len(live) // 2]
        actions: Dict[int, str] = {}
        for h, e in enumerate(self._ema):
            if e is None:
                continue
            if e > self.threshold * median:
                self._strikes[h] += 1
            else:
                self._strikes[h] = 0
            if self._strikes[h] >= self.evict_after:
                actions[h] = "evict"
            elif self._strikes[h] >= self.patience:
                actions[h] = "rebalance"
        return actions

    def drop_host(self, host: int):
        self._ema[host] = None
        self._strikes[host] = 0

    def microbatch_weights(self) -> List[float]:
        """Per-host work shares inversely proportional to EMA step time
        (the 'rebalance' mitigation). Sums to n_live."""
        live = [(h, e) for h, e in enumerate(self._ema) if e is not None]
        if not live:
            return []
        inv = [1.0 / e for _, e in live]
        s = sum(inv)
        n = len(live)
        return [n * x / s for x in inv]
