from repro.runtime.driver import Trainer, TrainerConfig
from repro.runtime.elastic import ElasticConfig, build_1d_mesh, solve_elastic
from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor
