from repro.runtime.driver import Trainer, TrainerConfig
from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor
