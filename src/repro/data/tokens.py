"""Deterministic synthetic LM token pipeline.

Production-shaped: sharded per data-parallel rank, deterministic from
(seed, step) so any step's batch can be regenerated exactly — which makes
the iterator state checkpointable as a single integer and restores
bit-identical batches after failures or elastic re-meshing (the number of
data shards may change between restarts; the *global* batch for a step is
invariant because it is generated globally and sliced per shard).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class TokenPipelineState:
    step: int = 0


class TokenPipeline:
    """Yields (tokens, targets) batches of synthetic text-like data.

    Tokens follow a Zipfian unigram distribution with short-range repeat
    structure so losses are non-trivial (the model can learn something).
    """

    def __init__(self, vocab_size: int, global_batch: int, seq_len: int,
                 seed: int = 0, zipf_a: float = 1.2):
        self.vocab_size = int(vocab_size)
        self.global_batch = int(global_batch)
        self.seq_len = int(seq_len)
        self.seed = int(seed)
        self.zipf_a = float(zipf_a)
        self.state = TokenPipelineState()
        # Zipf-ish unigram distribution over the vocab (stable, O(V)).
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** self.zipf_a
        self._probs = (p / p.sum()).astype(np.float64)

    # -- deterministic batch generation --------------------------------

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """The global batch for ``step`` (same result on every host)."""
        rng = np.random.default_rng((self.seed << 32) ^ step)
        toks = rng.choice(self.vocab_size, p=self._probs,
                          size=(self.global_batch, self.seq_len + 1))
        # short-range copy structure: repeat a window with prob 1/4.
        w = self.seq_len // 8
        if w > 1:
            repeat = rng.random(self.global_batch) < 0.25
            src = toks[:, :w]
            toks[repeat, w:2 * w] = src[repeat]
        tokens = toks[:, :-1].astype(np.int32)
        targets = toks[:, 1:].astype(np.int32)
        return tokens, targets

    def shard_at(self, step: int, shard: int, n_shards: int
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """The per-data-shard slice of step's global batch. Invariant to
        how many shards exist — the basis for elastic re-sharding."""
        if self.global_batch % n_shards != 0:
            raise ValueError(
                f"global_batch {self.global_batch} not divisible by "
                f"{n_shards} shards")
        tokens, targets = self.batch_at(step)
        per = self.global_batch // n_shards
        sl = slice(shard * per, (shard + 1) * per)
        return tokens[sl], targets[sl]

    # -- iterator protocol with checkpointable state --------------------

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        return self

    def __next__(self) -> Tuple[np.ndarray, np.ndarray]:
        batch = self.batch_at(self.state.step)
        self.state.step += 1
        return batch

    def checkpoint(self) -> dict:
        return {"step": self.state.step, "seed": self.seed,
                "global_batch": self.global_batch, "seq_len": self.seq_len,
                "vocab_size": self.vocab_size}

    @classmethod
    def restore(cls, ckpt: dict) -> "TokenPipeline":
        pipe = cls(vocab_size=ckpt["vocab_size"],
                   global_batch=ckpt["global_batch"],
                   seq_len=ckpt["seq_len"], seed=ckpt["seed"])
        pipe.state.step = ckpt["step"]
        return pipe
