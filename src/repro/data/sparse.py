"""Synthetic sparse datasets mirroring the paper's LIBSVM shape regimes.

The container is offline, so we generate matrices that match each paper
dataset's *regime* — aspect ratio (over/under-determined), density, value
scale — at CPU-feasible sizes. The SA claims under test (identical iterate
sequences, s-fold latency reduction, s-fold flop/bandwidth growth) are
dataset-independent; the paper itself emphasizes speedups hold across
"over/under-determined, sparse and dense" data (Sec. IV-B).

Matrices come in TWO coupled forms drawn from the SAME RNG stream: the
dense array with explicit zero patterns, and (``as_operand=True``) a
:class:`repro.core.types.SparseOperand` — BCOO plus the padded
blocked-ELL layout that ``repro.kernels.spmm`` executes, so density is
no longer just a cost-model parameter (DESIGN.md "Sparse operands").
``operand.todense()`` reproduces the dense form bit-for-bit.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.types import SparseOperand


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str
    m: int               # data points
    n: int               # features
    density: float       # fraction of nonzeros
    paper_analogue: str  # which LIBSVM dataset's regime this mirrors


# Scaled-down analogues of paper Tables II & IV (regime preserved).
SYNTHETIC_DATASETS = {
    # Lasso regimes (Table II)
    "url-like": SyntheticSpec("url-like", m=4096, n=6144, density=0.004,
                              paper_analogue="url (sparse, n > m)"),
    "news20-like": SyntheticSpec("news20-like", m=2048, n=8192, density=0.0013,
                                 paper_analogue="news20 (sparse, n >> m)"),
    "covtype-like": SyntheticSpec("covtype-like", m=16384, n=54, density=0.22,
                                  paper_analogue="covtype (dense-ish, m >> n)"),
    "epsilon-like": SyntheticSpec("epsilon-like", m=8192, n=512, density=1.0,
                                  paper_analogue="epsilon (dense, m >> n)"),
    "leu-like": SyntheticSpec("leu-like", m=38, n=7129, density=1.0,
                              paper_analogue="leu (dense, tiny m)"),
    # SVM regimes (Table IV)
    "w1a-like": SyntheticSpec("w1a-like", m=300, n=2477, density=0.04,
                              paper_analogue="w1a"),
    "duke-like": SyntheticSpec("duke-like", m=44, n=7129, density=1.0,
                               paper_analogue="duke"),
    "rcv1-like": SyntheticSpec("rcv1-like", m=4096, n=8192, density=0.0016,
                               paper_analogue="rcv1.binary"),
    "gisette-like": SyntheticSpec("gisette-like", m=2048, n=4096, density=0.99,
                                  paper_analogue="gisette"),
}


def _sparse_matrix(rng: np.random.Generator, m: int, n: int,
                   density: float, dtype=np.float32) -> np.ndarray:
    A = rng.standard_normal((m, n)).astype(dtype)
    if density < 1.0:
        mask = rng.random((m, n)) < density
        A = A * mask
        # guarantee no empty column (keeps Gram blocks nonzero).
        empty = ~mask.any(axis=0)
        if empty.any():
            rows = rng.integers(0, m, size=int(empty.sum()))
            A[rows, np.flatnonzero(empty)] = \
                rng.standard_normal(int(empty.sum())).astype(dtype)
    return A


def make_lasso_dataset(name: str, seed: int = 0, k_sparse: int = 32,
                       noise: float = 0.1, as_operand: bool = False
                       ) -> Tuple[object, np.ndarray, float]:
    """Returns (A, b, lam_max) for a named synthetic regime.

    b = A x_true + noise with a k-sparse planted x_true, so lasso has a
    meaningful sparse solution. lam_max = ||A^T b||_inf is the smallest
    lambda for which x* = 0; benchmarks use fractions of it.

    as_operand=True returns A as a :class:`SparseOperand` (BCOO +
    blocked-ELL) built from the SAME dense draw — same RNG stream, and
    ``A.todense()`` equals the dense form exactly, so dense and sparse
    solves of one named dataset see identical data.
    """
    spec = SYNTHETIC_DATASETS[name]
    rng = np.random.default_rng(seed)
    A = _sparse_matrix(rng, spec.m, spec.n, spec.density)
    x_true = np.zeros(spec.n, dtype=np.float32)
    support = rng.choice(spec.n, size=min(k_sparse, spec.n), replace=False)
    x_true[support] = rng.standard_normal(len(support)).astype(np.float32)
    b = A @ x_true + noise * rng.standard_normal(spec.m).astype(np.float32)
    lam_max = float(np.abs(A.T @ b).max())
    if as_operand:
        A = SparseOperand.from_dense(A)
    return A, b.astype(np.float32), lam_max


def make_svm_dataset(name: str, seed: int = 0, margin: float = 1.0,
                     as_operand: bool = False
                     ) -> Tuple[object, np.ndarray]:
    """Returns (A, b) — linearly-separable-ish binary classification with
    labels in {-1, +1}, mirroring the named regime.

    margin controls separability: labels are the sign of the planted
    scores plus noise scaled by 1/margin, so LARGER margin means LESS
    label noise (more separable), margin -> inf means perfectly
    separable. (The historical formula multiplied the noise BY margin —
    larger "margin" made the problem noisier.) margin = 1, the default,
    is bit-identical to the historical datasets.

    as_operand: as in :func:`make_lasso_dataset`.
    """
    if margin <= 0:
        raise ValueError(f"margin must be > 0, got {margin}")
    spec = SYNTHETIC_DATASETS[name]
    rng = np.random.default_rng(seed)
    A = _sparse_matrix(rng, spec.m, spec.n, spec.density)
    w = rng.standard_normal(spec.n).astype(np.float32)
    w /= np.linalg.norm(w)
    scores = A @ w
    b = np.sign(scores + (0.1 / margin) * rng.standard_normal(spec.m))
    b[b == 0] = 1.0
    if as_operand:
        A = SparseOperand.from_dense(A)
    return A, b.astype(np.float32)
