from repro.data.sparse import make_lasso_dataset, make_svm_dataset, \
    SYNTHETIC_DATASETS
from repro.data.tokens import TokenPipeline
