from repro.roofline.analysis import (HW_V5E, CollectiveStats,
                                     collective_bytes_from_hlo,
                                     collective_stats_from_hlo,
                                     roofline_terms, model_flops)
