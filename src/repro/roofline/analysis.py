"""Roofline term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell — all in seconds:

    compute    = HLO_FLOPs        / (chips * peak_FLOPs_per_chip)
    memory     = HLO_bytes        / (chips * HBM_bandwidth)
    collective = collective_bytes / (chips * ICI_link_bandwidth)

Sources and corrections (measured behaviours of jax 0.8.2 / XLA-CPU in
this container — see DESIGN.md §3):
  * ``compiled.cost_analysis()`` reports PER-DEVICE numbers and counts a
    ``scan`` body ONCE regardless of trip count -> we extract per-layer
    costs by a two-point fit over unrolled reduced-depth lowerings
    (cost = fixed + n_groups * per_group) and extrapolate to full depth.
  * XLA counts dot FLOPs as M*N*K (MACs). We convert MAC -> FLOP with x2
    on the reported total (matmuls dominate; elementwise undercount is
    <1% for these models). Verified in tests/test_roofline.py.
  * collective bytes are not in cost_analysis -> parsed from the
    post-SPMD ``compiled.as_text()`` by summing result-shape bytes of
    all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute ops (same two-point fit for scan bodies).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any, Dict, Mapping, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    peak_flops: float        # per chip, FLOP/s
    hbm_bw: float            # per chip, B/s
    ici_bw: float            # per link, B/s
    hbm_bytes: float         # per chip


HW_V5E = Hardware(name="tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                  ici_bw=50e9, hbm_bytes=16e9)

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result-shape token, e.g.  bf16[16,4096,256]{2,1,0}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    m = _SHAPE_RE.match(shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


_HLO_OP_RE = re.compile(
    r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")


@dataclasses.dataclass(frozen=True)
class CollectiveStats:
    """Typed per-collective counts and result bytes parsed from an HLO
    dump (replaces the historical dict whose ``counts`` entry was
    smuggled past the ``Dict[str, float]`` annotation with a
    ``# type: ignore``)."""

    counts: Mapping[str, int]
    bytes: Mapping[str, float]

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.counts.values()))


def collective_stats_from_hlo(hlo_text: str) -> CollectiveStats:
    """Count collective ops and sum their result bytes in a (post-SPMD)
    HLO dump.

    ONE rule covers every form an op can take:

      * plain:        ``bf16[128,256]{1,0} all-reduce(...)`` — count the
        op once, sum every result shape (tuple results are variadic
        collectives: each element is a distinct reduced buffer);
      * ``-start``:   the async launch half of a ``-start``/``-done``
        pair. When its result is a 2k-tuple whose halves match, the
        first half aliases the operand buffers and only the second half
        is the communicated result — count the op once with the result
        half's bytes (the historical parser summed both, double
        counting every async collective);
      * ``-done``:    the completion marker of a pair already counted at
        its ``-start`` — skipped entirely.

    Ops inside while bodies are counted once (caller applies trip-count
    fits).
    """
    counts = {c: 0 for c in _COLLECTIVES}
    bytes_ = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _HLO_OP_RE.match(line.strip())
        if not m:
            continue
        shapes_part, op, suffix = m.groups()
        if suffix == "-done":
            continue
        shapes = [_shape_bytes(sm.group(0))
                  for sm in _SHAPE_RE.finditer(shapes_part)]
        if suffix == "-start" and len(shapes) % 2 == 0 and \
                shapes[:len(shapes) // 2] == shapes[len(shapes) // 2:]:
            shapes = shapes[len(shapes) // 2:]
        counts[op] += 1
        bytes_[op] += float(sum(shapes))
    return CollectiveStats(counts=counts, bytes=bytes_)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Any]:
    """Legacy dict view of :func:`collective_stats_from_hlo` — per-op
    bytes keyed by op name, plus ``"total"`` (bytes) and ``"counts"``
    (the per-op count dict)."""
    stats = collective_stats_from_hlo(hlo_text)
    out: Dict[str, Any] = dict(stats.bytes)
    out["total"] = stats.total_bytes
    out["counts"] = dict(stats.counts)
    return out


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` normalized across jax versions:
    older jax returns a one-element list of dicts, newer jax the dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def two_point_fit(cost1: float, cost2: float, n1: int, n2: int,
                  n_target: int) -> float:
    """cost(n) = fixed + n * per_unit, fit on (n1, cost1), (n2, cost2)."""
    per = (cost2 - cost1) / max(n2 - n1, 1)
    fixed = cost1 - n1 * per
    return fixed + n_target * per


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float, hw: Hardware = HW_V5E,
                   mac_correction: float = 1.0) -> Dict[str, float]:
    """The three terms (seconds) + the bound classification."""
    compute = flops_per_dev * mac_correction / hw.peak_flops
    memory = bytes_per_dev / hw.hbm_bw
    collective = coll_bytes_per_dev / hw.ici_bw
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant,
            "bound_s": total,
            "roofline_fraction": compute / total if total > 0 else 0.0}


def model_flops(n_params_active: int, kind: str, tokens: int,
                batch: int = 1) -> float:
    """MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for inference.

    decode: D = batch (one token per sequence per step).
    """
    if kind == "train":
        return 6.0 * n_params_active * tokens
    if kind == "prefill":
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * batch        # decode: per step
