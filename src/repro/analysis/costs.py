"""Static Table I cost certification.

The collectives pass proves the STRUCTURAL half of the paper's claim
(one fused Allreduce per outer iteration); this pass proves the COST
half: the F/W/L entries of Table I — the s-scaling the CA-BCD line
(arXiv:1612.04003) and the CA-proximal line (arXiv:1710.08883) derive
analytically, and that ``repro.tune`` trusts through the per-family
``costs`` hooks — match the computation we actually lower. For every
registered family x variant it:

  * traces the FULL sharded solve (``repro.core.api.trace_sharded``)
    and walks the jaxpr with the same recursive scan/while traversal as
    the collectives pass, counting flops (dot_general / conv
    contraction dims x output size, scatter-add update elements for the
    sparse deferred updates — each multiplied by the enclosing scan
    trip counts) and all-reduce payload words, split per-iteration vs
    amortized by loop nesting;
  * evaluates the family's ``costs`` hook at the same (dims, s, mu,
    P=1) and certifies that counted F and W sit inside a declared
    per-family tolerance band of the modeled terms;
  * sweeps SA variants over an s grid and certifies the Table I
    s-scaling: the counted/modeled ratio must not DRIFT across the grid
    (a cost hook with a wrong s exponent drifts by s_max/s_min ~ 16,
    far past the declared tolerance), while the runtime message count
    equals ceil(H/s) — the modeled L falling as 1/s;
  * re-traces with a concrete :class:`SparseOperand` and certifies the
    two hot products count O(nnz), not O(mn): counted sparse flops must
    stay within ``sparse_factor`` x density of the dense count (Table
    I's density factor f is executed, not just modeled).

Tolerance rationale: the counted numbers are EXACT for the traced
program, but Table I keeps only leading terms — the model drops the
factor ~2 multiply-add convention, the appended projection columns, and
O(s mu n) deferred-update GEMVs, so counted/modeled sits in a small
constant band (measured 2.0-3.7x across the registry) that shrinks as
the additive terms amortize with s. The bands are declared per family
(new families inherit the defaults with zero wiring) and are deliberate
orders of magnitude tighter than the s^2-per-grid-step drift a wrong
exponent produces.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.analysis.collectives import _subjaxprs
from repro.analysis.common import (Diagnostic, family_variants,
                                   variant_config)
from repro.core.types import ProblemFamily, SolverConfig, SparseOperand

# Certification shapes: large enough that the model's leading terms
# dominate its dropped lower-order ones (at the 64x32 bench shapes the
# +1/+2 appended projection columns alone drift the ratio), small
# enough that tracing all families x variants x s stays ~1 s total.
CERT_SHAPES = {"row": (384, 128), "col": (128, 384)}
CERT_ITERATIONS = 48            # divisible by every s in the grid
CERT_S_GRID = (1, 4, 16)
CERT_DENSITY = 0.08


@dataclasses.dataclass(frozen=True)
class CostTolerance:
    """Per-family certification tolerances (see module docstring).

    f_band / w_band: admissible counted/modeled ratio for the F and W
        terms at every s on the grid.
    drift: admissible (max ratio)/(min ratio) across the s grid — the
        s-scaling detector. A hook whose F carries one extra (or one
        missing) power of s drifts by (s_max/s_min) = 16 on the default
        grid; a wrong s^2 drifts by 256.
    mu: certification block size override (None = the family's
        bench_block_size). svm certifies at mu=4: at its bench mu=1 the
        O(s mu n) deferred GEMVs the model drops are the SAME order as
        the modeled mu^2 s n Gram term, which inflates the ratio ~3x
        at s=1 and fakes a drift.
    sparse_factor: admissible counted-sparse / (density x counted-dense)
        flop ratio — the O(nnz)-not-O(mn) certificate, with headroom
        for blocked-ELL width padding.
    """

    f_band: Tuple[float, float] = (0.4, 8.0)
    w_band: Tuple[float, float] = (0.4, 4.0)
    drift: float = 2.5
    mu: Optional[int] = None
    sparse_factor: float = 4.0


COST_TOLERANCES: Dict[str, CostTolerance] = {
    "svm": CostTolerance(mu=4),
}


def cost_tolerance(family_name: str) -> CostTolerance:
    """The declared tolerance for a family — defaults for any family
    not listed in :data:`COST_TOLERANCES` (zero per-family wiring)."""
    return COST_TOLERANCES.get(family_name, CostTolerance())


@dataclasses.dataclass(frozen=True)
class CostCount:
    """Counted costs of one traced solve.

    flops: total floating-point operations (2 x output x contraction
        for dot_general/conv, update elements for scatter-add), with
        every eqn weighted by the product of enclosing scan lengths.
    flops_in_loop: the subset issued inside a scan/while body (the
        per-outer-iteration work; the rest is setup / remainder tail).
    words: all-reduce payload ELEMENTS moved (the model's W is in
        words, so no itemsize here — bytes live in CollectiveBudget).
    messages: runtime all-reduce executions (eqn count x trip counts) —
        the model's L at logP = 1.
    allreduces_in_loop: distinct in-loop all-reduce eqns (the
        structural count the collectives pass budgets).
    """

    flops: float
    flops_in_loop: float
    words: float
    messages: float
    allreduces_in_loop: int


def _prod(shape) -> float:
    return float(np.prod(shape, dtype=np.int64)) if shape else 1.0


def _dot_flops(eqn) -> float:
    (lhs_c, _), _ = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    contract = _prod([lhs[i] for i in lhs_c])
    return 2.0 * _prod(eqn.outvars[0].aval.shape) * contract


def _conv_flops(eqn) -> float:
    rhs = eqn.invars[1].aval.shape
    dn = eqn.params["dimension_numbers"]
    out_ch_dim = dn.rhs_spec[0]
    contract = _prod(rhs) / max(float(rhs[out_ch_dim]), 1.0)
    return 2.0 * _prod(eqn.outvars[0].aval.shape) * contract


def cost_count(closed_jaxpr) -> CostCount:
    """Walk a (Closed)Jaxpr recursively and accumulate the counted
    costs. Scan bodies multiply by their static trip count; while
    bodies count once (trip counts are data-dependent) but mark their
    contents as in-loop, mirroring the collectives pass."""
    tot = {"flops": 0.0, "flops_in": 0.0, "words": 0.0, "messages": 0.0}
    ar_in = [0]

    def walk(jaxpr, mult: float, in_loop: bool) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "dot_general":
                f = _dot_flops(eqn) * mult
                tot["flops"] += f
                if in_loop:
                    tot["flops_in"] += f
            elif name == "conv_general_dilated":
                f = _conv_flops(eqn) * mult
                tot["flops"] += f
                if in_loop:
                    tot["flops_in"] += f
            elif name in ("scatter-add", "scatter_add"):
                f = _prod(eqn.invars[2].aval.shape) * mult
                tot["flops"] += f
                if in_loop:
                    tot["flops_in"] += f
            elif name == "psum":
                tot["words"] += mult * sum(_prod(v.aval.shape)
                                           for v in eqn.outvars)
                tot["messages"] += mult
                if in_loop:
                    ar_in[0] += 1
            inner_mult, inner_loop = mult, in_loop
            if name == "scan":
                inner_mult = mult * eqn.params["length"]
                inner_loop = True
            elif name == "while":
                inner_loop = True
            for sub in _subjaxprs(eqn):
                walk(sub, inner_mult, inner_loop)

    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    walk(jaxpr, 1.0, False)
    return CostCount(flops=tot["flops"], flops_in_loop=tot["flops_in"],
                     words=tot["words"], messages=tot["messages"],
                     allreduces_in_loop=ar_in[0])


def solver_cost_count(fam: ProblemFamily, cfg: SolverConfig, mesh=None,
                      m: Optional[int] = None, n: Optional[int] = None,
                      dtype=None,
                      operand: Optional[SparseOperand] = None
                      ) -> CostCount:
    """The counted costs of one family x config sharded solve (dense
    shape (m, n), or the sparse path when ``operand`` is given). A
    1-device mesh (the default) is enough — the counts are symbolic."""
    from repro.core import api
    import jax.numpy as jnp
    if mesh is None:
        axis = fam.default_axes if isinstance(fam.default_axes, str) \
            else fam.default_axes[0]
        mesh = jax.make_mesh((1,), (axis,))
    if operand is None and (m is None or n is None):
        m, n = CERT_SHAPES[fam.partition]
    traced = api.trace_sharded(fam, cfg, mesh, m=m, n=n,
                               dtype=dtype or jnp.float32,
                               operand=operand)
    return cost_count(traced.jaxpr)


def certification_operand(fam: ProblemFamily,
                          density: float = CERT_DENSITY
                          ) -> SparseOperand:
    """A deterministic sparse operand at the family's certification
    shape: row i holds ~density x n nonzeros at evenly strided columns
    with values cycling over a small fixed set. No RNG — the certifier
    must produce the same verdict on every run."""
    m, n = CERT_SHAPES[fam.partition]
    k = max(1, int(round(density * n)))
    step = max(n // k, 1)
    dense = np.zeros((m, n), np.float32)
    for i in range(m):
        for j in range(k):
            dense[i, (i + j * step) % n] = 1.0 + 0.25 * ((i * k + j) % 7)
    return SparseOperand.from_dense(dense, with_bcoo=False)


@dataclasses.dataclass(frozen=True)
class CostRow:
    """One certification point: family x variant x s, counted vs
    modeled. ``sparse_flops``/``density`` are None when the sparse
    trace was not taken."""

    family: str
    variant: str
    s: int
    mu: int
    flops: float
    model_flops: float
    words: float
    model_words: float
    messages: float
    outer: int
    allreduces_in_loop: int
    sparse_flops: Optional[float] = None
    density: Optional[float] = None

    @property
    def f_ratio(self) -> float:
        return self.flops / max(self.model_flops, 1.0)

    @property
    def w_ratio(self) -> float:
        return self.words / max(self.model_words, 1.0)

    @property
    def sparse_ratio(self) -> Optional[float]:
        """counted-sparse / (density x counted-dense) flops — <= 1 for
        ideal nnz scaling; a dense-shaped sparse path sits at 1/density
        (12.5 at the default density)."""
        if self.sparse_flops is None:
            return None
        return self.sparse_flops / max(self.density * self.flops, 1.0)


def cost_ratio_rows(fam: ProblemFamily,
                    variants: Optional[Sequence[str]] = None,
                    mesh=None, s_grid: Sequence[int] = CERT_S_GRID,
                    iterations: int = CERT_ITERATIONS,
                    sparse: bool = True,
                    tolerance: Optional[CostTolerance] = None
                    ) -> List[CostRow]:
    """Trace and count every requested variant of ``fam`` across the s
    grid (classical variants certify at s=1 only — they have no s axis)
    and pair each count with the family's modeled costs. The raw table
    behind :func:`check_costs`, ``benchmarks/certify.py`` and the
    quickstart's certified cost table."""
    from repro.core.cost_model import ProblemDims
    if fam.costs is None:
        raise ValueError(
            f"family {fam.name!r} declares no costs hook — nothing to "
            f"certify (register costs= to enable Table I certification)")
    tol = tolerance if tolerance is not None else cost_tolerance(fam.name)
    mu = tol.mu or fam.bench_block_size
    kern = dict(fam.bench_problem_kwargs).get("kernel", "linear")
    m, n = CERT_SHAPES[fam.partition]
    operand = certification_operand(fam) if sparse else None
    density = (operand.nnz / float(m * n)) if sparse else None
    rows: List[CostRow] = []
    for variant in variants or family_variants(fam):
        grid = tuple(s_grid) if variant.startswith(("sa", "ca")) else (1,)
        for s in grid:
            if iterations % s:
                raise ValueError(
                    f"iterations={iterations} not divisible by s={s}: "
                    f"the tail group would blur the per-outer split")
            cfg = variant_config(fam, variant, iterations=iterations,
                                 s=s, block_size=mu)
            count = solver_cost_count(fam, cfg, mesh=mesh, m=m, n=n)
            model = fam.costs(ProblemDims(m=m, n=n, f=1.0), iterations,
                              mu, s, 1, kernel=kern)
            sp = None
            if sparse:
                sp = solver_cost_count(fam, cfg, mesh=mesh,
                                       operand=operand).flops
            rows.append(CostRow(
                family=fam.name, variant=variant, s=s, mu=mu,
                flops=count.flops, model_flops=float(model["F"]),
                words=count.words, model_words=float(model["W"]),
                messages=count.messages, outer=cfg.outer_iterations,
                allreduces_in_loop=count.allreduces_in_loop,
                sparse_flops=sp, density=density))
    return rows


def _band_diag(where: str, term: str, band: Tuple[float, float],
               offenders: List[Tuple[int, float]]) -> Diagnostic:
    worst = max(offenders,
                key=lambda sr: max(sr[1] / band[1], band[0] / sr[1]))
    return Diagnostic(
        "costs", "error", where,
        f"term {term}: counted/modeled ratio "
        f"{worst[1]:.3g} at s={worst[0]} outside the declared band "
        f"[{band[0]:g}, {band[1]:g}] "
        f"({len(offenders)} of the grid points violate) — the "
        f"registered costs hook does not describe the lowered "
        f"computation")


def check_costs(fam: ProblemFamily,
                variants: Optional[Sequence[str]] = None,
                mesh=None, s_grid: Sequence[int] = CERT_S_GRID,
                iterations: int = CERT_ITERATIONS,
                sparse: bool = True,
                tolerance: Optional[CostTolerance] = None
                ) -> Tuple[List[Diagnostic], List[str]]:
    """Certify the family's Table I costs hook against the lowered
    computation, for every registered variant. Per variant, at most one
    error per violated term:

      * ``F`` / ``W`` band — counted/modeled outside the declared band
        at some s;
      * ``F``/``W`` ``s-scaling`` — the ratio drifts across the s grid
        beyond the declared drift tolerance (wrong s exponent);
      * ``L`` — runtime all-reduce messages differ from ceil(H/s) (the
        modeled latency term must fall as 1/s);
      * ``O(nnz)`` — the sparse trace's flops exceed
        sparse_factor x density x the dense count (a sparse path that
        secretly densifies).

    Returns (diagnostics, checked subjects); counted-vs-modeled ratios
    ride along as info diagnostics per variant either way.
    """
    tol = tolerance if tolerance is not None else cost_tolerance(fam.name)
    diags: List[Diagnostic] = []
    checked: List[str] = []
    rows = cost_ratio_rows(fam, variants=variants, mesh=mesh,
                           s_grid=s_grid, iterations=iterations,
                           sparse=sparse, tolerance=tol)
    by_variant: Dict[str, List[CostRow]] = {}
    for row in rows:
        by_variant.setdefault(row.variant, []).append(row)
    for variant, vrows in by_variant.items():
        where = f"{fam.name}:{variant}"
        checked.append(where)
        bad_f = [(r.s, r.f_ratio) for r in vrows
                 if not tol.f_band[0] <= r.f_ratio <= tol.f_band[1]]
        if bad_f:
            diags.append(_band_diag(where, "F", tol.f_band, bad_f))
        bad_w = [(r.s, r.w_ratio) for r in vrows
                 if not tol.w_band[0] <= r.w_ratio <= tol.w_band[1]]
        if bad_w:
            diags.append(_band_diag(where, "W", tol.w_band, bad_w))
        if len(vrows) > 1:
            for term, ratios in (
                    ("F", [r.f_ratio for r in vrows]),
                    ("W", [r.w_ratio for r in vrows])):
                drift = max(ratios) / max(min(ratios), 1e-12)
                if drift > tol.drift:
                    diags.append(Diagnostic(
                        "costs", "error", where,
                        f"term {term} s-scaling: counted/modeled ratio "
                        f"drifts {drift:.3g}x across s="
                        f"{[r.s for r in vrows]} (declared tolerance "
                        f"{tol.drift:g}x) — the costs hook carries a "
                        f"wrong s exponent (Table I scales F and W "
                        f"linearly in s for SA variants)"))
        bad_l = [r for r in vrows if r.messages != r.outer]
        if bad_l:
            r = bad_l[0]
            diags.append(Diagnostic(
                "costs", "error", where,
                f"term L: {r.messages:.0f} runtime all-reduce messages "
                f"at s={r.s}, expected ceil(H/s) = {r.outer} — the "
                f"modeled latency must fall as 1/s"))
        bad_nnz = [(r.s, r.sparse_ratio) for r in vrows
                   if r.sparse_ratio is not None
                   and r.sparse_ratio > tol.sparse_factor]
        if bad_nnz:
            s_bad, ratio = max(bad_nnz, key=lambda sr: sr[1])
            diags.append(Diagnostic(
                "costs", "error", where,
                f"term O(nnz): sparse-operand trace counts {ratio:.3g}x "
                f"(density x dense flops) at s={s_bad}, over the "
                f"declared {tol.sparse_factor:g}x — the hot products "
                f"must cost O(nnz), not O(mn) (Table I's density "
                f"factor f)"))
        summary = "; ".join(
            f"s={r.s}: F {r.f_ratio:.2f}x W {r.w_ratio:.2f}x"
            + (f" nnz {r.sparse_ratio:.2f}x"
               if r.sparse_ratio is not None else "")
            for r in vrows)
        diags.append(Diagnostic(
            "costs", "info", where,
            f"counted/modeled (mu={vrows[0].mu}): {summary}; "
            f"messages = ceil(H/s) at every point"
            if not bad_l else
            f"counted/modeled (mu={vrows[0].mu}): {summary}"))
    return diags, checked
