"""Repo lint: AST rules for the library source plus the registry
contract check.

Three AST rules over ``src/repro`` (tests and benchmarks are exempt —
they legitimately poke internals):

  * **raw-collective** — no direct ``jax.lax.psum`` / ``all_gather`` /
    ``ppermute`` / ... outside the blessed call sites. Every solver
    communicates through ``repro.core.linalg.preduce`` (so compression
    and the collective budget stay centralized); the allowlist is the
    wrapper itself, the engine, the compression layer that implements
    the wire format, and the microbenchmark that measures raw
    collective latency.
  * **ambient-rng** — no stdlib ``random`` and no ``np.random.*``
    global-state calls (``seed``/``rand``/``randn``/...) anywhere in
    the library: solver sampling must flow through keyed
    ``jax.random`` so runs are reproducible and shard-deterministic.
    ``np.random.default_rng`` (explicit generator object) is allowed
    only in the data/launch layers and the microbench timer.
  * **bare-assert** — no ``assert`` statements in library code:
    ``python -O`` strips them, so input validation must raise
    ``ValueError`` (the repo's established convention; see
    ``SolverConfig.__post_init__``).

Plus one runtime contract check:

  * **registry** — every module-level :class:`FamilyProgram` backing a
    registered family must have ``carry_names`` matching the family's
    ``state_layout(cfg)`` leaf names for at least one registered cfg
    shape, or checkpoints written by the engine cannot be restored by
    the drivers (``SolveState`` leaves are keyed by name).
"""
from __future__ import annotations

import ast
import inspect
import pathlib
from typing import Iterable, List, Optional, Tuple

from repro.analysis.common import Diagnostic, variant_config

COLLECTIVE_FNS = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_gather", "ppermute",
    "all_to_all", "psum_scatter", "pshuffle",
})

# repo-relative (to src/repro) files allowed to touch raw collectives:
# the preduce wrapper, the engine's schedule-free fallback, the
# compressed wire format, and the collective microbenchmark.
RAW_COLLECTIVE_ALLOW = frozenset({
    "core/linalg.py", "core/engine.py", "optim/compress.py",
    "tune/microbench.py",
})

# files/dirs (relative to src/repro) allowed to build explicit
# np.random.default_rng generators: synthetic data, the serving demo
# and the microbench timer. Global-state np.random.* is allowed nowhere.
DEFAULT_RNG_ALLOW_DIRS = ("data/", "launch/")
DEFAULT_RNG_ALLOW_FILES = frozenset({"tune/microbench.py"})

_NP_NAMES = frozenset({"np", "numpy"})
_RNG_GLOBAL_OK = frozenset({"default_rng", "Generator", "RandomState",
                            "SeedSequence", "BitGenerator", "Philox",
                            "PCG64"})


def _attr_chain(node) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str):
        self.rel = rel
        self.diags: List[Diagnostic] = []
        self._psum_ok = rel in RAW_COLLECTIVE_ALLOW
        self._rng_ok = rel in DEFAULT_RNG_ALLOW_FILES or any(
            rel.startswith(d) for d in DEFAULT_RNG_ALLOW_DIRS)

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        self.diags.append(Diagnostic(
            "lint", "error", f"{self.rel}:{node.lineno}",
            f"[{rule}] {msg}"))

    # -- raw collectives ------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if chain:
            leaf = chain[-1]
            if leaf in COLLECTIVE_FNS and not self._psum_ok and (
                    len(chain) == 1 or chain[-2] == "lax"):
                self._emit(
                    "raw-collective", node,
                    f"direct jax.lax.{leaf} call — solvers must "
                    f"communicate via repro.core.linalg.preduce so "
                    f"compression and the collective budget stay "
                    f"centralized")
            if len(chain) >= 3 and chain[0] in _NP_NAMES \
                    and chain[1] == "random":
                fn = chain[2]
                if fn not in _RNG_GLOBAL_OK:
                    self._emit(
                        "ambient-rng", node,
                        f"np.random.{fn} uses numpy's ambient global "
                        f"RNG state — library code must take a keyed "
                        f"jax PRNG (or an explicit Generator in the "
                        f"data layer)")
                elif not self._rng_ok:
                    self._emit(
                        "ambient-rng", node,
                        f"np.random.{fn} outside the data/launch/"
                        f"microbench layers — solver-side randomness "
                        f"must be keyed jax.random for shard-"
                        f"deterministic sampling")
        self.generic_visit(node)

    # -- stdlib random --------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name == "random" or alias.name.startswith("random."):
                self._emit("ambient-rng", node,
                           "stdlib random is ambient global state — "
                           "use keyed jax.random")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "random":
            self._emit("ambient-rng", node,
                       "stdlib random is ambient global state — "
                       "use keyed jax.random")
        if node.module == "jax.lax" and not self._psum_ok:
            for alias in node.names:
                if alias.name in COLLECTIVE_FNS:
                    self._emit(
                        "raw-collective", node,
                        f"importing {alias.name} from jax.lax — "
                        f"communicate via repro.core.linalg.preduce")
        self.generic_visit(node)

    # -- bare assert ----------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        self._emit("bare-assert", node,
                   "bare assert is stripped under python -O — raise "
                   "ValueError for input validation")
        self.generic_visit(node)


def lint_source(source: str, rel: str) -> List[Diagnostic]:
    """Lint one module's source text; ``rel`` is its path relative to
    the package root (``src/repro``)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        return [Diagnostic("lint", "error", f"{rel}:{exc.lineno or 0}",
                           f"[syntax] {exc.msg}")]
    linter = _Linter(rel)
    linter.visit(tree)
    return linter.diags


def lint_paths(root=None,
               ) -> Tuple[List[Diagnostic], List[str]]:
    """Lint every ``.py`` file under ``root`` (default: the installed
    ``repro`` package directory)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[1]
    root = pathlib.Path(root)
    diags: List[Diagnostic] = []
    checked: List[str] = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        checked.append(rel)
        diags.extend(lint_source(path.read_text(), rel))
    return diags, checked


def check_registry() -> Tuple[List[Diagnostic], List[str]]:
    """Cross-check every family's engine program against its declared
    checkpoint layout: ``FamilyProgram.carry_names`` must be covered by
    the names ``state_layout(cfg)`` declares for at least one registered
    cfg shape — otherwise engine-written ``SolveState`` leaves cannot be
    restored by name."""
    from repro.core.engine import FamilyProgram
    from repro.core.types import FAMILIES
    diags: List[Diagnostic] = []
    checked: List[str] = []
    for fam in FAMILIES.values():
        if fam.state_layout is None:
            continue
        layouts = []
        for accelerated in (False, True):
            try:
                cfg = variant_config(
                    fam, sorted(fam.variants)[0], s=8,
                    accelerated=accelerated)
            except (TypeError, ValueError):
                continue
            layouts.append(frozenset(
                name for name, _ in fam.state_layout(cfg)))
        programs = {}
        for vname in fam.variants:
            module = inspect.getmodule(fam.variant(vname))
            if module is None:
                continue
            for attr, val in vars(module).items():
                if isinstance(val, FamilyProgram):
                    programs[f"{module.__name__}.{attr}"] = val
        for pname, prog in programs.items():
            where = f"{fam.name}:{pname}"
            checked.append(where)
            carry = frozenset(prog.carry_names)
            if not any(carry <= layout for layout in layouts):
                missing = carry - frozenset().union(*layouts) \
                    if layouts else carry
                diags.append(Diagnostic(
                    "registry", "error", where,
                    f"carry_names {sorted(carry)} not covered by any "
                    f"state_layout(cfg) ({[sorted(l) for l in layouts]}"
                    f") — leaves {sorted(missing)} would checkpoint "
                    f"under names the restore path cannot map"))
    return diags, checked
