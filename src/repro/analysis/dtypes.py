"""Silent f64 -> f32 downcast detection.

The numerical claims of the paper (machine-precision agreement of the
s-step recurrences with the classical iterates, Fig. 5's stability
sweeps) are only meaningful if a float64 experiment actually runs in
float64 end to end. jax makes that easy to break silently: any literal
created without an explicit dtype, any ``jnp.zeros`` default, any
numpy float32 constant inserts a ``convert_element_type`` that narrows
the computation — and nothing warns.

This pass traces each family×variant solve with float64 inputs under
``jax.experimental.enable_x64`` and walks the jaxpr (recursively, into
scan/while/cond/pjit bodies) for ``convert_element_type`` equations
whose source dtype is a WIDER float than their destination — each one
is a place where precision is silently discarded, reported with its
jax source location.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.common import (Diagnostic, bench_shape, family_variants,
                                   variant_config)
from repro.core.types import ProblemFamily


def _source_line(eqn) -> str:
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info)
        if frame is not None:
            return f"{frame.file_name.rsplit('/', 1)[-1]}:" \
                   f"{frame.start_line}"
    except Exception:
        pass
    return "<unknown>"


def find_float_narrowing(jaxpr) -> List[Tuple[str, str, str]]:
    """All float-narrowing ``convert_element_type`` eqns in a
    (Closed)Jaxpr, recursively: (src_dtype, dst_dtype, source_line)."""
    found: List[Tuple[str, str, str]] = []

    def walk(open_j) -> None:
        from jax._src import core as jcore
        for eqn in open_j.eqns:
            if eqn.primitive.name == "convert_element_type":
                src = np.dtype(eqn.invars[0].aval.dtype)
                dst = np.dtype(eqn.params["new_dtype"])
                if src.kind == "f" and dst.kind == "f" \
                        and src.itemsize > dst.itemsize:
                    found.append((src.name, dst.name, _source_line(eqn)))
            for val in eqn.params.values():
                vals = val if isinstance(val, (list, tuple)) else (val,)
                for v in vals:
                    if isinstance(v, jcore.ClosedJaxpr):
                        walk(v.jaxpr)
                    elif isinstance(v, jcore.Jaxpr):
                        walk(v)

    walk(getattr(jaxpr, "jaxpr", jaxpr))
    return found


def check_dtypes(fam: ProblemFamily,
                 variants: Optional[Tuple[str, ...]] = None,
                 iterations: int = 16) -> Tuple[List[Diagnostic], List[str]]:
    """Trace each variant's sharded solve with float64 inputs (x64
    enabled for the duration of the trace only — the process-global
    flag is untouched) and flag every silent float narrowing."""
    from jax.experimental import enable_x64
    from repro.core import api
    import jax.numpy as jnp
    diags: List[Diagnostic] = []
    checked: List[str] = []
    axis = fam.default_axes if isinstance(fam.default_axes, str) \
        else fam.default_axes[0]
    mesh = jax.make_mesh((1,), (axis,))
    m, n = bench_shape(fam)
    for variant in variants or family_variants(fam):
        where = f"{fam.name}:{variant}"
        checked.append(where)
        cfg = variant_config(fam, variant, iterations=iterations,
                             dtype=jnp.float64)
        with enable_x64():
            traced = api.trace_sharded(fam, cfg, mesh, m=m, n=n,
                                       dtype=jnp.float64)
        for src, dst, line in find_float_narrowing(traced.jaxpr):
            diags.append(Diagnostic(
                "dtypes", "error", where,
                f"silent {src} -> {dst} downcast at {line}: a float64 "
                f"solve loses precision through an implicit "
                f"convert_element_type (unhinted literal or np.float32 "
                f"constant) — thread the dtype through instead"))
    return diags, checked
