"""Replication-divergence taint analysis over shard_map jaxprs.

The sharded solvers run with ``check_rep=False`` (jax 0.4.x's
replication checker rejects several legitimate SA programs), which
means NOTHING verifies the replication contract the drivers rely on:
every output ``solve_sharded`` declares replicated (``out_specs=P()``)
— the objective, the row-partitioned families' full x, the replicated
state leaves the elastic runtime checkpoints — must compute the SAME
value on every shard. A shard-divergent "replicated" output is the
worst kind of bug: single-device tests pass, multi-device runs silently
diverge per shard and the fault-tolerant re-shard path restores garbage.

This pass recovers the guarantee statically. Each value in the
shard_map body carries a taint: the set of mesh axes its value may vary
over. The rules:

  * inputs taint with the axes their ``in_names`` shard them over;
  * ``axis_index(a)`` is the canonical divergence source — taint {a};
  * ``psum`` over axes A *removes* A from the operand taint (summing
    across an axis makes the result invariant along it) — this is the
    ONLY way a partition-tainted value becomes replicated;
  * everything else unions its operand taints;
  * scan/while carries iterate to a fixpoint; a while whose predicate
    is tainted poisons every carry (shards may run different trip
    counts); a cond joins branch outputs and a tainted predicate
    poisons the join (shards may take different branches).

An output declared replicated whose taint still contains a mesh axis is
an error naming the axis and the output (``state.gram`` etc.). The
analysis is purely symbolic — it runs on the 1-device trace, no devices
or compilation involved.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, List, Optional, Tuple

import jax

from repro.analysis.common import (Diagnostic, bench_shape, family_variants,
                                   variant_config)
from repro.core.types import ProblemFamily

Taint = FrozenSet[str]
EMPTY: Taint = frozenset()

# Primitives whose params hold the sub-jaxpr(s) we recurse into with a
# plain invar->outvar mapping (no carry fixpoint needed).
_CALL_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _as_open(j):
    return getattr(j, "jaxpr", j)


def _read(env: Dict, v) -> Taint:
    from jax._src.core import Literal
    if isinstance(v, Literal):
        return EMPTY
    return env.get(v, EMPTY)


def taint_jaxpr(jaxpr, in_taints: List[Taint]) -> List[Taint]:
    """Propagate taints through one (open) jaxpr; returns the outvar
    taints. Conservative: any primitive it does not model forwards the
    union of its operand taints to every output."""
    env: Dict = {}
    open_j = _as_open(jaxpr)
    if len(open_j.invars) != len(in_taints):
        raise ValueError(
            f"taint_jaxpr: {len(open_j.invars)} invars but "
            f"{len(in_taints)} input taints")
    for var, t in zip(open_j.invars, in_taints):
        env[var] = t
    for var in open_j.constvars:
        env[var] = EMPTY

    for eqn in open_j.eqns:
        name = eqn.primitive.name
        ins = [_read(env, v) for v in eqn.invars]
        union: Taint = frozenset().union(*ins) if ins else EMPTY

        if name == "psum":
            axes = frozenset(eqn.params.get("axes", ()))
            outs = [t - axes for t in ins]
        elif name == "axis_index":
            outs = [frozenset({eqn.params["axis_name"]})]
        elif name in ("all_gather", "pgather"):
            # gathers materialize every shard on every shard: the
            # result no longer varies over the gathered axis.
            axes = eqn.params.get("axis_name", ())
            axes = frozenset((axes,) if isinstance(axes, str) else axes)
            outs = [union - axes for _ in eqn.outvars]
        elif name == "scan":
            nc = eqn.params["num_consts"]
            ncarry = eqn.params["num_carry"]
            body = eqn.params["jaxpr"]
            consts, carry = ins[:nc], ins[nc:nc + ncarry]
            xs = ins[nc + ncarry:]
            carry = _fixpoint(
                body, lambda c: consts + c + xs,
                lambda o: o[:ncarry], carry)
            body_out = taint_jaxpr(body, consts + carry + xs)
            outs = list(carry) + body_out[ncarry:]
        elif name == "while":
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            cond_j, body_j = eqn.params["cond_jaxpr"], eqn.params["body_jaxpr"]
            cond_c, body_c = ins[:cn], ins[cn:cn + bn]
            carry = ins[cn + bn:]
            carry = _fixpoint(
                body_j, lambda c: body_c + c, lambda o: o, carry)
            pred = taint_jaxpr(cond_j, cond_c + carry)[0]
            if pred:
                # shards may disagree on when to stop: every carry
                # inherits the predicate's divergence.
                carry = [t | pred for t in carry]
            outs = list(carry)
        elif name == "cond":
            pred, operands = ins[0], ins[1:]
            branch_outs = [taint_jaxpr(b, list(operands))
                           for b in eqn.params["branches"]]
            outs = [frozenset().union(*(bo[i] for bo in branch_outs)) | pred
                    for i in range(len(eqn.outvars))]
        elif any(p in eqn.params for p in _CALL_PARAMS):
            sub = next(eqn.params[p] for p in _CALL_PARAMS
                       if p in eqn.params)
            outs = taint_jaxpr(sub, ins)
        else:
            outs = [union for _ in eqn.outvars]

        for var, t in zip(eqn.outvars, outs):
            from jax._src.core import DropVar
            if not isinstance(var, DropVar):
                env[var] = t
    return [_read(env, v) for v in open_j.outvars]


def _fixpoint(body, make_ins, take_carry, carry: List[Taint],
              max_iters: int = 32) -> List[Taint]:
    """Iterate carry taints through a loop body until stable. Taints
    only grow (sets under union), so this terminates in at most
    |axes| x |carry| rounds; max_iters is a safety valve."""
    for _ in range(max_iters):
        new = [a | b for a, b in
               zip(carry, take_carry(taint_jaxpr(body, make_ins(carry))))]
        if new == carry:
            return carry
        carry = new
    return carry


def _find_shard_map(jaxpr):
    open_j = _as_open(jaxpr)
    for eqn in open_j.eqns:
        if eqn.primitive.name == "shard_map":
            return eqn
        for key in _CALL_PARAMS:
            if key in eqn.params:
                found = _find_shard_map(eqn.params[key])
                if found is not None:
                    return found
    return None


def _names_taint(names) -> Taint:
    out: FrozenSet[str] = frozenset()
    for axes in dict(names).values():
        out |= frozenset(axes)
    return out


def shard_map_out_taints(jaxpr) -> Tuple[List[Taint], List[Taint]]:
    """Locate the shard_map eqn inside a traced jaxpr and run the taint
    analysis over its body. Returns (out_taints, declared_out_taints):
    the inferred per-output taints and what ``out_names`` declares
    (empty set = declared fully replicated)."""
    eqn = _find_shard_map(jaxpr)
    if eqn is None:
        raise ValueError("no shard_map equation found in jaxpr")
    in_taints = [_names_taint(n) for n in eqn.params["in_names"]]
    out_taints = taint_jaxpr(eqn.params["jaxpr"], in_taints)
    declared = [_names_taint(n) for n in eqn.params["out_names"]]
    return out_taints, declared


def check_replication(fam: ProblemFamily,
                      variants: Optional[Tuple[str, ...]] = None,
                      iterations: int = 16
                      ) -> Tuple[List[Diagnostic], List[str]]:
    """Verify, for every registered variant of ``fam``, that each
    output the sharded solve declares replicated is provably
    shard-invariant under the taint rules."""
    from repro.core import api
    diags: List[Diagnostic] = []
    checked: List[str] = []
    axis = fam.default_axes if isinstance(fam.default_axes, str) \
        else fam.default_axes[0]
    mesh = jax.make_mesh((1,), (axis,))
    m, n = bench_shape(fam)
    for variant in variants or family_variants(fam):
        where = f"{fam.name}:{variant}"
        checked.append(where)
        cfg = variant_config(fam, variant, iterations=iterations)
        traced = api.trace_sharded(fam, cfg, mesh, m=m, n=n)
        out_taints, declared = shard_map_out_taints(traced.jaxpr)
        names = [name for name, _ in traced.out_layout]
        if len(out_taints) != len(names):
            raise ValueError(
                f"{where}: traced {len(out_taints)} outputs but layout "
                f"declares {len(names)} — trace_sharded out of sync")
        for name, taint, decl in zip(names, out_taints, declared):
            leaked = taint - decl
            if leaked:
                kind = "replicated" if not decl else \
                    f"sharded only over {sorted(decl)}"
                diags.append(Diagnostic(
                    "replication", "error", where,
                    f"output {name!r} is declared {kind} but its value "
                    f"may vary over mesh axis(es) {sorted(leaked)}: it "
                    f"derives from shard-local data never psum'd over "
                    f"that axis, so shards will silently disagree"))
    return diags, checked
