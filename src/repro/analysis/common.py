"""Shared vocabulary of the static contract analyzer: diagnostics, the
report container, and the family-variant enumeration every pass uses.

Each pass (``collectives``, ``replication``, ``dtypes``, ``lint``)
returns a flat list of :class:`Diagnostic`; ``repro.analysis.check_all``
merges them into one :class:`AnalysisReport`. A diagnostic names its
pass, what it examined (``family:variant`` for the jaxpr passes,
``path:line`` for the repo lint) and the violated contract — so a CI
failure reads as "which invariant broke where", not a stack trace.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

from repro.core.types import ProblemFamily, SolverConfig

SEVERITIES = ("error", "warning", "info")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of one analysis pass.

    check:    the pass ("collectives", "replication", "dtypes", "lint",
              "registry").
    severity: "error" fails the analysis; "warning" is reported but
              non-fatal; "info" carries measurements (e.g. the bytes
              per outer iteration the compressed-collectives work
              needs).
    where:    "family:variant" for solver passes, "path:line" for the
              repo lint.
    message:  the violated contract (or the measurement), human-first.
    """

    check: str
    severity: str
    where: str
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(
                f"severity must be one of {SEVERITIES}, "
                f"got {self.severity!r}")

    def format(self) -> str:
        return f"[{self.check}] {self.severity}: {self.where}: " \
               f"{self.message}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class AnalysisReport:
    """All diagnostics of one analyzer run plus what it covered."""

    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    checked: List[str] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def format(self, verbose: bool = False) -> str:
        lines = []
        for d in self.diagnostics:
            if verbose or d.severity != "info":
                lines.append(d.format())
        lines.append(
            f"{len(self.checked)} subjects checked, "
            f"{len(self.errors)} error(s), "
            f"{sum(d.severity == 'warning' for d in self.diagnostics)} "
            f"warning(s)")
        return "\n".join(lines)

    def to_dict(self) -> dict:
        """JSON-ready report: the ``--json`` CLI payload CI artifacts
        and downstream tools consume instead of scraping the text."""
        return {
            "ok": self.ok,
            "errors": len(self.errors),
            "warnings": sum(d.severity == "warning"
                            for d in self.diagnostics),
            "checked": list(self.checked),
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }


def variant_config(fam: ProblemFamily, variant: str,
                   iterations: int = 16, **overrides) -> SolverConfig:
    """The SolverConfig under which ``fam.solve`` dispatches to the
    named registered variant: SA variants ("sa*", "ca*") get s = 8,
    classical ones s = 1; "accelerated" in the name toggles
    ``cfg.accelerated``. ``iterations`` defaults to a multiple of s so
    the lowering has no remainder tail group (the one-collective-per-
    outer budget is then exactly one in-loop all-reduce); pass an
    indivisible H to analyze the tail path too.

    ``track_objective`` is off — objective tracking legitimately adds
    one reduction per inner iteration in the row-partitioned families
    (a diagnostic, outside the paper's Table I contract), exactly as
    the dynamic ``benchmarks/collective_count.py`` rows measure it.
    """
    if variant not in fam.variants:
        raise ValueError(
            f"unknown variant {variant!r} for family {fam.name!r}; "
            f"registered: {sorted(fam.variants)}")
    kw = dict(
        block_size=fam.bench_block_size,
        s=8 if variant.startswith(("sa", "ca")) else 1,
        accelerated="accelerated" in variant,
        iterations=iterations,
        track_objective=False,
    )
    kw.update(overrides)
    return SolverConfig(**kw)


def family_variants(fam: ProblemFamily) -> Tuple[str, ...]:
    """The family's registered variant names, sorted — the enumeration
    axis of every solver pass (a new variant is analyzed with zero
    analyzer edits, exactly like a new family)."""
    return tuple(sorted(fam.variants))


def bench_shape(fam: ProblemFamily) -> Tuple[int, int]:
    """A small representative (m, n) per partition layout — row-
    partitioned families shard data points, column-partitioned ones
    shard features (mirrors benchmarks/collective_count.py)."""
    return (64, 32) if fam.partition == "row" else (32, 64)
