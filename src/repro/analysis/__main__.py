"""CLI: ``python -m repro.analysis [--checks ...] [--families ...]``.

Runs the static contract analyzer and exits 1 if any pass reports an
error — the CI "Static analysis" job is exactly this invocation.
``-v`` additionally prints the info diagnostics (per-variant all-reduce
payload bytes, certified cost ratios, derived kernel VMEM footprints);
``--json`` emits the full machine-readable report instead of text;
``--variants`` restricts the per-family solver passes to the named
variants (``--family`` is an alias of ``--families``).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import CHECKS, check_all


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract analysis of the SA solvers.")
    parser.add_argument("--checks", nargs="+", choices=CHECKS,
                        default=None, metavar="CHECK",
                        help=f"subset of passes to run (default: all of "
                             f"{', '.join(CHECKS)})")
    parser.add_argument("--families", "--family", nargs="+", default=None,
                        metavar="FAMILY", dest="families",
                        help="subset of registered families (default: all)")
    parser.add_argument("--variants", "--variant", nargs="+", default=None,
                        metavar="VARIANT", dest="variants",
                        help="subset of registered variants for the "
                             "per-family passes (default: all)")
    parser.add_argument("--json", action="store_true",
                        help="emit the machine-readable report (always "
                             "includes info diagnostics)")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="also print info diagnostics (payload bytes, "
                             "cost ratios, VMEM footprints)")
    args = parser.parse_args(argv)

    report = check_all(checks=args.checks, families=args.families,
                       variants=args.variants)
    if args.json:
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        print(report.format(verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
