"""CLI: ``python -m repro.analysis [--checks ...] [--families ...]``.

Runs the static contract analyzer and exits 1 if any pass reports an
error — the CI "Static analysis" job is exactly this invocation.
``-v`` additionally prints the info diagnostics (the per-variant
all-reduce payload bytes).
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import CHECKS, check_all


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static contract analysis of the SA solvers.")
    parser.add_argument("--checks", nargs="+", choices=CHECKS,
                        default=None, metavar="CHECK",
                        help=f"subset of passes to run (default: all of "
                             f"{', '.join(CHECKS)})")
    parser.add_argument("--families", nargs="+", default=None,
                        metavar="FAMILY",
                        help="subset of registered families (default: all)")
    parser.add_argument("-v", "--verbose", action="store_true",
                        help="also print info diagnostics (payload bytes)")
    args = parser.parse_args(argv)

    report = check_all(checks=args.checks, families=args.families)
    print(report.format(verbose=args.verbose))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
