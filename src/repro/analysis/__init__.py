"""repro.analysis — static contract analyzer for the SA solvers.

Seven passes; the solver passes enumerate the ``FAMILIES`` registry (so
new families and variants are covered with zero analyzer edits):

  * ``collectives``  — exactly ONE all-reduce per outer iteration,
    nothing else, with payload bytes reported (``collectives.py``);
  * ``replication``  — every output the sharded solve declares
    replicated is provably shard-invariant (taint analysis,
    ``replication.py``);
  * ``dtypes``       — no silent f64 -> f32 narrowing in an f64 trace
    (``dtypes.py``);
  * ``costs``        — the family's Table I cost model certified
    against flops/bytes/messages COUNTED in the traced jaxpr, dense
    and SparseOperand, across an s-grid (``costs.py``);
  * ``kernels``      — Pallas kernel safety: VMEM guard drift, output
    index-map injectivity (write races), index-map/gather bounds
    (``kernels.py``);
  * ``lint``         — AST repo lint (raw collectives, ambient RNG,
    bare asserts) plus the registry carry/state-layout contract
    (``lint.py``).

Entry points: :func:`check_all` in-process, ``python -m repro.analysis``
on the command line (``--json`` for machine-readable reports),
``tools/sa_lint.py`` for the lint rules alone, and the pytest tier
``-m analysis``.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.collectives import (COLLECTIVE_PRIMS, CollectiveBudget,
                                        budget_rows, check_collectives,
                                        collective_budget,
                                        solver_collective_budget)
from repro.analysis.common import (AnalysisReport, Diagnostic, SEVERITIES,
                                   family_variants, variant_config)
from repro.analysis.costs import (CostCount, CostRow, CostTolerance,
                                  certification_operand, check_costs,
                                  cost_count, cost_ratio_rows,
                                  cost_tolerance, solver_cost_count)
from repro.analysis.dtypes import check_dtypes, find_float_narrowing
from repro.analysis.kernels import (KernelCapture, SpecView,
                                    capture_footprint, capture_pallas_calls,
                                    check_kernels, guard_drift_diags,
                                    index_map_bounds_diags,
                                    output_injectivity_diags)
from repro.analysis.lint import check_registry, lint_paths, lint_source
from repro.analysis.replication import (check_replication,
                                        shard_map_out_taints, taint_jaxpr)

CHECKS = ("collectives", "replication", "dtypes", "costs", "kernels",
          "lint", "registry")

__all__ = [
    "AnalysisReport", "CHECKS", "COLLECTIVE_PRIMS", "CollectiveBudget",
    "CostCount", "CostRow", "CostTolerance", "Diagnostic",
    "KernelCapture", "SEVERITIES", "SpecView", "budget_rows",
    "capture_footprint", "capture_pallas_calls", "certification_operand",
    "check_all", "check_collectives", "check_costs", "check_dtypes",
    "check_kernels", "check_registry", "check_replication",
    "collective_budget", "cost_count", "cost_ratio_rows",
    "cost_tolerance", "family_variants", "find_float_narrowing",
    "guard_drift_diags", "index_map_bounds_diags", "lint_paths",
    "lint_source", "output_injectivity_diags", "shard_map_out_taints",
    "solver_collective_budget", "solver_cost_count", "taint_jaxpr",
    "variant_config",
]


def check_all(checks: Optional[Sequence[str]] = None,
              families: Optional[Sequence[str]] = None,
              variants: Optional[Sequence[str]] = None) -> AnalysisReport:
    """Run the selected passes (default: all) over the selected
    registered families (default: all) and merge the findings.

    ``variants`` filters the per-family solver passes to the named
    variants (each family keeps only the names it registers; a name no
    selected family registers is an error). The registry-wide passes
    (``lint``, ``registry``, ``kernels``) ignore the filter.
    """
    from repro.core.types import FAMILIES
    checks = tuple(checks or CHECKS)
    unknown = set(checks) - set(CHECKS)
    if unknown:
        raise ValueError(f"unknown checks {sorted(unknown)}; "
                         f"available: {CHECKS}")
    fams = []
    for name in families or sorted(FAMILIES):
        if name not in FAMILIES:
            raise ValueError(f"unknown family {name!r}; registered: "
                             f"{sorted(FAMILIES)}")
        fams.append(FAMILIES[name])
    if variants is not None:
        registered = {v for fam in fams for v in fam.variants}
        missing = set(variants) - registered
        if missing:
            raise ValueError(
                f"variant(s) {sorted(missing)} registered by no "
                f"selected family; available: {sorted(registered)}")

    report = AnalysisReport()
    per_family = {"collectives": check_collectives,
                  "replication": check_replication,
                  "dtypes": check_dtypes,
                  "costs": check_costs}
    for check in checks:
        if check in per_family:
            for fam in fams:
                sel = None
                if variants is not None:
                    sel = tuple(v for v in family_variants(fam)
                                if v in variants)
                    if not sel:
                        continue
                diags, checked = per_family[check](fam, variants=sel)
                report.extend(diags)
                report.checked.extend(f"{check}:{c}" for c in checked)
        elif check == "kernels":
            diags, checked = check_kernels()
            report.extend(diags)
            report.checked.extend(f"kernels:{c}" for c in checked)
        elif check == "lint":
            diags, checked = lint_paths()
            report.extend(diags)
            report.checked.extend(f"lint:{c}" for c in checked)
        elif check == "registry":
            diags, checked = check_registry()
            report.extend(diags)
            report.checked.extend(f"registry:{c}" for c in checked)
    return report
