"""repro.analysis — static contract analyzer for the SA solvers.

Four passes, each enumerating the ``FAMILIES`` registry (so new
families and variants are covered with zero analyzer edits):

  * ``collectives``  — exactly ONE all-reduce per outer iteration,
    nothing else, with payload bytes reported (``collectives.py``);
  * ``replication``  — every output the sharded solve declares
    replicated is provably shard-invariant (taint analysis,
    ``replication.py``);
  * ``dtypes``       — no silent f64 -> f32 narrowing in an f64 trace
    (``dtypes.py``);
  * ``lint``         — AST repo lint (raw collectives, ambient RNG,
    bare asserts) plus the registry carry/state-layout contract
    (``lint.py``).

Entry points: :func:`check_all` in-process, ``python -m repro.analysis``
on the command line, ``tools/sa_lint.py`` for the lint rules alone, and
the pytest tier ``-m analysis``.
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.collectives import (COLLECTIVE_PRIMS, CollectiveBudget,
                                        check_collectives, collective_budget,
                                        solver_collective_budget)
from repro.analysis.common import (AnalysisReport, Diagnostic, SEVERITIES,
                                   family_variants, variant_config)
from repro.analysis.dtypes import check_dtypes, find_float_narrowing
from repro.analysis.lint import check_registry, lint_paths, lint_source
from repro.analysis.replication import (check_replication,
                                        shard_map_out_taints, taint_jaxpr)

CHECKS = ("collectives", "replication", "dtypes", "lint", "registry")

__all__ = [
    "AnalysisReport", "CHECKS", "COLLECTIVE_PRIMS", "CollectiveBudget",
    "Diagnostic", "SEVERITIES", "check_all", "check_collectives",
    "check_dtypes", "check_registry", "check_replication",
    "collective_budget", "family_variants", "find_float_narrowing",
    "lint_paths", "lint_source", "shard_map_out_taints",
    "solver_collective_budget", "taint_jaxpr", "variant_config",
]


def check_all(checks: Optional[Sequence[str]] = None,
              families: Optional[Sequence[str]] = None) -> AnalysisReport:
    """Run the selected passes (default: all) over the selected
    registered families (default: all) and merge the findings."""
    from repro.core.types import FAMILIES
    checks = tuple(checks or CHECKS)
    unknown = set(checks) - set(CHECKS)
    if unknown:
        raise ValueError(f"unknown checks {sorted(unknown)}; "
                         f"available: {CHECKS}")
    fams = []
    for name in families or sorted(FAMILIES):
        if name not in FAMILIES:
            raise ValueError(f"unknown family {name!r}; registered: "
                             f"{sorted(FAMILIES)}")
        fams.append(FAMILIES[name])

    report = AnalysisReport()
    per_family = {"collectives": check_collectives,
                  "replication": check_replication,
                  "dtypes": check_dtypes}
    for check in checks:
        if check in per_family:
            for fam in fams:
                diags, checked = per_family[check](fam)
                report.extend(diags)
                report.checked.extend(f"{check}:{c}" for c in checked)
        elif check == "lint":
            diags, checked = lint_paths()
            report.extend(diags)
            report.checked.extend(f"lint:{c}" for c in checked)
        elif check == "registry":
            diags, checked = check_registry()
            report.extend(diags)
            report.checked.extend(f"registry:{c}" for c in checked)
    return report
