"""Static collective-budget verification.

The paper's structural invariant — every outer iteration of an SA
solver issues exactly ONE fused Allreduce of the (s·mu)² Gram /
(m, s·mu) cross block and nothing else (Table I; the same contract in
the primal/dual BCD precursor arXiv:1612.04003 and the CA-proximal line
arXiv:1710.08883) — was only checked dynamically, by the 8-device
subprocess rows of ``benchmarks/collective_count.py``. This pass checks
it at lowering time, in-process, for every registered family×variant:

  * trace the FULL sharded solve (``repro.core.api.trace_sharded`` —
    the same shard_map program ``solve_sharded`` runs, state leaves
    included) on a 1-device mesh: the jaxpr carries every collective
    primitive symbolically, regardless of how many devices this host
    exposes;
  * walk the jaxpr recursively and split collective eqns into
    ``per_iteration`` (inside a scan/while body — issued once per outer
    iteration) and ``amortized`` (outside every loop — setup work and
    the remainder tail group, issued once per solve);
  * assert the budget: exactly one in-loop all-reduce, zero in-loop
    all-gather / all-to-all / reduce-scatter / collective-permute, and
    no amortized collectives beyond the remainder tail's own single
    all-reduce.

Bytes ride along: each all-reduce's payload size falls out of the eqn
output avals, giving the bytes-per-outer-iteration column the
compressed-collectives roadmap item needs — without compiling anything.
When >= 2 devices are available the pass can additionally cross-check
the compiled post-SPMD HLO text through the hardened
``repro.roofline.analysis.collective_stats_from_hlo`` parser.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.analysis.common import (Diagnostic, bench_shape, family_variants,
                                   variant_config)
from repro.core.types import ProblemFamily, SolverConfig

# jaxpr collective primitive -> the HLO-side op name the roofline parser
# and the benchmarks report (one shared vocabulary).
COLLECTIVE_PRIMS = {
    "psum": "all-reduce",
    "all_gather": "all-gather",
    "reduce_scatter": "reduce-scatter",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
}

_LOOP_PRIMS = ("scan", "while")


@dataclasses.dataclass(frozen=True)
class CollectiveBudget:
    """Counts and all-reduce payload bytes of one traced solve, split by
    where the op sits in the loop structure.

    per_iteration: collectives inside a scan/while body — issued once
        per outer iteration (the budgeted hot path).
    amortized: collectives outside every loop — setup (e.g. a warm
        start's margin rebuild) plus the remainder tail group, issued
        once per solve.
    per_iteration_bytes / amortized_bytes: summed result bytes of the
        corresponding all-reduces (the fused payload the compressed-
        collectives work quantizes).
    """

    per_iteration: Dict[str, int]
    amortized: Dict[str, int]
    per_iteration_bytes: float
    amortized_bytes: float

    @property
    def total(self) -> Dict[str, int]:
        return {k: self.per_iteration[k] + self.amortized[k]
                for k in COLLECTIVE_PRIMS.values()}


def _subjaxprs(eqn):
    """Every sub-jaxpr stashed in an eqn's params (scan/while/cond/
    pjit/custom_* all keep theirs under different keys — scan the values
    so an unanticipated higher-order primitive is still walked)."""
    from jax._src import core as jcore
    for val in eqn.params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jcore.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jcore.Jaxpr):
                yield v


def _aval_bytes(var) -> float:
    aval = var.aval
    return float(np.prod(aval.shape, dtype=np.int64) if aval.shape else 1) \
        * np.dtype(aval.dtype).itemsize


def collective_budget(closed_jaxpr) -> CollectiveBudget:
    """Walk a (Closed)Jaxpr recursively and classify every collective
    primitive as per-iteration (inside any scan/while body) or
    amortized (outside all loops)."""
    per = {k: 0 for k in COLLECTIVE_PRIMS.values()}
    amo = {k: 0 for k in COLLECTIVE_PRIMS.values()}
    nbytes = {"per": 0.0, "amo": 0.0}

    def walk(jaxpr, in_loop: bool) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name in COLLECTIVE_PRIMS:
                op = COLLECTIVE_PRIMS[name]
                bucket = per if in_loop else amo
                bucket[op] += 1
                if op == "all-reduce":
                    nbytes["per" if in_loop else "amo"] += sum(
                        _aval_bytes(v) for v in eqn.outvars)
            inner_loop = in_loop or name in _LOOP_PRIMS
            for sub in _subjaxprs(eqn):
                walk(sub, inner_loop)

    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    walk(jaxpr, in_loop=False)
    return CollectiveBudget(per_iteration=per, amortized=amo,
                            per_iteration_bytes=nbytes["per"],
                            amortized_bytes=nbytes["amo"])


def solver_collective_budget(fam: ProblemFamily, cfg: SolverConfig,
                             mesh=None, m: Optional[int] = None,
                             n: Optional[int] = None,
                             dtype=None) -> CollectiveBudget:
    """The collective budget of one family×config sharded solve. A
    1-device mesh (the default) is enough — the jaxpr is structurally
    identical for any axis size."""
    from repro.core import api
    import jax.numpy as jnp
    if mesh is None:
        axis = fam.default_axes if isinstance(fam.default_axes, str) \
            else fam.default_axes[0]
        mesh = jax.make_mesh((1,), (axis,))
    bm, bn = bench_shape(fam)
    traced = api.trace_sharded(fam, cfg, mesh, m=m or bm, n=n or bn,
                               dtype=dtype or jnp.float32)
    return collective_budget(traced.jaxpr)


def check_collectives(fam: ProblemFamily,
                      variants: Optional[Tuple[str, ...]] = None,
                      mesh=None, iterations: int = 16
                      ) -> Tuple[List[Diagnostic], List[str]]:
    """Assert the per-outer-iteration collective budget for every
    registered variant of ``fam``: exactly ONE in-loop all-reduce,
    nothing else in-loop, and no amortized collectives (H is chosen
    divisible by s so there is no tail group; with a remainder the tail
    contributes exactly one more amortized all-reduce, which
    :func:`collective_budget` callers can allow explicitly).

    Returns (diagnostics, checked-subject names). Per-variant payload
    bytes are reported as "info" diagnostics either way.
    """
    diags: List[Diagnostic] = []
    checked: List[str] = []
    for variant in variants or family_variants(fam):
        where = f"{fam.name}:{variant}"
        checked.append(where)
        cfg = variant_config(fam, variant, iterations=iterations)
        budget = solver_collective_budget(fam, cfg, mesh=mesh)
        outer = cfg.outer_iterations
        ar = budget.per_iteration["all-reduce"]
        if ar != 1:
            diags.append(Diagnostic(
                "collectives", "error", where,
                f"expected exactly ONE all-reduce per outer iteration, "
                f"found {ar} inside the outer loop body (s={cfg.s}, "
                f"mu={cfg.block_size}) — the SA contract (Table I) is "
                f"one fused Gram/cross Allreduce and nothing else"))
        for op, count in budget.per_iteration.items():
            if op != "all-reduce" and count:
                diags.append(Diagnostic(
                    "collectives", "error", where,
                    f"{count} in-loop {op} op(s): the SA solvers must "
                    f"not {op} — every exchanged value rides the one "
                    f"fused all-reduce"))
        extra_amortized = dict(budget.amortized)
        if sum(extra_amortized.values()):
            ops = {k: v for k, v in extra_amortized.items() if v}
            diags.append(Diagnostic(
                "collectives", "error", where,
                f"amortized (outside-loop) collectives {ops} with no "
                f"remainder tail (H={cfg.iterations} divisible by "
                f"s={cfg.s}): setup must not communicate for a "
                f"zero-initialized solve"))
        diags.append(Diagnostic(
            "collectives", "info", where,
            f"all-reduce payload {budget.per_iteration_bytes:.0f} B per "
            f"outer iteration x {outer} outer iterations "
            f"(runtime messages = {outer})"))
    return diags, checked


def compiled_collective_stats(fam: ProblemFamily, cfg: SolverConfig,
                              mesh, m: Optional[int] = None,
                              n: Optional[int] = None):
    """Cross-check: the compiled post-SPMD HLO of the same lowering,
    parsed with the hardened roofline parser. Needs a REAL multi-device
    mesh (XLA removes single-participant collectives during
    compilation); returns a
    :class:`repro.roofline.analysis.CollectiveStats` whose static
    all-reduce count is 1 per distinct group trace (scan bodies count
    once)."""
    from repro.core import api
    from repro.roofline.analysis import collective_stats_from_hlo
    bm, bn = bench_shape(fam)
    txt = api.lower_solve(fam, cfg, mesh, m=m or bm * 8, n=n or bn * 8
                          ).compile().as_text()
    return collective_stats_from_hlo(txt)


@dataclasses.dataclass(frozen=True)
class BudgetRow:
    """One (family, s) row of the assembled collective-budget report —
    the shared shape both ``benchmarks/collective_count.py`` and the
    certification smoke emit, so the derived columns (runtime messages,
    payload bytes) are computed in exactly one place."""

    family: str
    s: int
    iterations: int
    budget: CollectiveBudget

    @property
    def allreduces_in_loop(self) -> int:
        return self.budget.per_iteration["all-reduce"]

    @property
    def other_collectives(self) -> int:
        return sum(v for k, v in self.budget.total.items()
                   if k != "all-reduce")

    @property
    def trips(self) -> int:
        return -(-self.iterations // self.s)

    @property
    def runtime_messages(self) -> int:
        return self.allreduces_in_loop * self.trips

    @property
    def bytes_per_outer(self) -> float:
        return self.budget.per_iteration_bytes


# the report shapes: large enough that the payload-bytes column is
# representative, small enough to trace the whole registry in seconds.
BUDGET_SHAPES = {"row": (512, 128), "col": (256, 512)}


def budget_rows(families: Optional[Tuple[str, ...]] = None,
                s_values: Tuple[int, ...] = (1, 4, 16),
                iterations: int = 64,
                shapes: Optional[Dict[str, Tuple[int, int]]] = None
                ) -> Dict[Tuple[str, int], BudgetRow]:
    """Assemble the per-(family, s) collective-budget rows every
    reporting surface shares: trace each registered family's default
    solve at each s and wrap the budget in a :class:`BudgetRow`."""
    from repro.core.types import FAMILIES
    shapes = shapes or BUDGET_SHAPES
    rows: Dict[Tuple[str, int], BudgetRow] = {}
    for name in sorted(families or FAMILIES):
        fam = FAMILIES[name]
        m, n = shapes[fam.partition]
        for s in s_values:
            cfg = SolverConfig(block_size=fam.bench_block_size,
                               iterations=iterations, s=s,
                               track_objective=False)
            rows[(name, s)] = BudgetRow(
                family=name, s=s, iterations=iterations,
                budget=solver_collective_budget(fam, cfg, m=m, n=n))
    return rows
