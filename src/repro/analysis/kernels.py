"""Static Pallas kernel safety pass.

The solver kernels under ``repro.kernels`` encode three hand-maintained
contracts that nothing cross-checked until now:

  * **VMEM guards** — ``dispatch.kernel_vmem_model()`` models each
    package's resident working set; dispatch admits a configuration
    when the model fits the budget. If the model under-counts what the
    kernel actually holds resident (the exact bug class the f64
    dtype-blind guards had before PR 5), near-cap configurations
    dispatch Pallas and die — or silently spill — on hardware this CI
    never sees. This pass derives the TRUE footprint from each
    package's BlockSpecs, operand shapes and scratch allocations (by
    capturing the ``pallas_call`` invocation under ``jax.eval_shape``
    — no TPU, no compilation) and flags any model that claims less
    than the derived footprint (guard drift).
  * **Output index-map injectivity** — two grid steps mapping to the
    same output block is only legal across grid dimensions declared
    "arbitrary" (sequential — the revisit is the accumulation pattern);
    a revisit across "parallel" dimensions is a write race that
    produces nondeterministic output on real grids.
  * **Index-map / gather bounds** — every BlockSpec index map must land
    inside the operand's block grid for every grid point, and the
    blocked-ELL SpMM's scalar-prefetch gather indices must address
    inside the VMEM-resident dense operand (checked on a concrete
    representative operand, padded slots included).

Capture is by monkeypatching ``pallas_call`` on the shared
``jax.experimental.pallas`` module for the duration of one traced
invocation: the fake records grid/specs/shapes and returns zeros of the
declared out_shape, so the wrapper code around the kernel runs
unmodified and the recorded specs are EXACTLY what the real call would
launch. Every package named in ``repro.kernels.KERNEL_PACKAGES`` must
have a describer here — a new package without one is itself an error
(coverage check), so kernels cannot bypass the safety pass by
omission.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.common import Diagnostic

_SLACK = 1.25   # admissible derived/modeled overshoot: the O(smaller-
                # operand) residents the models deliberately fold into
                # the budget's 2x headroom.


@dataclasses.dataclass(frozen=True)
class SpecView:
    """One operand's residency view: its full (padded) shape/dtype and,
    when blocked, the BlockSpec's block shape and index map (None block
    shape = the whole operand is VMEM-resident)."""

    label: str
    shape: Tuple[int, ...]
    dtype: Any
    block_shape: Optional[Tuple[int, ...]] = None
    index_map: Optional[Callable] = None


@dataclasses.dataclass(frozen=True)
class KernelCapture:
    """Everything one recorded ``pallas_call`` declares: the grid, the
    per-operand views, scratch allocations, dimension semantics and the
    scalar-prefetch operands (concrete arrays when the capture ran on
    real inputs — the gather bounds check reads them)."""

    name: str
    grid: Tuple[int, ...]
    inputs: Tuple[SpecView, ...]
    outputs: Tuple[SpecView, ...]
    scratch: Tuple[Tuple[Tuple[int, ...], Any], ...]
    semantics: Optional[Tuple[str, ...]] = None
    scalar_args: Tuple[Any, ...] = ()

    def dim_semantics(self, dim: int) -> str:
        if self.semantics is None or dim >= len(self.semantics):
            return "arbitrary"    # TPU default: sequential grid dims
        return self.semantics[dim]


def _as_tuple(x):
    if x is None:
        return ()
    return tuple(x) if isinstance(x, (list, tuple)) else (x,)


def _spec_views(specs, args, prefix: str) -> Tuple[SpecView, ...]:
    views = []
    specs = _as_tuple(specs) if specs is not None else (None,) * len(args)
    for i, (spec, arg) in enumerate(zip(specs, args)):
        block = getattr(spec, "block_shape", None) if spec is not None \
            else None
        imap = getattr(spec, "index_map", None) if spec is not None \
            else None
        views.append(SpecView(
            label=f"{prefix}{i}", shape=tuple(jnp.shape(arg)),
            dtype=getattr(arg, "dtype", jnp.float32),
            block_shape=tuple(block) if block is not None else None,
            index_map=imap))
    return tuple(views)


def capture_pallas_calls(fn: Callable, *args) -> List[KernelCapture]:
    """Trace ``fn(*args)`` with ``pallas_call`` replaced by a recorder
    that returns zeros of the declared out_shape. Shape-only arguments
    (``jax.ShapeDtypeStruct``) are fine — the trace runs under
    ``jax.eval_shape`` so nothing is materialized or compiled."""
    import jax.experimental.pallas as pl_mod
    records: List[KernelCapture] = []
    real = pl_mod.pallas_call

    def fake(kernel, out_shape=None, *, grid_spec=None, grid=(),
             in_specs=None, out_specs=None, scratch_shapes=(),
             compiler_params=None, **kw):
        nsp = 0
        if grid_spec is not None:
            grid = tuple(grid_spec.grid)
            in_specs = grid_spec.in_specs
            out_specs = grid_spec.out_specs
            scratch_shapes = getattr(grid_spec, "scratch_shapes",
                                     scratch_shapes)
            nsp = getattr(grid_spec, "num_scalar_prefetch", 0)
        grid = tuple(grid)
        sem = None
        if compiler_params is not None:
            sem = getattr(compiler_params, "dimension_semantics", None)
            if sem is None and isinstance(compiler_params, dict):
                sem = compiler_params.get("dimension_semantics")
            sem = tuple(sem) if sem is not None else None
        out_leaves = jax.tree_util.tree_leaves(out_shape)
        scratch = tuple(
            (tuple(s.shape), getattr(s, "dtype", jnp.float32))
            for s in _as_tuple(scratch_shapes))

        def run(*call_args):
            scalars, blocked = call_args[:nsp], call_args[nsp:]
            out_views = _spec_views(
                out_specs, out_leaves, "out") if out_leaves else ()
            records.append(KernelCapture(
                name=getattr(kernel, "__name__", "kernel"), grid=grid,
                inputs=_spec_views(in_specs, blocked, "in"),
                outputs=out_views, scratch=scratch, semantics=sem,
                scalar_args=tuple(
                    None if isinstance(a, jax.core.Tracer) else a
                    for a in scalars)))
            return jax.tree_util.tree_map(
                lambda sds: jnp.zeros(sds.shape, sds.dtype), out_shape)

        return run

    pl_mod.pallas_call = fake
    try:
        jax.eval_shape(fn, *args)
    finally:
        pl_mod.pallas_call = real
    return records


def capture_footprint(capture: KernelCapture) -> float:
    """The VMEM bytes a captured call holds resident: full operands for
    spec-less calls, block tiles (double-buffered — the Pallas pipeline
    prefetches the next tile while computing the current one) for
    blocked ones, a single buffer for operands whose block IS the full
    shape (resident, constant index map — nothing to prefetch), plus
    scratch. Scalar-prefetch operands live in SMEM and are excluded."""
    total = 0.0
    for view in capture.inputs + capture.outputs:
        block = view.block_shape or view.shape
        buffers = 2 if (view.block_shape is not None
                        and view.block_shape != view.shape
                        and capture.grid) else 1
        total += buffers * float(np.prod(block, dtype=np.int64)) \
            * jnp.dtype(view.dtype).itemsize
    for shape, dtype in capture.scratch:
        total += float(np.prod(shape, dtype=np.int64)) \
            * jnp.dtype(dtype).itemsize
    return total


def guard_drift_diags(where: str, modeled_bytes: float,
                      derived_bytes: float, cap: float,
                      slack: float = _SLACK) -> List[Diagnostic]:
    """The drift detector: the hand-maintained VMEM model must claim at
    least the BlockSpec-derived footprint (within ``slack`` for the
    small residents the models fold into the budget's headroom). A
    model claiming LESS admits configurations whose true working set
    exceeds the cap — the f64 2x-VMEM dispatch bug class."""
    if modeled_bytes * slack >= derived_bytes:
        return []
    return [Diagnostic(
        "kernels", "error", where,
        f"VMEM guard drift: kernel_vmem_model claims "
        f"{modeled_bytes:.0f} B resident but the BlockSpec-derived "
        f"footprint is {derived_bytes:.0f} B "
        f"({derived_bytes / max(modeled_bytes, 1.0):.2f}x, over the "
        f"{slack:g}x slack) — the dispatch guard would admit "
        f"configurations exceeding the {cap:.0f} B cap")]


def _grid_points(grid: Sequence[int]):
    if not grid:
        return
    idx = [0] * len(grid)
    while True:
        yield tuple(idx)
        for d in reversed(range(len(grid))):
            idx[d] += 1
            if idx[d] < grid[d]:
                break
            idx[d] = 0
        else:
            return


def output_injectivity_diags(where: str, capture: KernelCapture
                             ) -> List[Diagnostic]:
    """Write-race check: an output block visited by two grid points is
    only legal when every grid dimension the points differ in is
    declared "arbitrary" (sequential revisits accumulate in order); a
    revisit across a "parallel" dimension races."""
    diags: List[Diagnostic] = []
    for view in capture.outputs:
        if view.index_map is None or not capture.grid:
            continue
        seen: Dict[Tuple, Tuple] = {}
        flagged = False
        for point in _grid_points(capture.grid):
            block = tuple(view.index_map(*point))
            prev = seen.setdefault(block, point)
            if prev is point or flagged:
                continue
            racing = [d for d in range(len(capture.grid))
                      if prev[d] != point[d]
                      and capture.dim_semantics(d) == "parallel"]
            if racing:
                flagged = True
                diags.append(Diagnostic(
                    "kernels", "error", where,
                    f"write race on {view.label}: grid points {prev} "
                    f"and {point} both map to output block {block} but "
                    f"differ in \"parallel\" grid dimension(s) "
                    f"{racing} — revisits must be confined to "
                    f"\"arbitrary\" (sequential) dimensions, where "
                    f"they are the accumulation pattern"))
        if flagged:
            continue
    return diags


def index_map_bounds_diags(where: str, capture: KernelCapture
                           ) -> List[Diagnostic]:
    """Every BlockSpec index map must land inside the operand's block
    grid — ceil(dim/block) blocks per dimension — for EVERY grid point
    (padded shapes included: the wrappers pad before calling)."""
    diags: List[Diagnostic] = []
    for view in capture.inputs + capture.outputs:
        if view.index_map is None or view.block_shape is None \
                or not capture.grid:
            continue
        nblocks = [-(-dim // blk) for dim, blk
                   in zip(view.shape, view.block_shape)]
        for point in _grid_points(capture.grid):
            block = tuple(view.index_map(*point))
            oob = [d for d, (b, nb) in enumerate(zip(block, nblocks))
                   if not 0 <= b < nb]
            if oob:
                diags.append(Diagnostic(
                    "kernels", "error", where,
                    f"index map out of bounds on {view.label}: grid "
                    f"point {point} maps to block {block} but the "
                    f"operand shape {view.shape} at block "
                    f"{view.block_shape} only has {nblocks} blocks "
                    f"per dimension"))
                break
    return diags


# ---------------------------------------------------------------------------
# Per-package describers: representative invocations + the model kwargs
# the captured configuration corresponds to in kernel_vmem_model().
# ---------------------------------------------------------------------------

def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _describe_gram():
    from repro.kernels.gram.kernel import gram_t_pallas
    bm, bi, bj = 256, 128, 128
    caps = capture_pallas_calls(
        lambda x, y: gram_t_pallas(x, y, block_m=bm, block_i=bi,
                                   block_j=bj),
        _sds((512, 256)), _sds((512, 384)))
    return [("gram", dict(block_m=bm, block_i=bi, block_j=bj,
                          itemsize=4), caps)]


def _describe_spmm():
    from repro.core.types import SparseOperand
    from repro.kernels.spmm.kernel import ell_spmm_pallas
    # a concrete representative blocked-ELL operand (deterministic
    # banded pattern) so the scalar-prefetch gather indices are REAL
    # padded data, not just shapes.
    C, Q, R = 32, 128, 8
    dense = np.zeros((R, C), np.float32)
    for i in range(R):
        for j in range(1 + i % 3):
            dense[i, (3 * i + 5 * j) % C] = 1.0 + j
    op = SparseOperand.from_dense(dense, with_bcoo=False)
    vals, idx, blocks = (np.asarray(op.row_vals), np.asarray(op.row_cols),
                         np.asarray(op.row_blocks))
    small = capture_pallas_calls(
        lambda: ell_spmm_pallas(jnp.asarray(vals), jnp.asarray(idx),
                                jnp.asarray(blocks),
                                jnp.zeros((C, Q), jnp.float32),
                                ell_block=op.ell_block))
    Rl, Kl, Cl, Ql = 512, 64, 2048, 128
    large = capture_pallas_calls(
        lambda v, i, b, d: ell_spmm_pallas(v, i, b, d, ell_block=8),
        _sds((Rl, Kl)), _sds((Rl, Kl), jnp.int32),
        _sds((Rl,), jnp.int32), _sds((Cl, Ql)))
    return [
        ("spmm", dict(R=R, K=idx.shape[1], C=C, Q=Q, itemsize=4), small),
        ("spmm[large]", dict(R=Rl, K=Kl, C=Cl, Q=Ql, itemsize=4), large),
    ]


def _inner_shapes(s, mu, n_mats):
    smu = s * mu
    return [_sds((smu, smu))] + [_sds((s, mu))] * n_mats \
        + [_sds((s, mu), jnp.int32)]


def _describe_sa_inner():
    from repro.kernels.sa_inner.kernel import sa_inner_pallas
    out = []
    for s, mu in ((64, 8), (181, 8)):   # large + guard-boundary smu
        G, yp, zp, zv, idx = _inner_shapes(s, mu, 3)
        caps = capture_pallas_calls(
            lambda *a: sa_inner_pallas(*a, q=1.5, lam1=0.1),
            G, yp, zp, zv, idx, _sds((s,)), _sds((s,)))
        out.append((f"sa_inner[s={s},mu={mu}]",
                    dict(s=s, mu=mu, itemsize=4), caps))
    return out


def _describe_svm_inner():
    from repro.kernels.svm_inner.kernel import svm_inner_pallas
    out = []
    for s, mu in ((64, 8), (181, 8)):
        G, proj, b_sel, a_vals, idx = _inner_shapes(s, mu, 3)
        caps = capture_pallas_calls(
            lambda *a: svm_inner_pallas(*a, gamma=1e-3, nu=1.0),
            G, proj, b_sel, a_vals, idx)
        out.append((f"svm_inner[s={s},mu={mu}]",
                    dict(s=s, mu=mu, itemsize=4), caps))
    return out


def _describe_flash_attention():
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    caps = capture_pallas_calls(
        lambda q, k, v: flash_attention_pallas(q, k, v, causal=True),
        _sds((1, 2, 256, 128)), _sds((1, 1, 256, 128)),
        _sds((1, 1, 256, 128)))
    return [("flash_attention", dict(block_q=128, block_k=128,
                                     head_dim=128, itemsize=4), caps)]


_DESCRIBERS: Dict[str, Callable[[], List[Tuple[str, Dict, List]]]] = {
    "gram": _describe_gram,
    "spmm": _describe_spmm,
    "sa_inner": _describe_sa_inner,
    "svm_inner": _describe_svm_inner,
    "flash_attention": _describe_flash_attention,
}


def _gather_bounds_diags(where: str, capture: KernelCapture
                         ) -> List[Diagnostic]:
    """spmm scalar-prefetch gather bounds: every (padded) flat ELL
    index must address a row of the VMEM-resident dense operand —
    checked on the concrete representative operand's data."""
    diags: List[Diagnostic] = []
    idx = capture.scalar_args[0] if capture.scalar_args else None
    if idx is None:
        return diags
    # the dense right operand is the resident input (block == shape).
    dense = [v for v in capture.inputs
             if v.block_shape == v.shape and len(v.shape) == 2]
    if not dense:
        return diags
    rows = dense[0].shape[0]
    lo, hi = int(np.min(idx)), int(np.max(idx))
    if lo < 0 or hi >= rows:
        diags.append(Diagnostic(
            "kernels", "error", where,
            f"scalar-prefetch gather out of bounds: ELL indices span "
            f"[{lo}, {hi}] but the resident dense operand has {rows} "
            f"rows — padded slots must gather row 0 (value 0), never "
            f"past the operand"))
    return diags


def check_kernels() -> Tuple[List[Diagnostic], List[str]]:
    """Run the full safety pass over every kernel package: coverage
    (every ``KERNEL_PACKAGES`` entry has a describer AND a VMEM model
    entry), guard drift, output index-map injectivity, index-map
    bounds, and the spmm scalar-prefetch gather bounds. Returns
    (diagnostics, checked package names); derived footprints ride along
    as info diagnostics."""
    from repro.kernels import KERNEL_PACKAGES
    from repro.kernels.dispatch import kernel_vmem_model
    diags: List[Diagnostic] = []
    checked: List[str] = []
    model = kernel_vmem_model()
    for pkg in KERNEL_PACKAGES:
        if pkg not in _DESCRIBERS:
            diags.append(Diagnostic(
                "kernels", "error", pkg,
                f"kernel package {pkg!r} has no safety-pass describer "
                f"— register one in repro.analysis.kernels so its "
                f"VMEM guard and index maps are verified"))
            continue
        if pkg not in model:
            diags.append(Diagnostic(
                "kernels", "error", pkg,
                f"kernel package {pkg!r} has no kernel_vmem_model "
                f"entry — dispatch cannot guard what the model does "
                f"not describe"))
            continue
        checked.append(pkg)
        entry = model[pkg]
        for label, model_kwargs, captures in _DESCRIBERS[pkg]():
            for cap in captures:
                derived = capture_footprint(cap)
                modeled = entry.resident_bytes(**model_kwargs)
                diags.extend(guard_drift_diags(label, modeled, derived,
                                               entry.cap))
                diags.extend(output_injectivity_diags(label, cap))
                diags.extend(index_map_bounds_diags(label, cap))
                if pkg == "spmm":
                    diags.extend(_gather_bounds_diags(label, cap))
                diags.append(Diagnostic(
                    "kernels", "info", label,
                    f"derived VMEM footprint {derived:.0f} B vs "
                    f"modeled {modeled:.0f} B (cap {entry.cap} B), "
                    f"grid {cap.grid or '()'} — "
                    f"{len(cap.inputs)} in / {len(cap.outputs)} out / "
                    f"{len(cap.scratch)} scratch"))
    stray = sorted(set(_DESCRIBERS) - set(KERNEL_PACKAGES))
    if stray:
        diags.append(Diagnostic(
            "kernels", "error", ",".join(stray),
            f"describer(s) {stray} name no package in "
            f"repro.kernels.KERNEL_PACKAGES — stale registration"))
    return diags, checked
