"""AdamW with f32 state, global-norm clipping and cosine schedule.

State pytrees mirror the param tree, so the param PartitionSpecs apply
verbatim to both moments — under FSDP this is ZeRO-style sharded
optimizer state for free.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)
    return lr


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Dict
    nu: Dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                          nu=jax.tree.map(jnp.copy, zeros))

    def state_specs(self, param_specs_tree):
        """PartitionSpecs for the state, mirroring the param specs."""
        from jax.sharding import PartitionSpec as P
        return AdamWState(step=P(), mu=param_specs_tree,
                          nu=jax.tree.map(lambda s: s, param_specs_tree))

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Dict, AdamWState]:
        step = state.step + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm > 0:
            gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                                 for g in jax.tree.leaves(gf)))
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(gnorm, 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, gf)
        nu = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                          state.nu, gf)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.learning_rate(step) if callable(self.learning_rate) \
            else self.learning_rate

        def upd(p, m, v):
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu)
