from repro.optim.adamw import AdamW, cosine_schedule
from repro.optim.compress import (quantize_int8, dequantize_int8,
                                  compressed_psum, ErrorFeedback)
