"""Gradient compression: int8 quantization with error feedback.

Used by the shard_map data-parallel trainer (runtime.driver ``dp_compressed``
mode): gradients are quantized to int8 + per-tensor scale before the psum
and the quantization error is fed back into the next step's gradient
(Seide et al. / EF-SGD), keeping convergence intact while cutting
allreduce bytes 4x vs f32 (2x vs bf16). This composes with the paper's SA
batching: SA reduces the NUMBER of messages, compression reduces their
SIZE — together they attack both L and W of Table I.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


class ErrorFeedback(NamedTuple):
    """Residual buffers, one per gradient leaf (f32)."""
    residual: Dict

    @classmethod
    def init(cls, params):
        return cls(residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compressed_psum(grads, ef: ErrorFeedback, axis_name,
                    n_shards: Optional[int] = None
                    ) -> Tuple[Dict, ErrorFeedback]:
    """Allreduce gradients in int8 with error feedback.

    Quantize (g + residual) per leaf, psum the int8 payload (as int32
    accumulator to avoid overflow across shards), dequantize with the
    max-scale, and stash the local quantization error. Inside shard_map.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = quantize_int8(corrected)
        # shared scale: use the max over shards so dequantization is
        # consistent (one extra scalar in the same fused reduce).
        gscale = jax.lax.pmax(scale, axis_name)
        q = jnp.clip(jnp.round(corrected / gscale), -127, 127)
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        mean = summed.astype(jnp.float32) * gscale
        if n_shards is not None:
            mean = mean / n_shards
        err = corrected - q * gscale
        return mean, err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    out, errs = [], []
    for g, r in zip(flat_g, flat_r):
        m, e = one(g, r)
        out.append(m)
        errs.append(e)
    return (jax.tree.unflatten(tdef, out),
            ErrorFeedback(residual=jax.tree.unflatten(tdef, errs)))
