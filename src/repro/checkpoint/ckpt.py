"""Sharded checkpointing with atomic writes, retention, async save and
cross-topology (elastic) restore.

Layout:  <dir>/step_<N>/
             manifest.json   — tree structure, shapes, dtypes, step,
                               partition specs (logical, mesh-agnostic),
                               data-pipeline state, extra metadata
             arrays.npz      — flattened leaves keyed by path

Because the manifest stores *logical* PartitionSpecs (axis names, not
device ids), a checkpoint written on a 512-chip mesh restores onto any
mesh whose axis names exist — the basis of elastic scaling: after a node
failure the driver rebuilds a smaller mesh and restores the same
checkpoint onto it.

Single-process container note: on a real multi-host pod each host writes
its local shards (process_index-suffixed npz) and host 0 the manifest;
here process count is 1, so one npz holds everything. The format keeps
the per-host field so the layout is forward-compatible.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            parts.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            parts.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            parts.append(str(e.name))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _spec_to_json(spec: P):
    out = []
    for part in spec:
        if part is None:
            out.append(None)
        elif isinstance(part, (tuple, list)):
            out.append(list(part))
        else:
            out.append(part)
    return out


def _spec_from_json(obj):
    return P(*[tuple(p) if isinstance(p, list) else p for p in obj])


# numpy's savez cannot represent ml_dtypes types (bfloat16, float8s) —
# they round-trip as raw void. Encode them as unsigned views + the
# logical dtype string in the manifest.
_EXOTIC_VIEWS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                 "float8_e5m2": np.uint8}


def _encode_array(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC_VIEWS:
        return arr.view(_EXOTIC_VIEWS[name]), name
    return arr, name


def _decode_array(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC_VIEWS:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def save_checkpoint(directory: str, step: int, tree: Any,
                    specs: Optional[Any] = None,
                    extra: Optional[Dict] = None) -> str:
    """Atomic save: write to a temp dir, fsync, rename."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        flat, _ = jax.tree_util.tree_flatten_with_path(tree)
        arrays = {}
        manifest_leaves = []
        spec_flat = None
        if specs is not None:
            spec_flat = [s for _, s in
                         jax.tree_util.tree_flatten_with_path(
                             specs, is_leaf=lambda x: isinstance(x, P))[0]]
        for i, (path, leaf) in enumerate(flat):
            key = _path_str(path)
            raw = np.asarray(jax.device_get(leaf))
            arrays[key], dtype_name = _encode_array(raw)
            manifest_leaves.append({
                "path": key,
                "shape": list(raw.shape),
                "dtype": dtype_name,
                "spec": _spec_to_json(spec_flat[i])
                if spec_flat is not None else None,
            })
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {"step": step, "process_count": 1,
                    "leaves": manifest_leaves, "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> Optional[int]:
    """Newest COMPLETE checkpoint step, or None.

    A ``step_<N>`` directory without a manifest.json is a partial write
    (e.g. a crash simulated mid-copy, or a foreign tool's leftovers —
    the atomic tmp+rename save never produces one itself) and is
    skipped: restore-latest must land on a checkpoint it can read."""
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_")
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int] = None,
                       tree_like: Any = None,
                       mesh: Optional[Mesh] = None):
    """Restore. With ``mesh``, leaves are placed with their manifest
    PartitionSpecs re-bound to THIS mesh (cross-topology / elastic restore:
    axis names are logical; the mesh may have different sizes).

    Returns (tree, manifest_extra).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    by_path = {}
    for leaf_info in manifest["leaves"]:
        arr = _decode_array(data[leaf_info["path"]], leaf_info["dtype"])
        if mesh is not None and leaf_info["spec"] is not None:
            spec = _spec_from_json(leaf_info["spec"])
            spec = P(*[p if _axes_exist(p, mesh) else None for p in spec])
            arr = jax.device_put(arr, NamedSharding(mesh, spec))
        by_path[leaf_info["path"]] = arr

    if tree_like is not None:
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        leaves = [by_path[_path_str(p)] for p, _ in flat]
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
    else:
        tree = by_path
    return tree, manifest.get("extra", {})


def _axes_exist(part, mesh: Mesh) -> bool:
    if part is None:
        return True
    names = (part,) if isinstance(part, str) else tuple(part)
    return all(n in mesh.axis_names for n in names)


class CheckpointManager:
    """Retention + async save on top of save/restore.

    Use as a context manager (or call :meth:`close`) so the last async
    save thread is joined before the run exits — a dangling daemon
    thread could otherwise still be mid-``np.savez`` while the caller
    reads the directory or the interpreter tears down."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, specs=None, extra=None):
        # materialize on host BEFORE handing to the thread (the train loop
        # may donate/overwrite device buffers).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, specs, extra)
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(int(d.split("_")[1])
                       for d in os.listdir(self.directory)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def close(self):
        """Join the outstanding async save (if any). Idempotent."""
        self.wait()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    def restore_latest(self, tree_like=None, mesh=None):
        self.wait()
        return restore_checkpoint(self.directory, None, tree_like, mesh)
