"""xlstm-350m [ssm] — alternating sLSTM + mLSTM blocks
[arXiv:2405.04517; unverified].

24L d_model=1024 4H d_ff=0 (xLSTM blocks carry their own projections;
no separate MLP) vocab=50304. Recurrent -> long_500k RUNS (O(1) state).
Pattern period 2: [mlstm, slstm] x 12.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, rope_theta=0.0, pos_embed="none",
    block_pattern=("mlstm", "slstm"),
    source="arXiv:2405.04517; unverified",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="xlstm-smoke", n_layers=4, d_model=64, n_heads=4,
    n_kv_heads=4, vocab_size=256)
