"""llama3-8b [dense] — GQA, 128k vocab [arXiv:2407.21783; unverified].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
Pure full attention -> long_500k skipped.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    block_pattern=("attn_mlp",),
    skip_shapes=("long_500k",),
    source="arXiv:2407.21783; unverified",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="llama3-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256)
