"""whisper-large-v3 [audio] — encoder-decoder, conv frontend STUB
[arXiv:2212.04356; unverified].

32L (enc) + 32L (dec) d_model=1280 20H d_ff=5120 vocab=51866;
encoder length 1500 frames. input_specs provides post-conv frame
embeddings (B, 1500, d_model). Decoder is full attention ->
long_500k skipped; decode shapes exercise the decoder self-attn cache at
the stated lengths (real Whisper caps at 448 positions — mechanical per
the brief, DESIGN.md).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866, rope_theta=0.0,
    pos_embed="sinusoidal", mlp_type="mlp2", act="gelu",
    tie_embeddings=True,
    block_pattern=("attn_mlp",),
    encoder_layers=32, encoder_seq=1500, frontend="audio_stub",
    skip_shapes=("long_500k",),
    source="arXiv:2212.04356; unverified",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="whisper-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256, encoder_layers=2,
    encoder_seq=30)
