"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer,
meta tokens, SWA [arXiv:2411.13676; hf].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Adaptations (DESIGN.md): all layers use sliding-window attention
(window=1024; the released model interleaves 3 global layers — dropped to
keep the stack scan-homogeneous); SSM heads are the chunked scalar-decay
linear recurrence (Mamba-2/SSD form). Sub-quadratic -> long_500k RUNS.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab_size=32001,
    block_pattern=("hybrid",), window=1024,
    ssm_state=16, ssm_heads=25, meta_tokens=128,
    source="arXiv:2411.13676; hf",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="hymba-smoke", n_layers=2, d_model=100, n_heads=5,
    n_kv_heads=5, d_ff=128, vocab_size=256, window=32, ssm_state=4,
    ssm_heads=5, meta_tokens=4)
