"""tinyllama-1.1b [dense] — llama2-arch small [arXiv:2401.02385; hf].

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
Pure full attention -> long_500k skipped (DESIGN.md §Arch-applicability).
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000,
    block_pattern=("attn_mlp",),
    skip_shapes=("long_500k",),
    source="arXiv:2401.02385; hf",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="tinyllama-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256)
