"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072. The vision
frontend is a STUB: input_specs provides precomputed patch embeddings
(B, n_patches, d_model) prepended to the text embeddings.
Pure full attention -> long_500k skipped.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128, rope_theta=1e6,
    block_pattern=("attn_mlp",),
    frontend="vision_stub", n_patches=1024,
    skip_shapes=("long_500k",),
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="pixtral-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16, n_patches=8)
