"""granite-moe-1b-a400m [moe] — 32 experts top-8, fine-grained d_ff=512
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].

24L d_model=1024 16H (GQA kv=8) d_ff=512/expert vocab=49155.
Pure full attention -> long_500k skipped.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    block_pattern=("moe",), n_experts=32, top_k=8,
    skip_shapes=("long_500k",),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="granite-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=32, vocab_size=256, n_experts=4, top_k=2)
