"""Architecture registry: ``get_config(name)`` / ``list_archs()``.

Each assigned architecture lives in its own module with the exact
hyperparameters from the brief plus a reduced smoke-test variant.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, input_specs

_ARCH_MODULES = {
    "hymba-1.5b": "repro.configs.hymba_1p5b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1p1b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "llama3-8b": "repro.configs.llama3_8b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.CONFIG


def get_smoke_config(name: str) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests."""
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.SMOKE_CONFIG
