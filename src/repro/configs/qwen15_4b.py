"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-4B; hf].

40L d_model=2560 20H (GQA kv=20, i.e. MHA) d_ff=6912 vocab=151936.
Pure full attention -> long_500k skipped.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab_size=151936, qkv_bias=True,
    block_pattern=("attn_mlp",),
    skip_shapes=("long_500k",),
    source="hf:Qwen/Qwen1.5-4B; hf",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="qwen-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab_size=256)
