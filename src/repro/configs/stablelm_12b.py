"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-12b; hf].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352.
Pure full attention -> long_500k skipped.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab_size=100352, head_dim=160,
    block_pattern=("attn_mlp",),
    skip_shapes=("long_500k",),
    source="hf:stabilityai/stablelm-2-12b; hf",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="stablelm-smoke", n_layers=2, d_model=80, n_heads=4,
    n_kv_heads=2, d_ff=160, vocab_size=256, head_dim=20)
