"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336/expert vocab=32000,
window=4096 (SWA bounds the KV cache) -> long_500k RUNS.
"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=32000, window=4096,
    block_pattern=("moe",), n_experts=8, top_k=2,
    source="arXiv:2401.04088; hf",
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=64, vocab_size=256, window=32, n_experts=4,
    top_k=2)
