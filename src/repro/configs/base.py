"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload
shape is a ``ShapeConfig``. ``input_specs(arch, shape)`` yields
ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
shardable, no device allocation) — the dry-run lowers against these.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # per-layer block pattern, cycled over the depth. Entries:
    #   attn_mlp | swa_mlp | moe | mamba_mlp | mlstm | slstm | hybrid
    block_pattern: Tuple[str, ...] = ("attn_mlp",)
    qkv_bias: bool = False
    window: int = 0                # sliding-window size for swa blocks
    rope_theta: float = 10000.0
    pos_embed: str = "rope"        # rope | sinusoidal | none
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / recurrent
    ssm_state: int = 0             # key dim of the linear-recurrence heads
    ssm_heads: int = 0             # 0 -> n_heads
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed source length (whisper: 1500)
    # modality frontend stubs
    frontend: str = "none"         # none | audio_stub | vision_stub
    n_patches: int = 0             # vision stub: patches prepended to text
    meta_tokens: int = 0           # hymba: learnable prefix tokens
    norm: str = "rmsnorm"
    act: str = "silu"
    mlp_type: str = "swiglu"       # swiglu | mlp2
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # which shapes this arch must SKIP (sub-quadratic requirement etc.)
    skip_shapes: Tuple[str, ...] = ()
    source: str = ""               # provenance note from the brief

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jnp_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def block_at(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    def param_count(self) -> int:
        """Analytical parameter count (embeddings included)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        Hd = self.head_dim_
        qkv = D * (self.n_heads * Hd) + 2 * D * (self.n_kv_heads * Hd) \
            + (self.n_heads * Hd) * D
        mlp = 3 * D * F                          # gate/up/down (SwiGLU)
        total = 0
        for layer in range(self.n_layers):
            blk = self.block_at(layer)
            if blk in ("attn_mlp", "swa_mlp"):
                total += qkv + mlp
            elif blk == "moe":
                total += qkv + self.n_experts * 3 * D * F + D * self.n_experts
            elif blk == "mamba_mlp":
                total += self._ssm_params() + mlp
            elif blk == "hybrid":
                total += qkv + self._ssm_params() + mlp
            elif blk in ("mlstm", "slstm"):
                total += self._xlstm_params(blk)
            total += 2 * D                       # two norms
        total += V * D                           # embed
        if not self.tie_embeddings:
            total += D * V                       # unembed
        if self.is_encdec:
            enc = self.encoder_layers * (qkv + mlp + 2 * D)
            cross = self.n_layers * (qkv + D)    # cross-attn per dec layer
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.n_experts == 0:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * D * F
        n_moe_layers = sum(1 for l in range(self.n_layers)
                           if self.block_at(l) == "moe")
        return self.param_count() - n_moe_layers * inactive

    def _ssm_params(self) -> int:
        H = self.ssm_heads or self.n_heads
        dk = self.ssm_state
        dv = self.d_model // H
        D = self.d_model
        return D * H * (2 * dk + 2 * dv) + H * dv * D   # q,k,v,gate + out

    def _xlstm_params(self, kind: str) -> int:
        D = self.d_model
        if kind == "mlstm":
            up = 2 * D
            return D * up * 2 + up * D + 3 * (up // 1) * 0 + 4 * up * up // 4
        return 4 * D * D + 4 * D * D // 4               # slstm approx


# ---------------------------------------------------------------------------
# Shape configs (assigned per the brief)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, object]:
    """ShapeDtypeStruct stand-ins for every model input of this workload.

    train:   {tokens, targets [, frames | patches]}
    prefill: {tokens [, frames | patches]}
    decode:  {tokens (B, 1), cache (pytree), pos}
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = arch.jnp_dtype

    def tok(s):
        return jax.ShapeDtypeStruct((B, s), i32)

    extras: Dict[str, object] = {}
    text_len = S
    if arch.frontend == "vision_stub" and shape.kind != "decode":
        n_patch = min(arch.n_patches, S // 4)
        text_len = S - n_patch
        extras["patches"] = jax.ShapeDtypeStruct((B, n_patch, arch.d_model), dt)
    if arch.frontend == "audio_stub":
        extras["frames"] = jax.ShapeDtypeStruct(
            (B, arch.encoder_seq, arch.d_model), dt)

    if shape.kind == "train":
        return {"tokens": tok(text_len), "targets": tok(text_len), **extras}
    if shape.kind == "prefill":
        return {"tokens": tok(text_len), **extras}
    # decode: one new token against a cache of length S.
    from repro.models import lm as lm_lib           # deferred, avoids cycle
    cache = lm_lib.cache_specs(arch, B, S)
    out = {"tokens": tok(1), "cache": cache,
           "pos": jax.ShapeDtypeStruct((), i32)}
    if arch.frontend == "audio_stub":
        # cross-attention reads the (stub) encoder output each step.
        out["frames"] = jax.ShapeDtypeStruct(
            (B, arch.encoder_seq, arch.d_model), dt)
    return out
