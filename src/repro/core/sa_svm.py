"""Synchronization-Avoiding linear SVM — paper Algorithm 4 and its block
generalization SA-BDCD (after Devarakonda et al., arXiv:1612.04003).

Unrolls s iterations of (block) dual CD: sample s blocks of mu row
indices up front, compute the (s*mu) x (s*mu) Gram matrix
G = Y Y^T + gamma I  and the projections  x' = Y x_sk  with ONE fused
Allreduce (Alg. 4 lines 9-10; the local GEMM can route through the
``repro.kernels.gram`` Pallas kernel), then run the s inner block-updates
redundantly on replicated O(s^2 mu^2)-sized data. The diagonal blocks of
G supply every step size (Alg. 4 line 11: eta for mu = 1; lambda_max via
power iteration for mu > 1) — the classical per-iteration Gram-block
reductions vanish entirely. Deferred primal update:
x += Y^T (b * theta), ONE local GEMV per outer iteration.

The s dependent inner updates run through ``repro.kernels.svm_inner``:
a pure-jnp reference on CPU, or (``cfg.use_pallas``) one fused Pallas
kernel holding all replicated state in VMEM. The path actually taken is
surfaced in ``SolverResult.aux["inner_impl"]``.

Same-index collisions across the s blocks of an outer group (paper
Eq. 14's I_{sk+j}^T I_{sk+t} term) are handled by the eq-matrix gather
inside the inner loop, and by the Gram cross terms, whose off-diagonal
blocks hold the raw Y_j Y_t^T even when indices repeat — algebraically
identical to the classical method, see DESIGN.md.

iterations need not divide by s: floor(H/s) full groups run in a scan,
then ONE remainder group of H mod s iterations finishes the schedule —
every configuration executes exactly H inner iterations with
ceil(H/s) Allreduces.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.sa_lasso import _gram_and_proj, _reduce_gram_proj
from repro.core.sa_loop import grouped_impl_label, run_grouped
from repro.core.sparse_exec import (prep_operand, row_block_ops,
                                    spmm_aux)
from repro.core.types import (SVMProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, operand_rmatvec,
                              require_unit_block, resume_carry)
from repro.kernels.svm_inner import inner_impl, svm_inner_loop


def sa_bdcd_svm(problem: SVMProblem, cfg: SolverConfig,
                axis_name: Optional[object] = None,
                alpha0=None, state: Optional[SolveState] = None
                ) -> SolverResult:
    """s-step unrolled BDCD: identical iterates to ``bdcd_svm`` in exact
    arithmetic, ONE Allreduce per s inner iterations."""
    A = prep_operand(problem.A, cfg.dtype)
    sparse = isinstance(A, SparseOperand)
    take, gram, _, apply_t = row_block_ops(A, cfg)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    mu = cfg.block_size
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    gamma_f, nu_f = float(problem.gamma), float(problem.nu)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    carry0 = resume_carry(state, alpha0, "sa_bdcd_svm")
    h0 = 0 if state is None else int(state.iteration)

    if carry0 is not None:
        # resume: carry restored verbatim (no matvec / Allreduce rebuild)
        alpha = jnp.asarray(carry0["alpha"], cfg.dtype)
        x = jnp.asarray(carry0["x"], cfg.dtype)
        dual0 = jnp.asarray(carry0["dual"], cfg.dtype)
    else:
        alpha = jnp.zeros((m,), cfg.dtype) if alpha0 is None \
            else jnp.asarray(alpha0, cfg.dtype)
        x = operand_rmatvec(A, b * alpha)                 # line 2 (local)
        # warm start: resume incremental dual tracking from f_D(alpha0), as
        # in ``bdcd_svm``, reusing the x just built (zero-start: no
        # communication).
        dual0 = jnp.asarray(0.0, cfg.dtype) if alpha0 is None else (
            0.5 * linalg.preduce(jnp.sum(x * x), axis_name)
            + 0.5 * gamma * jnp.sum(alpha * alpha) - jnp.sum(alpha))

    def group(carry, start, s_grp):
        """One outer group of s_grp block updates; ``start`` is the
        (traced) global iteration id preceding the group."""
        alpha, x, dual = carry
        # sample the blocks with the same fold_in ids as the non-SA
        # solver (global iteration ids h = start + j) -> bit-identical
        # draws.
        hs = start + 1 + jnp.arange(s_grp)
        idxs = jax.vmap(
            lambda h: linalg.sample_block(jax.random.fold_in(key, h),
                                          m, mu))(hs)     # (s_grp, mu)
        flat = idxs.reshape(s_grp * mu)
        Y = take(flat)                                    # (s_grp*mu, n_loc)
        b_sel = b[flat].reshape(s_grp, mu)                # replicated
        # --- Communication: ONE fused Allreduce of  Y [Y^T | x] ---
        if sparse:
            Graw, P = _reduce_gram_proj(gram(Y, x[:, None]), s_grp * mu,
                                        1, axis_name, cfg.symmetric_gram)
        else:
            Graw, P = _gram_and_proj(Y.T, x[:, None], axis_name,
                                     symmetric=cfg.symmetric_gram,
                                     use_pallas=cfg.use_pallas)
        G = Graw + gamma * jnp.eye(s_grp * mu, dtype=cfg.dtype)  # line 9
        proj = P[:, 0].reshape(s_grp, mu)                 # line 10: Y x_sk
        a_vals = alpha[flat].reshape(s_grp, mu)
        # --- the s_grp dependent inner updates (Alg. 4 lines 11-20) ---
        theta, deltas = svm_inner_loop(
            G, proj, b_sel, a_vals, idxs, gamma=gamma_f, nu=nu_f,
            power_iters=cfg.power_iters, use_pallas=cfg.use_pallas)
        theta = theta.astype(cfg.dtype)
        deltas = deltas.astype(cfg.dtype)
        bt = (b_sel * theta).reshape(s_grp * mu)
        alpha = alpha.at[flat].add(theta.reshape(s_grp * mu))  # line 20
        # Deferred primal update (local GEMV): x += Y^T (theta * b_sel).
        x = x + apply_t(Y, bt)                            # line 21, batched
        objs = dual + jnp.cumsum(deltas) if cfg.track_objective \
            else jnp.zeros((s_grp,), cfg.dtype)
        dual = dual + jnp.sum(deltas)
        return (alpha, x, dual), objs

    (alpha, x, dual), objs = run_grouped(group, (alpha, x, dual0), H, s,
                                         cfg.dtype, start=h0)
    return SolverResult(x=x, objective=objs,
                        aux={"alpha": alpha, "dual": dual,
                             "state": SolveState(
                                 h0 + H,
                                 {"alpha": alpha, "x": x, "dual": dual}),
                             "inner_impl": grouped_impl_label(
                                 inner_impl, H, s, mu, cfg.use_pallas,
                                 jnp.dtype(cfg.dtype).itemsize),
                             **spmm_aux(A, cfg, "row_gram", H=H,
                                        extra=1)})


def sa_svm(problem: SVMProblem, cfg: SolverConfig,
           axis_name: Optional[object] = None,
           alpha0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Paper Algorithm 4: the block_size = 1 special case of
    ``sa_bdcd_svm``."""
    require_unit_block(cfg, "sa_svm")
    return sa_bdcd_svm(problem, cfg, axis_name, alpha0, state)
