"""Synchronization-Avoiding linear SVM — paper Algorithm 4 and its block
generalization SA-BDCD (after Devarakonda et al., arXiv:1612.04003),
expressed as a :class:`repro.core.engine` FamilyProgram.

Unrolls s iterations of (block) dual CD: sample s blocks of mu row
indices up front, compute the (s*mu) x (s*mu) Gram matrix
G = Y Y^T + gamma I  and the projections  x' = Y x_sk  with ONE fused
Allreduce (Alg. 4 lines 9-10; the local GEMM can route through the
``repro.kernels.gram`` Pallas kernel), then run the s inner block-updates
redundantly on replicated O(s^2 mu^2)-sized data. The diagonal blocks of
G supply every step size (Alg. 4 line 11: eta for mu = 1; lambda_max via
power iteration for mu > 1) — the classical per-iteration Gram-block
reductions vanish entirely. Deferred primal update:
x += Y^T (b * theta), ONE local GEMV per outer iteration.

The s dependent inner updates run through ``repro.kernels.svm_inner``
(jnp reference, or one fused Pallas kernel per ``cfg.use_pallas``); the
path taken lands in ``SolverResult.aux["inner_impl"]``.

Same-index collisions across the s blocks (paper Eq. 14's
I_{sk+j}^T I_{sk+t} term) are handled by the eq-matrix gather in the
inner loop and by the raw Y_j Y_t^T Gram cross terms — algebraically
identical to the classical method, see DESIGN.md.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core import linalg
from repro.core.engine import (Ctx, FamilyProgram, gram_local,
                               reduce_gram_proj, run_program)
from repro.core.sparse_exec import prep_operand, row_block_ops
from repro.core.types import (SVMProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, operand_rmatvec,
                              require_unit_block)
from repro.kernels.svm_inner import svm_inner_loop


def _svm_setup(problem, cfg, axis_name, alpha0, carry0):
    A = prep_operand(problem.A, cfg.dtype)
    take, gram, _, apply_t = row_block_ops(A, cfg)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    ctx = Ctx(A=A, b=b, m=m, mu=cfg.block_size, gamma=gamma,
              gamma_f=float(problem.gamma), nu_f=float(problem.nu),
              sparse=isinstance(A, SparseOperand), take=take, gram=gram,
              apply_t=apply_t, cfg=cfg, axis_name=axis_name)

    if carry0 is not None:
        # resume: carry restored verbatim (no matvec / Allreduce rebuild)
        alpha = jnp.asarray(carry0["alpha"], cfg.dtype)
        x = jnp.asarray(carry0["x"], cfg.dtype)
        dual0 = jnp.asarray(carry0["dual"], cfg.dtype)
    else:
        alpha = jnp.zeros((m,), cfg.dtype) if alpha0 is None \
            else jnp.asarray(alpha0, cfg.dtype)
        x = operand_rmatvec(A, b * alpha)                 # line 2 (local)
        # warm start: resume dual tracking from f_D(alpha0), as in
        # ``bdcd_svm`` (zero-start: no communication).
        dual0 = jnp.asarray(0.0, cfg.dtype) if alpha0 is None else (
            0.5 * linalg.preduce(jnp.sum(x * x), axis_name)
            + 0.5 * gamma * jnp.sum(alpha * alpha) - jnp.sum(alpha))
    return ctx, (alpha, x, dual0)


def _svm_assemble(ctx, carry, idxs, s_grp):
    _, x, _ = carry
    flat = idxs.reshape(s_grp * ctx.mu)
    Y = ctx.take(flat)                                # (s_grp*mu, n_loc)
    # LOCAL fused  Y [Y^T | x]  (Alg. 4 lines 9-10, pre-reduce half)
    local = ctx.gram(Y, x[:, None]) if ctx.sparse \
        else gram_local(Y.T, x[:, None], ctx.cfg.use_pallas)
    return Y, local


def _svm_reduce(ctx, local, idxs, s_grp):
    smu = s_grp * ctx.mu
    Graw, P = reduce_gram_proj(local, smu, 1, ctx.axis_name,
                               ctx.cfg.symmetric_gram)
    G = Graw + ctx.gamma * jnp.eye(smu, dtype=ctx.cfg.dtype)  # line 9
    proj = P[:, 0].reshape(s_grp, ctx.mu)             # line 10: Y x_sk
    return G, proj


def _svm_inner(ctx, carry, Y, payload, idxs, win, s_grp):
    alpha, x, dual = carry
    cfg, mu = ctx.cfg, ctx.mu
    G, proj = payload
    flat = idxs.reshape(s_grp * mu)
    b_sel = ctx.b[flat].reshape(s_grp, mu)            # replicated
    a_vals = alpha[flat].reshape(s_grp, mu)
    # --- the s_grp dependent inner updates (Alg. 4 lines 11-20) ---
    theta, deltas = svm_inner_loop(
        G, proj, b_sel, a_vals, idxs, gamma=ctx.gamma_f, nu=ctx.nu_f,
        power_iters=cfg.power_iters, use_pallas=cfg.use_pallas)
    return carry, (theta.astype(cfg.dtype), deltas.astype(cfg.dtype),
                   b_sel, flat)


def _svm_defer(ctx, carry, Y, inner_out, payload, idxs, win, s_grp):
    alpha, x, dual = carry
    cfg = ctx.cfg
    theta, deltas, b_sel, flat = inner_out
    bt = (b_sel * theta).reshape(s_grp * ctx.mu)
    alpha = alpha.at[flat].add(theta.reshape(s_grp * ctx.mu))  # line 20
    # Deferred primal update (local GEMV): x += Y^T (theta * b_sel).
    x = x + ctx.apply_t(Y, bt)                        # line 21, batched
    objs = dual + jnp.cumsum(deltas) if cfg.track_objective \
        else jnp.zeros((s_grp,), cfg.dtype)
    dual = dual + jnp.sum(deltas)
    return (alpha, x, dual), objs


_BDCD_PROGRAM = FamilyProgram(
    name="sa_bdcd_svm", setup=_svm_setup,
    sample=lambda ctx, key: linalg.sample_block(key, ctx.m, ctx.mu),
    assemble=_svm_assemble, reduce=_svm_reduce, inner=_svm_inner,
    defer=_svm_defer,
    finalize=lambda ctx, carry, sched: (
        carry[1], {"alpha": carry[0], "dual": carry[2]}),
    carry_names=("alpha", "x", "dual"), uses_svm_inner=True,
    spmm_kind="row_gram", spmm_extra=1)


def sa_bdcd_svm(problem: SVMProblem, cfg: SolverConfig,
                axis_name: Optional[object] = None,
                alpha0=None, state: Optional[SolveState] = None
                ) -> SolverResult:
    """s-step unrolled BDCD: identical iterates to ``bdcd_svm`` in exact
    arithmetic, ONE Allreduce per s inner iterations."""
    return run_program(_BDCD_PROGRAM, problem, cfg, axis_name, alpha0,
                       state)


def sa_svm(problem: SVMProblem, cfg: SolverConfig,
           axis_name: Optional[object] = None,
           alpha0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Paper Algorithm 4: the block_size = 1 case of ``sa_bdcd_svm``."""
    require_unit_block(cfg, "sa_svm")
    return sa_bdcd_svm(problem, cfg, axis_name, alpha0, state)
