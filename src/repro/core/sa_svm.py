"""Synchronization-Avoiding linear SVM — paper Algorithm 4 and its block
generalization SA-BDCD (after Devarakonda et al., arXiv:1612.04003).

Unrolls s iterations of (block) dual CD: sample s blocks of mu row
indices up front, compute the (s*mu) x (s*mu) Gram matrix
G = Y Y^T + gamma I  and the projections  x' = Y x_sk  with ONE fused
Allreduce (Alg. 4 lines 9-10; the local GEMM can route through the
``repro.kernels.gram`` Pallas kernel), then run the s inner block-updates
redundantly on replicated O(s^2 mu^2)-sized data. The diagonal blocks of
G supply every step size (Alg. 4 line 11: eta for mu = 1; lambda_max via
power iteration for mu > 1) — the classical per-iteration Gram-block
reductions vanish entirely. Deferred primal update:
x += Y^T (b * theta), ONE local GEMV per outer iteration.

Same-index collisions across the s blocks of an outer group (paper
Eq. 14's I_{sk+j}^T I_{sk+t} term) are handled by gathering beta_j from
the *updated* replicated alpha, and by the Gram cross terms, whose
off-diagonal blocks hold the raw Y_j Y_t^T even when indices repeat —
algebraically identical to the classical method, see DESIGN.md.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.sa_lasso import _gram_and_proj
from repro.core.types import SVMProblem, SolverConfig, SolverResult


def sa_bdcd_svm(problem: SVMProblem, cfg: SolverConfig,
                axis_name: Optional[object] = None,
                alpha0=None) -> SolverResult:
    """s-step unrolled BDCD: identical iterates to ``bdcd_svm`` in exact
    arithmetic, ONE Allreduce per s inner iterations."""
    A = jnp.asarray(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    mu = cfg.block_size
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    nu = jnp.asarray(problem.nu, cfg.dtype)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    K = H // s

    alpha = jnp.zeros((m,), cfg.dtype) if alpha0 is None \
        else jnp.asarray(alpha0, cfg.dtype)
    x = A.T @ (b * alpha)                                 # line 2 (local)

    def outer(carry, k):
        alpha, x, dual = carry
        # sample the s blocks with the same fold_in ids as the non-SA
        # solver (global iteration ids h = k*s + j) -> bit-identical draws.
        hs = k * s + 1 + jnp.arange(s)
        idxs = jax.vmap(
            lambda h: linalg.sample_block(jax.random.fold_in(key, h),
                                          m, mu))(hs)     # (s, mu)
        Y = A[idxs.reshape(s * mu)]                       # (s*mu, n_loc)
        b_sel = b[idxs.reshape(s * mu)].reshape(s, mu)    # replicated
        # --- Communication: ONE fused Allreduce of  Y [Y^T | x] ---
        Graw, P = _gram_and_proj(Y.T, x[:, None], axis_name,
                                 symmetric=cfg.symmetric_gram,
                                 use_pallas=cfg.use_pallas)
        G = Graw + gamma * jnp.eye(s * mu, dtype=cfg.dtype)   # line 9
        G4 = G.reshape(s, mu, s, mu)
        x_proj = P[:, 0].reshape(s, mu)                   # line 10: Y x_sk

        def inner(inner_carry, j):
            alpha, bt_buf, dual = inner_carry
            idx_j = idxs[j]
            b_j = b_sel[j]
            beta = alpha[idx_j]                           # Eq. (14), exact
            Gj = G4[j]                                    # (mu, s, mu)
            # Eq. (15): cross terms  Y_j Y_t^T (b_t theta_t)  for t < j.
            # The +gamma*I in G only touches the diagonal block t == j,
            # which the t<j mask excludes, so G's off-diagonal blocks are
            # the raw Y Y^T the equation needs — even when indices repeat
            # across blocks.
            cross = jnp.einsum("ptq,tq->tp", Gj, bt_buf)  # (s, mu)
            mask = (jnp.arange(s) < j).astype(cfg.dtype)
            rj = x_proj[j] + jnp.einsum("t,tp->p", mask, cross)
            g = b_j * rj - 1.0 + gamma * beta
            Gjj = Gj[:, j, :]                             # (mu, mu) diag blk
            v = linalg.power_iteration_max_eig(Gjj, cfg.power_iters)
            gbar = jnp.abs(jnp.clip(beta - g, 0.0, nu) - beta)   # line 15
            theta = jnp.where(
                gbar != 0.0,
                jnp.clip(beta - g / v, 0.0, nu) - beta,          # line 16
                0.0)
            alpha = alpha.at[idx_j].add(theta)            # line 20
            bt = b_j * theta
            bt_buf = bt_buf.at[j].set(bt)
            dual = dual + jnp.sum(theta * g) + 0.5 * bt @ (Gjj @ bt)
            return (alpha, bt_buf, dual), dual

        bt_buf0 = jnp.zeros((s, mu), cfg.dtype)
        (alpha, bt_buf, dual), duals = jax.lax.scan(
            inner, (alpha, bt_buf0, dual), jnp.arange(s))
        # Deferred primal update (local GEMV): x += Y^T (theta * b_sel).
        x = x + Y.T @ bt_buf.reshape(s * mu)              # line 21, batched
        objs = duals if cfg.track_objective \
            else jnp.zeros((s,), cfg.dtype)
        return (alpha, x, dual), objs

    dual0 = jnp.asarray(0.0, cfg.dtype)
    (alpha, x, dual), objs = jax.lax.scan(
        outer, (alpha, x, dual0), jnp.arange(K))
    return SolverResult(x=x, objective=objs.reshape(H),
                        aux={"alpha": alpha, "dual": dual})


def sa_svm(problem: SVMProblem, cfg: SolverConfig,
           axis_name: Optional[object] = None,
           alpha0=None) -> SolverResult:
    """Paper Algorithm 4: the block_size = 1 special case of
    ``sa_bdcd_svm``."""
    assert cfg.block_size == 1
    return sa_bdcd_svm(problem, cfg, axis_name, alpha0)
