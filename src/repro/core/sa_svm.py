"""Synchronization-Avoiding linear SVM — paper Algorithm 4.

Unrolls s iterations of dual CD: sample s row indices up front, compute the
s x s Gram matrix  G = Y Y^T + gamma I  and the projections  x' = Y x_sk
with ONE fused Allreduce (Alg. 4 lines 9-10), then run the s inner updates
on replicated scalars. The diagonal of G supplies every eta_{sk+j}
(Alg. 4 line 11) — the classical per-iteration ||A_i||^2 reductions vanish
entirely. Deferred primal update: x += Y^T (theta * b_sel), a local GEMV.

Same-index collisions across inner iterations (paper Eq. 14's
I_{sk+j}^T I_{sk+t} term) are handled by gathering beta_j from the
*updated* replicated alpha — algebraically identical, see DESIGN.md.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.types import SVMProblem, SolverConfig, SolverResult


def sa_svm(problem: SVMProblem, cfg: SolverConfig,
           axis_name: Optional[object] = None,
           alpha0=None) -> SolverResult:
    A = jnp.asarray(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    nu = jnp.asarray(problem.nu, cfg.dtype)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    K = H // s

    alpha = jnp.zeros((m,), cfg.dtype) if alpha0 is None \
        else jnp.asarray(alpha0, cfg.dtype)
    x = A.T @ (b * alpha)                                 # line 2 (local)

    def outer(carry, k):
        alpha, x, dual = carry
        # sample s indices with the same fold_in ids as the non-SA solver.
        hs = k * s + 1 + jnp.arange(s)
        idx = jax.vmap(
            lambda h: jax.random.randint(jax.random.fold_in(key, h),
                                         (), 0, m))(hs)   # (s,)
        Y = A[idx]                                        # (s, n_loc) local
        b_sel = b[idx]                                    # (s,) replicated
        # --- Communication: ONE fused Allreduce of  Y [Y^T | x] ---
        red = linalg.preduce(
            Y @ jnp.concatenate([Y.T, x[:, None]], axis=1), axis_name)
        G = red[:, :s] + gamma * jnp.eye(s, dtype=cfg.dtype)  # line 9
        x_proj = red[:, s]                                # line 10: Y x_sk
        etas = jnp.diagonal(G)                            # line 11

        def inner(inner_carry, j):
            alpha, theta_buf, dual = inner_carry
            i_j = idx[j]
            beta = alpha[i_j]                             # Eq. (14), exact
            # Eq. (15): cross terms sum_{t<j} theta_t b_j b_t (Y Y^T)[j, t].
            # The +gamma*I in G only touches [j, j], which the t<j mask
            # excludes, so G's off-diagonals are the raw Y Y^T the equation
            # needs — even when i_t == i_j.
            mask = (jnp.arange(s) < j).astype(cfg.dtype)
            cross = b_sel[j] * jnp.sum(mask * theta_buf * b_sel * G[j])
            g = b_sel[j] * x_proj[j] - 1.0 + gamma * beta + cross
            eta = etas[j]
            gbar = jnp.abs(jnp.clip(beta - g, 0.0, nu) - beta)   # line 15
            theta = jnp.where(
                gbar != 0.0,
                jnp.clip(beta - g / eta, 0.0, nu) - beta,        # line 16
                0.0)
            alpha = alpha.at[i_j].add(theta)              # line 20
            theta_buf = theta_buf.at[j].set(theta)
            dual = dual + theta * g + 0.5 * theta * theta * eta
            return (alpha, theta_buf, dual), dual

        theta_buf0 = jnp.zeros((s,), cfg.dtype)
        (alpha, theta_buf, dual), duals = jax.lax.scan(
            inner, (alpha, theta_buf0, dual), jnp.arange(s))
        # Deferred primal update (local GEMV): x += Y^T (theta * b_sel).
        x = x + Y.T @ (theta_buf * b_sel)                 # line 21, batched
        objs = duals if cfg.track_objective \
            else jnp.zeros((s,), cfg.dtype)
        return (alpha, x, dual), objs

    dual0 = jnp.asarray(0.0, cfg.dtype)
    (alpha, x, dual), objs = jax.lax.scan(
        outer, (alpha, x, dual0), jnp.arange(K))
    return SolverResult(x=x, objective=objs.reshape(H),
                        aux={"alpha": alpha, "dual": dual})
