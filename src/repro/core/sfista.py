"""Sampled FISTA (SFISTA) and its s-step synchronization-avoiding unroll
CA-SFISTA — after Soori et al., "Avoiding communication in proximal
methods for convex optimization problems" (arXiv:1710.08883), which
builds accelerated sampled proximal least-squares from the same s-step
recurrences as the BCD line.

SFISTA takes a FISTA step restricted to a random block B of mu
coordinates of the momentum iterate y:

    x_h = y_{h-1} + e_B d,   d = prox(y_B - eta A_B^T ry, eta) - y_B
    y_h = x_h + beta_h e_B (x_B^h - x_B^{h-1}),
    beta_h = (t_{h-1} - 1) / t_h      (the classical FISTA t-sequence),

with eta = 1 / lambda_max(A_B^T A_B) from the sampled Gram block and
rx = A x - b, ry = A y - b the two coupled residuals (row-partitioned
like the Lasso solvers). The momentum extrapolation is applied IN THE
SAMPLED SUBSPACE only: coordinates outside B satisfy y_i = x_i after
every iteration. At mu = n this is exactly FISTA (full-vector
extrapolation); for mu < n extrapolating the untouched coordinates —
which received no gradient contraction to balance it — makes the
iteration diverge, while the subspace rule keeps y - x supported on the
last sampled block and the objective decreasing. Per classical
iteration: ONE fused Allreduce of the (mu, mu + 1) block [G | A_B^T ry].

CA-SFISTA is the same s-step transformation as every other family:
sample all s blocks up front, fuse the group's Gram/projection products
into ONE Allreduce of Y^T [Y | ry], and run the s dependent inner
updates on replicated data. Subspace momentum makes the unrolled
residual recurrence a pure accumulation,

    ry_j  = ry_sk + sum_{t <= j} A_{B_t} c_t,    c_t = d_t + beta_t w_t,
    rx_j  = ry_{j-1} + A_{B_j} d_j,
    w_t   = x_B^t - x_B^{t-1}  (gathered from the replicated x, y),

so the gradient projection at step j is  A_B_j^T ry_sk (one payload
column) plus Gram-block contractions with the recorded c_t — every term
a slice of the ONE reduced payload. x and y in R^n are replicated and
updated densely inside the inner loop (no communication), exactly like
the Lasso solvers' z/y. The deferred O(nnz)/dense application then
materializes rx, ry (and the per-step residuals for objective
stitching) from the two coefficient buffers.

Registered as the ``"sfista"`` family: the generic engine
(:mod:`repro.core.engine`) owns grouping, remainder tails, fold_in ids,
the t-schedule windows and SolveState resume; the registry gives it the
sharded driver, elastic checkpointing, the CLI and the autotuner with
zero edits to any of them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model, linalg, prox as prox_lib
from repro.core.engine import (Ctx, FamilyProgram, deferred_steps,
                               gram_local, reduce_gram_proj, run_program)
from repro.core.sparse_exec import col_block_ops, prep_operand, spmm_aux
from repro.core.types import (SolveState, SolverConfig, SolverResult,
                              SparseOperand, operand_matvec,
                              register_family, resume_carry)


@dataclasses.dataclass(frozen=True)
class SFISTAProblem:
    """Proximal least-squares problem data for the (CA-)SFISTA family.

    Same data as :class:`~repro.core.types.LassoProblem` — A (m, n) dense
    or :class:`~repro.core.types.SparseOperand` (the local ROW shard when
    distributed), b (m,), l1 weight lam, optional l2 -> elastic net — but
    a distinct problem class: the registry dispatches on it, selecting
    the momentum (FISTA) iteration instead of coordinate descent.
    """

    A: Any
    b: Any
    lam: float
    l2: float = 0.0

    @property
    def shape(self):
        return self.A.shape


def _prep(problem: SFISTAProblem, cfg: SolverConfig):
    A = prep_operand(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    n = A.shape[1]
    mu = cfg.block_size
    prox = prox_lib.make_prox(problem.lam, problem.l2, None)
    return A, b, n, mu, prox


def _objective(residual, x, problem, axis_name):
    quad = 0.5 * linalg.preduce(jnp.sum(residual * residual), axis_name)
    return quad + prox_lib.reg_value(x, problem.lam, problem.l2, None)


def _init_iterates(A, b, n, cfg, x0, carry0):
    """(x, y, rx, ry): restored verbatim from a checkpoint, rebuilt
    locally from a warm start (momentum restarts: y = x, ry = rx), or the
    zero start where rx = ry = -b with no communication at all."""
    if carry0 is not None:
        return (jnp.asarray(carry0["x"], cfg.dtype),
                jnp.asarray(carry0["y"], cfg.dtype),
                jnp.asarray(carry0["rx"], cfg.dtype),
                jnp.asarray(carry0["ry"], cfg.dtype))
    if x0 is None:
        x = jnp.zeros((n,), cfg.dtype)
        return x, x, -b, -b
    x = jnp.asarray(x0, cfg.dtype)
    rx = operand_matvec(A, x) - b
    return x, x, rx, rx


# ---------------------------------------------------------------------------
# Classical SFISTA: one (mu, mu + 1) fused Allreduce per iteration.
# ---------------------------------------------------------------------------

def sfista(problem: SFISTAProblem, cfg: SolverConfig,
           axis_name: Optional[object] = None,
           x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Sampled FISTA (block proximal gradient + subspace momentum).

    x0: optional warm start (replicated (n,)); the momentum restarts
    (y = x0) and both residuals rebuild locally — no communication.
    state: optional checkpointed :class:`SolveState` — resumes x, y, rx,
    ry and the t-schedule at the recorded global iteration (the schedule
    is deterministic, so recomputing over ``start + H`` steps reproduces
    the uninterrupted prefix bitwise).
    """
    A, b, n, mu, prox = _prep(problem, cfg)
    block_gram, block_apply = col_block_ops(A, cfg)
    key = jax.random.key(cfg.seed)
    H = cfg.iterations
    carry0 = resume_carry(state, x0, "sfista")
    start = 0 if state is None else int(state.iteration)
    ts = linalg.fista_t_schedule(start + H, cfg.dtype)    # (start+H+1,)
    x0_, y0, rx0, ry0 = _init_iterates(A, b, n, cfg, x0, carry0)

    def step(carry, inputs):
        x, y, rx, ry = carry
        h, t_prev, t_cur = inputs
        idx = linalg.sample_block(jax.random.fold_in(key, h), n, mu)
        # --- Communication: one fused Allreduce of [G | A_B^T ry] ---
        Ah, local = block_gram(idx, ry[:, None])          # (mu, mu+1) local
        GR = linalg.preduce(local, axis_name)
        G, g = GR[:, :mu], GR[:, mu]
        v = linalg.power_iteration_max_eig(G, cfg.power_iters)
        eta = 1.0 / linalg.floor_eig(v)   # floored: zero block -> no-op
        yB = y[idx]
        d = prox(yB - eta * g, eta) - yB
        x_new = y.at[idx].add(d)                          # prox step on y
        rx_new = ry + block_apply(Ah, d)                  # A x_new - b
        beta = (t_prev - 1.0) / t_cur
        w = yB + d - x[idx]                               # x_B^h - x_B^{h-1}
        y_new = x_new.at[idx].add(beta * w)               # subspace momentum
        ry_new = ry + block_apply(Ah, d + beta * w)
        obj = _objective(rx_new, x_new, problem, axis_name) \
            if cfg.track_objective else jnp.asarray(0.0, cfg.dtype)
        return (x_new, y_new, rx_new, ry_new), obj

    hs = jnp.arange(start + 1, start + H + 1)
    (x, y, rx, ry), objs = jax.lax.scan(
        step, (x0_, y0, rx0, ry0), (hs, ts[start:-1], ts[start + 1:]))
    return SolverResult(x=x, objective=objs,
                        aux={"residual": rx,
                             "state": SolveState(
                                 start + H,
                                 {"x": x, "y": y, "rx": rx, "ry": ry}),
                             **spmm_aux(A, cfg, "col_gram", extra=1)})


# ---------------------------------------------------------------------------
# CA-SFISTA: the s-step unroll, as an engine FamilyProgram.
# ---------------------------------------------------------------------------

def _ca_setup(problem, cfg, axis_name, x0, carry0):
    A, b, n, mu, prox = _prep(problem, cfg)
    ctx = Ctx(A=A, b=b, n=n, mu=mu, prox=prox,
              sparse=isinstance(A, SparseOperand),
              block_gram=col_block_ops(A, cfg)[0],
              m_loc=A.shape[0], problem=problem, cfg=cfg,
              axis_name=axis_name)
    return ctx, _init_iterates(A, b, n, cfg, x0, carry0)


def _ca_sample(ctx, key):
    return linalg.sample_block(key, ctx.n, ctx.mu)


def _ca_schedule(ctx, cfg, total):
    return linalg.fista_t_schedule(total, cfg.dtype)      # (total+1,)


def _ca_assemble(ctx, carry, idxs, s_grp):
    x, y, rx, ry = carry
    flat = idxs.reshape(s_grp * ctx.mu)
    if ctx.sparse:
        return ctx.block_gram(flat, ry[:, None])
    Y = ctx.A[:, flat]                                # (m_loc, s*mu) local
    return Y, gram_local(Y, ry[:, None], ctx.cfg.use_pallas)


def _ca_reduce(ctx, local, idxs, s_grp):
    return reduce_gram_proj(local, s_grp * ctx.mu, 1, ctx.axis_name,
                            ctx.cfg.symmetric_gram)


def _ca_inner(ctx, carry, handle, payload, idxs, win, s):
    x, y, rx, ry = carry
    cfg, mu = ctx.cfg, ctx.mu
    G, P = payload
    G4 = G.reshape(s, mu, s, mu)
    ry_proj = P[:, 0].reshape(s, mu)                  # A_j^T ry_sk
    th_prev, th_cur = win
    betas = (th_prev - 1.0) / th_cur

    def inner(inner_carry, j):
        x, y, c_buf, d_buf = inner_carry
        idx_j = idxs[j]
        Gj = G4[j]                                    # (mu, s, mu)
        # ry_{j-1} = ry_sk + sum_t A_{B_t} c_t, so the gradient is the
        # payload column plus Gram contractions with the recorded c_t
        # (rows t >= j are still zero).
        cross = jnp.einsum("ptq,tq->tp", Gj, c_buf)   # (s, mu)
        g = ry_proj[j] + jnp.einsum("tp->p", cross)
        v = linalg.power_iteration_max_eig(Gj[:, j, :], cfg.power_iters)
        eta = 1.0 / linalg.floor_eig(v)  # floored: zero block -> no-op
        yB = y[idx_j]
        d = ctx.prox(yB - eta * g, eta) - yB
        x_new = y.at[idx_j].add(d)                    # prox step on y
        w = yB + d - x[idx_j]                         # x_B^j - x_B^{j-1}
        beta = betas[j]
        y_new = x_new.at[idx_j].add(beta * w)         # subspace momentum
        c_buf = c_buf.at[j].set(d + beta * w)
        d_buf = d_buf.at[j].set(d)
        out = x_new if cfg.track_objective else None
        return (x_new, y_new, c_buf, d_buf), out

    init = (x, y, jnp.zeros((s, mu), cfg.dtype), jnp.zeros((s, mu),
                                                           cfg.dtype))
    (x, y, c_buf, d_buf), xs = jax.lax.scan(inner, init, jnp.arange(s))
    return (x, y, rx, ry), (c_buf, d_buf, xs)


def _ca_defer(ctx, carry, handle, inner_out, payload, idxs, win, s):
    x, y, rx, ry = carry
    cfg = ctx.cfg
    c_buf, d_buf, xs = inner_out
    # Deferred m-dimensional steps (local GEMVs; sparse: O(nnz of the
    # sampled columns) scatter-adds): A_{B_t} c_t rebuilds the momentum
    # residual ry, A_{B_t} d_t the prox-point residual rx.
    steps_c = deferred_steps(ctx, handle, c_buf, s)   # (s, m_loc)
    steps_d = deferred_steps(ctx, handle, d_buf, s)
    cum = jnp.cumsum(steps_c, axis=0)
    prefix = ry[None, :] + cum - steps_c              # ry_{j-1} per step
    ry_new = ry + cum[-1]
    rx_new = prefix[-1] + steps_d[-1]

    if cfg.track_objective:
        r_steps = prefix + steps_d                    # rx_j per step
        objs = jax.vmap(
            lambda rr, xx: _objective(rr, xx, ctx.problem, ctx.axis_name))(
            r_steps, xs)
    else:
        objs = jnp.zeros((s,), cfg.dtype)
    return (x, y, rx_new, ry_new), objs


def _ca_finalize(ctx, carry, sched):
    x, y, rx, ry = carry
    return x, {"residual": rx}


_CA_PROGRAM = FamilyProgram(
    name="ca_sfista", setup=_ca_setup, sample=_ca_sample,
    assemble=_ca_assemble, reduce=_ca_reduce, inner=_ca_inner,
    defer=_ca_defer, finalize=_ca_finalize,
    carry_names=("x", "y", "rx", "ry"), schedule=_ca_schedule,
    spmm_kind="col_gram", spmm_extra=1)


def ca_sfista(problem: SFISTAProblem, cfg: SolverConfig,
              axis_name: Optional[object] = None,
              x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """s-step unrolled SFISTA: identical iterates to ``sfista`` in exact
    arithmetic, ONE Allreduce per s inner iterations."""
    return run_program(_CA_PROGRAM, problem, cfg, axis_name, x0, state)


# ---------------------------------------------------------------------------
# Registration: the fifth family — zero edits to dispatch, the sharded
# driver, the elastic runtime, or the CLI.
# ---------------------------------------------------------------------------

def sfista_objective(problem: SFISTAProblem, x,
                     axis_name: Optional[object] = None):
    """Direct objective evaluation 1/2 ||Ax - b||^2 + g(x) (diagnostic)."""
    A = problem.A if isinstance(problem.A, SparseOperand) \
        else jnp.asarray(problem.A)
    x = jnp.asarray(x, A.dtype)
    residual = operand_matvec(A, x) - jnp.asarray(problem.b, A.dtype)
    return _objective(residual, x, problem, axis_name)


def _cli_problem(args):
    from repro.data.sparse import make_lasso_dataset
    A, b, lam_max = make_lasso_dataset(args.dataset, args.seed)
    return SFISTAProblem(A=A, b=b, lam=args.lam_frac * lam_max)


def _cli_describe(args, res, elapsed: float) -> str:
    import numpy as np
    obj = np.asarray(res.objective)
    nnz = int(np.sum(np.abs(np.asarray(res.x)) > 1e-8))
    return (f"sfista {args.dataset} s={args.s} mu={args.mu}: "
            f"obj {obj[0]:.4f} -> {obj[-1]:.4f}, nnz(x)={nnz}, "
            f"{elapsed:.2f}s")


@register_family(
    "sfista",
    problem_cls=SFISTAProblem,
    partition="row",
    default_axes="data",
    x0_layout="replicated",
    aux_out=(("residual", "partition"),),
    variants={
        "classical": "repro.core.sfista:sfista",
        "sa": "repro.core.sfista:ca_sfista",
    },
    objective=sfista_objective,
    # same operand layout and fused-payload shapes as Lasso, so
    # Table I's Lasso entries model it.
    costs=lambda dims, H, mu, s, P, kernel="linear": cost_model.lasso_costs(
        dims, H, mu, s, P),
    make_problem=_cli_problem,
    describe=_cli_describe,
    default_mu=8,
    bench_block_size=4,
    bench_problem_kwargs={"lam": 0.1},
    # the fused payload replicates (s mu)^2 + s mu entries — same growth
    # as Lasso, so the same candidate grid applies.
    tune_space={"s": (1, 2, 4, 8, 16, 32), "mu": (1, 2, 4, 8, 16)},
    supports_symmetric_gram=True,
    state_layout=lambda cfg: (("x", "replicated"), ("y", "replicated"),
                              ("rx", "partition"), ("ry", "partition")),
)
def solve_sfista(problem: SFISTAProblem, cfg: SolverConfig,
                 axis_name: Optional[object] = None,
                 x0=None, state=None) -> SolverResult:
    """Dispatch on cfg.s: classical SFISTA vs the CA-SFISTA unroll."""
    if cfg.s > 1:
        return ca_sfista(problem, cfg, axis_name, x0, state)
    return sfista(problem, cfg, axis_name, x0, state)
