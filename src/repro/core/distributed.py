"""Distributed drivers for the SA solvers: shard_map over the production
mesh, reproducing the paper's data layouts (Fig. 1 / Sec. V):

* Lasso: A 1D-ROW-partitioned over the data-parallel axes. On the
  multi-pod mesh the reduction runs hierarchically over ('pod', 'data')
  — psum over a tuple of axes lowers to the hierarchical collective
  schedule on the torus.
* SVM:   A 1D-COLUMN-partitioned over the model axis.

Rows/columns are zero-padded to a multiple of the shard count. Zero
padding is exact for every quantity the solvers compute:
  - Lasso: padded rows contribute 0 to A_h^T A_h and A_h^T r, and padded
    b entries are 0 so the padded residual coordinates stay 0 forever.
  - SVM: padded columns contribute 0 to ||A_i||^2 and A_i x, and the
    corresponding x coordinates stay 0.

The drivers jit the whole solve: ONE compiled program containing the full
scan-over-iterations, whose HLO exhibits exactly H/s collectives — this is
what ``benchmarks/collective_count.py`` verifies structurally.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import lasso as lasso_lib, svm as svm_lib
from repro.core.types import LassoProblem, SVMProblem, SolverConfig, SolverResult

AxisNames = Union[str, Tuple[str, ...]]


def _pad_to(x: np.ndarray, size: int, axis: int) -> np.ndarray:
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def _axis_size(mesh: Mesh, axes: AxisNames) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def solve_lasso_sharded(problem: LassoProblem, cfg: SolverConfig,
                        mesh: Mesh, axes: AxisNames = "data") -> SolverResult:
    """Row-partitioned distributed Lasso solve (classical or SA).

    ``axes`` may be a single mesh axis or a tuple (e.g. ('pod', 'data')) —
    the allreduce then spans pods hierarchically.
    """
    n_shards = _axis_size(mesh, axes)
    A = np.asarray(problem.A)
    b = np.asarray(problem.b)
    m = A.shape[0]
    m_pad = -(-m // n_shards) * n_shards
    A = _pad_to(A, m_pad, 0)
    b = _pad_to(b, m_pad, 0)

    row_spec = P(axes) if isinstance(axes, str) else P(tuple(axes))
    a_spec = P(row_spec[0], None)

    def local_solve(A_loc, b_loc):
        local_problem = LassoProblem(A=A_loc, b=b_loc, lam=problem.lam,
                                     l2=problem.l2, groups=problem.groups)
        res = lasso_lib.solve_lasso(local_problem, cfg, axis_name=axes)
        return res.x, res.objective, res.aux["residual"]

    fn = shard_map(local_solve, mesh=mesh,
                   in_specs=(a_spec, row_spec),
                   out_specs=(P(), P(), row_spec),
                   check_rep=False)
    x, objs, residual = jax.jit(fn)(jnp.asarray(A, cfg.dtype),
                                    jnp.asarray(b, cfg.dtype))
    return SolverResult(x=x, objective=objs, aux={"residual": residual[:m]})


def solve_svm_sharded(problem: SVMProblem, cfg: SolverConfig,
                      mesh: Mesh, axes: AxisNames = "model") -> SolverResult:
    """Column-partitioned distributed SVM solve (classical or SA)."""
    n_shards = _axis_size(mesh, axes)
    A = np.asarray(problem.A)
    n = A.shape[1]
    n_pad = -(-n // n_shards) * n_shards
    A = _pad_to(A, n_pad, 1)

    col_spec = P(None, axes) if isinstance(axes, str) else P(None, tuple(axes))
    x_spec = P(axes) if isinstance(axes, str) else P(tuple(axes))

    def local_solve(A_loc, b_full):
        local_problem = SVMProblem(A=A_loc, b=b_full, lam=problem.lam,
                                   loss=problem.loss,
                                   kernel=problem.kernel,
                                   kernel_params=problem.kernel_params)
        res = svm_lib.solve_svm(local_problem, cfg, axis_name=axes)
        return res.x, res.objective, res.aux["alpha"]

    fn = shard_map(local_solve, mesh=mesh,
                   in_specs=(col_spec, P()),
                   out_specs=(x_spec, P(), P()),
                   check_rep=False)
    x, objs, alpha = jax.jit(fn)(jnp.asarray(A, cfg.dtype),
                                 jnp.asarray(problem.b, cfg.dtype))
    return SolverResult(x=x[:n], objective=objs, aux={"alpha": alpha})


def lower_lasso_step(cfg: SolverConfig, mesh: Mesh, m: int, n: int,
                     axes: AxisNames = "data", dtype=jnp.float32):
    """Lower (without executing) a full distributed Lasso solve for shape
    (m, n) — used by the dry-run and the collective-count benchmark.

    Returns the ``jax.stages.Lowered`` object.
    """
    row_spec = P(axes) if isinstance(axes, str) else P(tuple(axes))
    a_spec = P(row_spec[0], None)

    def local_solve(A_loc, b_loc):
        prob = LassoProblem(A=A_loc, b=b_loc, lam=0.1)
        res = lasso_lib.solve_lasso(prob, cfg, axis_name=axes)
        return res.x, res.objective

    fn = shard_map(local_solve, mesh=mesh, in_specs=(a_spec, row_spec),
                   out_specs=(P(), P()), check_rep=False)
    A_spec = jax.ShapeDtypeStruct((m, n), dtype)
    b_spec = jax.ShapeDtypeStruct((m,), dtype)
    return jax.jit(fn).lower(A_spec, b_spec)


def lower_svm_step(cfg: SolverConfig, mesh: Mesh, m: int, n: int,
                   axes: AxisNames = "model", dtype=jnp.float32,
                   kernel: str = "linear", kernel_params=None):
    """Lower a full distributed SVM solve for shape (m, n); ``kernel``
    routes through the kernelized (SA-)K-BDCD solvers."""
    col_spec = P(None, axes) if isinstance(axes, str) else P(None, tuple(axes))
    x_spec = P(axes) if isinstance(axes, str) else P(tuple(axes))

    def local_solve(A_loc, b_full):
        prob = SVMProblem(A=A_loc, b=b_full, lam=1.0, kernel=kernel,
                          kernel_params=kernel_params)
        res = svm_lib.solve_svm(prob, cfg, axis_name=axes)
        return res.x, res.objective

    fn = shard_map(local_solve, mesh=mesh, in_specs=(col_spec, P()),
                   out_specs=(x_spec, P()), check_rep=False)
    A_spec = jax.ShapeDtypeStruct((m, n), dtype)
    b_spec = jax.ShapeDtypeStruct((m,), dtype)
    return jax.jit(fn).lower(A_spec, b_spec)
