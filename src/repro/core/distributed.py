"""Legacy distributed entry points — thin shims over the generic
registry-driven driver in ``repro.core.api``.

Historically this module hand-built the shard_map/pad/unpad plumbing
separately for the Lasso (1D-row) and SVM (1D-column) layouts. That
duplication now lives ONCE in ``repro.core.api.solve_sharded`` /
``lower_solve``, parameterized by each family's declared partition axis;
these wrappers only preserve the old names and signatures (and are what
the shim-equivalence tests in tests/test_api.py pin down: same compiled
program, bit-identical results).

Layout reminder (see ``repro.core.api._specs``): Lasso rows are sharded
over the data axes (reductions may span ('pod', 'data') hierarchically);
SVM/K-SVM columns over the model axis. Zero padding is exact for every
family — padded rows/columns contribute 0 to every Gram/cross product.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import api
from repro.core.api import _axis_size, _pad_to  # noqa: F401  (re-export)
from repro.core.types import (LassoProblem, SVMProblem, SolverConfig,
                              SolverResult)

AxisNames = Union[str, Tuple[str, ...]]


def solve_lasso_sharded(problem: LassoProblem, cfg: SolverConfig,
                        mesh: Mesh, axes: AxisNames = "data") -> SolverResult:
    """Row-partitioned distributed Lasso solve (classical or SA)."""
    return api.solve_sharded(problem, cfg, mesh, axes=axes, family="lasso")


def solve_svm_sharded(problem: SVMProblem, cfg: SolverConfig,
                      mesh: Mesh, axes: AxisNames = "model") -> SolverResult:
    """Column-partitioned distributed SVM solve (classical or SA; the
    family — linear BDCD vs kernelized K-BDCD — follows problem.kernel)."""
    return api.solve_sharded(problem, cfg, mesh, axes=axes)


def lower_lasso_step(cfg: SolverConfig, mesh: Mesh, m: int, n: int,
                     axes: AxisNames = "data", dtype=jnp.float32):
    """Lower (without executing) a full distributed Lasso solve for shape
    (m, n) — used by the dry-run and the collective-count benchmark.

    Returns the ``jax.stages.Lowered`` object.
    """
    return api.lower_solve("lasso", cfg, mesh, m, n, axes=axes, dtype=dtype,
                           problem_kwargs={"lam": 0.1})


def lower_svm_step(cfg: SolverConfig, mesh: Mesh, m: int, n: int,
                   axes: AxisNames = "model", dtype=jnp.float32,
                   kernel: str = "linear", kernel_params=None):
    """Lower a full distributed SVM solve for shape (m, n); ``kernel``
    routes through the kernelized (SA-)K-BDCD solvers."""
    family = "svm" if kernel == "linear" else "ksvm"
    return api.lower_solve(
        family, cfg, mesh, m, n, axes=axes, dtype=dtype,
        problem_kwargs={"lam": 1.0, "kernel": kernel,
                        "kernel_params": kernel_params})
