"""Proximal operators and objective functions (paper Sec. I, Eq. 2).

All operators are elementwise / blockwise jnp functions usable inside jit,
scan and shard_map. The solvers call ``make_prox`` once to bind a problem's
regularizer into a ``prox(v, eta) -> v`` closure.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp


def soft_threshold(v, alpha):
    """S_alpha(v) = sign(v) * max(|v| - alpha, 0)   (paper Eq. 2)."""
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - alpha, 0.0)


def elastic_net_prox(v, eta, l1, l2):
    """prox of eta * (l1 ||x||_1 + l2 ||x||_2^2): shrink then scale."""
    return soft_threshold(v, eta * l1) / (1.0 + 2.0 * eta * l2)


def group_soft_threshold(v, alpha):
    """Block soft-threshold for group lasso: v * max(0, 1 - alpha/||v||_2).

    ``v`` is one whole group (the solvers sample whole groups when a group
    structure is present, so a block == a group).
    """
    norm = jnp.linalg.norm(v)
    scale = jnp.maximum(0.0, 1.0 - alpha / jnp.maximum(norm, 1e-30))
    return v * scale


def make_prox(lam: float, l2: float = 0.0, groups: Optional[object] = None
              ) -> Callable:
    """Bind a regularizer into prox(v, eta).

    lam/l2 follow the paper's three regularizers:
      lasso:        g(x) = lam ||x||_1
      elastic-net:  g(x) = lam_2 ||x||_2^2 + lam_1 ||x||_1
      group lasso:  g(x) = lam sum_g ||x_g||_2   (v = one group)
    """
    if groups is not None:
        return lambda v, eta: group_soft_threshold(v, eta * lam)
    if l2 != 0.0:
        return lambda v, eta: elastic_net_prox(v, eta, lam, l2)
    return lambda v, eta: soft_threshold(v, eta * lam)


def reg_value(x, lam: float, l2: float = 0.0, groups=None):
    """g(x) for the objective trace."""
    if groups is not None:
        # sum of group norms; groups is a *host-side* (n,) int array of group
        # ids (static — numpy, not a tracer, so the group count is concrete).
        import numpy as np
        groups = np.asarray(groups)
        n_groups = int(np.max(groups)) + 1
        sq = jnp.zeros(n_groups, dtype=x.dtype).at[groups].add(x * x)
        return lam * jnp.sum(jnp.sqrt(sq))
    val = lam * jnp.sum(jnp.abs(x))
    if l2 != 0.0:
        val = val + l2 * jnp.sum(x * x)
    return val


def lasso_objective(residual, x, lam: float, l2: float = 0.0, groups=None):
    """f(A,b,x) + g(x) with residual = Ax - b (paper Sec. IV-A)."""
    return 0.5 * jnp.sum(residual * residual) + reg_value(x, lam, l2, groups)
