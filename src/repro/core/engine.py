"""The generic SA s-step engine: ONE unrolled driver for every
synchronization-avoiding solver family.

The paper's core construction — sample all s blocks up front, fuse the
group's Gram/cross products into ONE Allreduce, run the s dependent
inner updates redundantly on replicated data, apply the deferred
O(nnz)/dense updates — was hand-cloned four times (Lasso, accelerated
Lasso, linear SVM, kernel SVM, logreg). Every copy duplicated the same
scaffolding around a family-specific recurrence:

  * ``run_grouped`` scheduling: floor(H/s) full groups in one lax.scan
    plus ONE remainder tail group of H mod s iterations;
  * global ``fold_in`` iteration ids (h = start + j), so SA and
    classical solvers draw bit-identical block sequences and a resumed
    solve continues the uninterrupted schedule;
  * :class:`~repro.core.types.SolveState` resume (restore the named
    recurrence leaves + RNG/schedule offset at an outer boundary);
  * θ/momentum schedules, precomputed over the FULL horizon and sliced
    per group with ``dynamic_slice`` — the remainder tail reads the
    same array at its global offset, so the schedule prefix is
    preserved bitwise no matter how H splits into groups;
  * objective stitching into one (H,) trace;
  * VMEM-guarded Pallas↔ref dispatch surfaced as "main+tail" impl
    labels when the tail group dispatches differently;
  * the single-Allreduce-per-outer-iteration contract.

A family now supplies only the algorithm as a :class:`FamilyProgram` —
sampled-block assembly, the fused-Allreduce payload, the inner update
rule, the deferred application and objective recurrence, plus its carry
schema — and :func:`run_program` owns everything else. The callback
seams follow the phase structure every SA method shares:

    setup -> [per outer group: sample -> assemble -> reduce -> inner
              -> defer] -> finalize

``assemble`` builds the LOCAL (pre-reduce) payload, ``reduce`` performs
the group's ONE collective, ``inner`` runs the s dependent updates on
the replicated reduced data, ``defer`` applies the m/n-dimensional
updates and stitches the objective trace. See DESIGN.md "The SA
engine" for the contract and a family-authoring guide.
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.sparse_exec import spmm_aux
from repro.core.types import (SolveState, SolverResult, resume_carry)
from repro.kernels import spmm
from repro.kernels.gram import gram_t

__all__ = [
    "FamilyProgram", "run_program", "run_grouped", "grouped_impl_label",
    "gram_local", "reduce_gram_proj", "gram_and_proj", "sample_all",
    "deferred_steps",
]


# ---------------------------------------------------------------------------
# Grouped outer-loop scheduling (moved here from repro.core.sa_loop, which
# remains as a compatibility shim).
# ---------------------------------------------------------------------------

def run_grouped(group, carry, H: int, s: int, dtype, start: int = 0):
    """Run ``group(carry, start, s_grp) -> (carry, objs (s_grp,))`` over
    the full schedule; returns (carry, objs (H,)).

    floor(H/s) full s-step groups run inside one lax.scan, then ONE
    remainder tail group of H mod s iterations (the group body is
    shape-parameterized, so the tail is just a second trace at a smaller
    group size). ceil(H/s) Allreduces total, exactly H inner iterations.
    H < s degenerates to a single tail group with zero scan trips.

    ``start`` (a host int) offsets the global iteration ids — a solve
    resumed from a checkpointed :class:`~repro.core.types.SolveState`
    at iteration ``start`` passes it here so the groups keep the
    uninterrupted schedule's ``fold_in`` ids. Checkpoints are taken at
    outer-iteration boundaries, so ``start`` is a multiple of the
    original run's s whenever group alignment matters (DESIGN.md
    "Elastic recovery of SA recurrences")."""
    K, rem = divmod(H, s)
    objs = jnp.zeros((0,), dtype)
    if K:        # full s-step groups
        carry, objs = jax.lax.scan(
            lambda c, k: group(c, start + k * s, s), carry, jnp.arange(K))
        objs = objs.reshape(K * s)
    if rem:      # remainder tail group: the last H mod s iterations
        carry, objs_tail = group(carry, jnp.asarray(start + K * s), rem)
        objs = jnp.concatenate([objs, objs_tail])
    return carry, objs


def grouped_impl_label(impl_fn, H: int, s: int, mu: int,
                       use_pallas: bool, itemsize: int = 4) -> str:
    """The inner-loop implementation(s) the grouped schedule actually
    runs: the tail group dispatches at (H mod s, mu), which can differ
    from the full groups' (s, mu) — e.g. an over-VMEM s falls back to
    "ref" while a small tail still runs "pallas". Mixed runs are
    labeled "main+tail" so benchmarks never mislabel the timings.
    ``itemsize`` is the solve dtype's bytes/element (the VMEM guards are
    dtype-aware)."""
    K, rem = divmod(H, s)
    labels = ([impl_fn(s, mu, use_pallas, itemsize)] if K else []) \
        + ([impl_fn(rem, mu, use_pallas, itemsize)] if rem else [])
    if len(set(labels)) == 1:
        return labels[0]
    return "+".join(labels)


# ---------------------------------------------------------------------------
# Fused Gram/projection payload helpers (moved here from repro.core.sa_lasso;
# shared by the Lasso, SVM and SFISTA programs).
# ---------------------------------------------------------------------------

def reduce_gram_proj(local, smu, vec_cols, axis_name,
                     symmetric: bool = False):
    """ONE fused Allreduce of the LOCAL (smu, smu + k) Gram/projection
    block -> (G, P) replicated, with G (smu, smu) and P (smu, k).

    symmetric (``SolverConfig.symmetric_gram``, paper footnote 3): G is
    symmetric, so communicating only its lower triangle halves the message
    size — ~2x less W at O(s^2 mu^2) local pack/unpack reshuffling. The
    reduced values are identical, only their layout changes.
    """
    if symmetric:
        il, jl = jnp.tril_indices(smu)
        packed = jnp.concatenate(
            [local[:, :smu][il, jl], local[:, smu:].reshape(-1)])
        packed = linalg.preduce(packed, axis_name)
        ntri = il.shape[0]
        G = jnp.zeros((smu, smu), local.dtype).at[il, jl].set(packed[:ntri])
        G = G + jnp.tril(G, -1).T
        P = packed[ntri:].reshape(smu, vec_cols)
        return G, P
    out = linalg.preduce(local, axis_name)
    return out[:, :smu], out[:, smu:]


def gram_local(Y, vecs, use_pallas: bool = False):
    """LOCAL fused Gram/projection block  Y^T @ [Y | vecs]  (the
    pre-Allreduce half of paper Alg. 2 lines 11-12).

    Y: (m_loc, s*mu) sampled columns; vecs: (m_loc, k) residual-like
    vectors. ``use_pallas`` routes the GEMM through the
    ``repro.kernels.gram`` Pallas kernel (f32 MXU accumulation)."""
    rhs = jnp.concatenate([Y, vecs], axis=1)
    if use_pallas:
        return gram_t(Y, rhs, use_pallas=True).astype(Y.dtype)
    return Y.T @ rhs


def gram_and_proj(Y, vecs, axis_name, symmetric: bool = False,
                  use_pallas: bool = False):
    """ONE fused Allreduce:  Y^T @ [Y | vecs]  — :func:`gram_local`
    followed by :func:`reduce_gram_proj`. Returns (G, P) with G
    (s*mu, s*mu) and P (s*mu, k), replicated."""
    local = gram_local(Y, vecs, use_pallas)
    return reduce_gram_proj(local, Y.shape[1], vecs.shape[1], axis_name,
                            symmetric)


def sample_all(key, sampler, start, s_grp):
    """Sample the s_grp blocks of the outer group starting after global
    iteration id ``start``, matching the non-SA fold_in indices
    (h = start + j, j = 1..s_grp) so SA and non-SA draw bit-identical
    coordinate sequences."""
    hs = start + 1 + jnp.arange(s_grp)
    return jax.vmap(lambda h: sampler(jax.random.fold_in(key, h)))(hs)


def deferred_steps(ctx, handle, buf, s_grp):
    """The deferred m-dimensional step vectors  S_t = A_{B_t} @ buf_t
    (s_grp, m_loc) for the column-sampling layout: a local GEMV per
    step (sparse: O(nnz of the sampled columns) scatter-adds). ``ctx``
    must carry ``sparse``, ``mu`` and ``m_loc`` (see the Lasso/SFISTA
    programs)."""
    if ctx.sparse:
        rows_g, vals_g, _ = handle
        return spmm.scatter_steps(rows_g.reshape(s_grp, ctx.mu, -1),
                                  vals_g.reshape(s_grp, ctx.mu, -1),
                                  buf, ctx.m_loc)
    return jnp.einsum("msc,sc->sm",
                      handle.reshape(ctx.m_loc, s_grp, ctx.mu), buf)


# ---------------------------------------------------------------------------
# The program spec + the ONE generic unrolled driver.
# ---------------------------------------------------------------------------

Ctx = SimpleNamespace   # programs stash whatever their callbacks close over


@dataclasses.dataclass(frozen=True)
class FamilyProgram:
    """A solver family's s-step program: the six callback seams plus the
    declarative fields the engine needs to own scheduling, resume,
    checkpoint schema and impl labels.

    Callback contract (``ctx`` is the namespace ``setup`` returns;
    ``carry`` is the family's recurrence-leaf tuple, ordered as
    ``carry_names``; ``s_grp`` is the group size — ``cfg.s`` for full
    groups, ``H mod s`` for the remainder tail; ``win`` is the sliced
    ``(sched[start : start+s_grp], sched[start+1 : start+s_grp+1])``
    schedule window, or None for schedule-free families):

    setup(problem, cfg, axis_name, x0, carry0) -> (ctx, carry)
        Prepare operands/closures and build the initial carry — from the
        restored ``carry0`` dict (a SolveState resume), from ``x0`` (a
        warm start), or from zero. The engine has already enforced
        state/x0 mutual exclusion via ``resume_carry``.
    sample(ctx, key) -> (mu,) int block
        Draw ONE iteration's coordinate block. The engine vmaps this
        over the group's ``fold_in`` iteration ids.
    assemble(ctx, carry, idxs, s_grp) -> (handle, local)
        Build the LOCAL (pre-reduce) fused payload for the group's
        sampled blocks ``idxs`` (s_grp, mu). ``handle`` is whatever the
        deferred application needs later (the dense sampled columns, a
        sparse gather handle, ...).
    reduce(ctx, local, idxs, s_grp) -> payload
        The group's ONE Allreduce (+ any post-reduce transform applied
        to the replicated copy, e.g. kernelization). Nothing else in the
        program may communicate — this seam IS the
        one-Allreduce-per-outer-iteration contract.
    inner(ctx, carry, handle, payload, idxs, win, s_grp)
        -> (carry, inner_out)
        The s_grp dependent inner updates, redundantly on replicated
        O(s*mu)-sized data (plus any replicated R^n/R^m leaves the
        family maintains densely).
    defer(ctx, carry, handle, inner_out, payload, idxs, win, s_grp)
        -> (carry, objs (s_grp,))
        Apply the deferred O(nnz)/dense updates and stitch the per-inner-
        iteration objective trace (zeros when ``cfg.track_objective`` is
        off).
    finalize(ctx, carry, sched) -> (x, aux_extra dict)
        Map the final carry to the solution vector and the family's
        extra aux entries (residuals, duals, ...).

    Declarative fields:

    carry_names: the SolveState leaf names, in carry order — the
        engine builds ``aux["state"]`` from these, so they must match
        the family's registered ``state_layout`` exactly.
    schedule(ctx, cfg, total) -> (total + 1,) array, optional
        Deterministic acceleration/momentum schedule over the FULL
        (resume-offset + H) horizon. The engine slices each group's
        window out of this one array with ``dynamic_slice`` at the
        group's global offset — which is what keeps the remainder
        tail's schedule prefix bitwise identical to the uninterrupted
        schedule.
    uses_svm_inner: surface the ``repro.kernels.svm_inner`` dispatch as
        ``aux["inner_impl"]`` with main+tail labels.
    spmm_kind / spmm_extra: the sparse-execution layout of the fused
        payload ("col_gram" / "row_gram" / "cross" + appended-vector
        count) — the engine derives ``aux["spmm_impl"]`` from it (ONE
        place, so the label cannot drift from the dispatched shapes).
        Requires ``ctx.A`` to be the prepared operand.
    """

    name: str
    setup: Callable
    sample: Callable
    assemble: Callable
    reduce: Callable
    inner: Callable
    defer: Callable
    finalize: Callable
    carry_names: Tuple[str, ...]
    schedule: Optional[Callable] = None
    uses_svm_inner: bool = False
    spmm_kind: Optional[str] = None
    spmm_extra: int = 0


def run_program(prog: FamilyProgram, problem, cfg, axis_name=None,
                x0=None, state=None) -> SolverResult:
    """Run a :class:`FamilyProgram` over the full grouped schedule.

    Owns everything the hand-cloned SA solvers used to duplicate: the
    resume offset, the replicated RNG key and global ``fold_in`` ids,
    schedule precompute + per-group window slicing, ``run_grouped``
    (full groups + remainder tail), SolveState assembly from the carry
    schema, and the Pallas↔ref impl labels."""
    carry0 = resume_carry(state, x0, prog.name)
    h0 = 0 if state is None else int(state.iteration)
    ctx, carry = prog.setup(problem, cfg, axis_name, x0, carry0)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    sched = None if prog.schedule is None \
        else prog.schedule(ctx, cfg, h0 + H)       # (h0 + H + 1,)

    def group(carry, start, s_grp):
        idxs = sample_all(key, lambda k: prog.sample(ctx, k),
                          start, s_grp)            # (s_grp, mu)
        win = None if sched is None else (
            jax.lax.dynamic_slice(sched, (start,), (s_grp,)),
            jax.lax.dynamic_slice(sched, (start + 1,), (s_grp,)))
        # --- Communication: assemble locally, reduce ONCE ---
        handle, local = prog.assemble(ctx, carry, idxs, s_grp)
        payload = prog.reduce(ctx, local, idxs, s_grp)
        # --- the s_grp dependent inner updates, then deferred apply ---
        carry, inner_out = prog.inner(ctx, carry, handle, payload, idxs,
                                      win, s_grp)
        return prog.defer(ctx, carry, handle, inner_out, payload, idxs,
                          win, s_grp)

    carry, objs = run_grouped(group, carry, H, s, cfg.dtype, start=h0)
    x, extra = prog.finalize(ctx, carry, sched)
    aux = dict(extra)
    aux["state"] = SolveState(h0 + H, dict(zip(prog.carry_names, carry)))
    itemsize = jnp.dtype(cfg.dtype).itemsize
    if prog.uses_svm_inner:
        from repro.kernels.svm_inner import inner_impl
        aux["inner_impl"] = grouped_impl_label(
            inner_impl, H, s, cfg.block_size, cfg.use_pallas, itemsize)
    if prog.spmm_kind is not None:
        aux.update(spmm_aux(ctx.A, cfg, prog.spmm_kind, H=H,
                            extra=prog.spmm_extra))
    return SolverResult(x=x, objective=objs, aux=aux)
