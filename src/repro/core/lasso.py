"""Classical (synchronous) coordinate-descent solvers for proximal
least-squares — paper Algorithm 1 and its non-accelerated / single-coordinate
variants (accBCD, BCD, accCD, CD).

All solvers are pure JAX, jit/scan-based, and run either

* single-device: ``axis_name=None``, A is the full (m, n) matrix; or
* distributed:   inside ``shard_map`` with A 1D-row-partitioned and
  ``axis_name`` naming the mesh axis (or tuple of axes) to reduce over.
  Vectors in R^m (residuals) are row-partitioned like A; vectors in R^n
  (solutions) and all scalars are replicated — exactly Figure 1 of the
  paper.

Communication structure (the object of study): each iteration performs ONE
fused Allreduce of the (mu x mu) Gram block and the (mu,) projection — the
paper's "Communication: lines 8 and 9".
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cost_model, linalg, prox as prox_lib
from repro.core.sparse_exec import col_block_ops, prep_operand, spmm_aux
from repro.core.types import (LassoProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, operand_matvec,
                              register_family, require_unit_block,
                              resume_carry)


def _validate_groups(groups, n: int, mu: int) -> None:
    """Enforce the documented group-lasso contract (DESIGN.md): groups
    are contiguous, equal-sized blocks of exactly mu coordinates.

    Both violations used to be silent wrong answers: with mu not
    dividing n, ``n_groups = n // mu`` drops the last ``n % mu``
    coordinates from the sampler — they are never updated; a groups
    array that isn't contiguous mu-blocks makes the block prox shrink
    sets of coordinates that aren't the declared groups.
    """
    import numpy as np
    if n % mu != 0:
        raise ValueError(
            f"group lasso requires block_size (the group size) to divide "
            f"n: got n={n}, block_size={mu} — the trailing {n % mu} "
            f"coordinates would never be sampled or updated")
    g = np.asarray(groups)
    if g.shape != (n,):
        raise ValueError(
            f"groups must be an (n,) array of group ids; got shape "
            f"{g.shape} for n={n}")
    # contract: each consecutive mu-sized block carries ONE group id,
    # and no id spans two blocks. The ids themselves may be any
    # distinct labels (the prox is blockwise and the objective
    # partitions by label, so relabeling does not change the solve).
    blocks = g.reshape(n // mu, mu)
    uniform = (blocks == blocks[:, :1]).all()
    labels = blocks[:, 0]
    if not uniform or len(np.unique(labels)) != labels.size:
        raise ValueError(
            "groups must label contiguous, equal-sized blocks of "
            "block_size coordinates (one distinct group id per "
            "mu-sized block); the provided array does not — reorder "
            "the features or adjust cfg.block_size to the group size")


def _prep(problem: LassoProblem, cfg: SolverConfig):
    A = prep_operand(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    n = A.shape[1]
    mu = cfg.block_size
    if problem.groups is not None:
        _validate_groups(problem.groups, n, mu)
        n_groups = n // mu
        q = n_groups
        def sampler(key):
            return linalg.sample_group(key, n_groups, mu)
    else:
        q = -(-n // mu)  # ceil(n / mu)
        def sampler(key):
            return linalg.sample_block(key, n, mu)
    prox = prox_lib.make_prox(problem.lam, problem.l2, problem.groups)
    return A, b, n, mu, q, sampler, prox


def _objective(residual, x, problem, axis_name):
    quad = 0.5 * linalg.preduce(jnp.sum(residual * residual), axis_name)
    return quad + prox_lib.reg_value(x, problem.lam, problem.l2, problem.groups)


# ---------------------------------------------------------------------------
# Non-accelerated BCD (mu = 1 -> CD). Richtarik–Takac style proximal step.
# ---------------------------------------------------------------------------

def bcd_lasso(problem: LassoProblem, cfg: SolverConfig,
              axis_name: Optional[object] = None,
              x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Classical (non-accelerated) randomized block coordinate descent.

    x0: optional warm start (replicated (n,) vector). The residual is
    rebuilt locally from the row shard — no communication.
    state: optional checkpointed :class:`SolveState` (carries x AND the
    residual, plus the global iteration offset) — the resumed solve
    continues the uninterrupted iterate sequence exactly.
    """
    A, b, n, mu, q, sampler, prox = _prep(problem, cfg)
    block_gram, block_apply = col_block_ops(A, cfg)
    key = jax.random.key(cfg.seed)
    carry0 = resume_carry(state, x0, "bcd_lasso")
    start = 0 if state is None else int(state.iteration)

    if carry0 is not None:
        x0 = jnp.asarray(carry0["x"], cfg.dtype)
        r0 = jnp.asarray(carry0["residual"], cfg.dtype)
    elif x0 is None:
        x0 = jnp.zeros((n,), cfg.dtype)
        r0 = -b  # residual Ax - b at x = 0 (row shard)
    else:
        x0 = jnp.asarray(x0, cfg.dtype)
        r0 = operand_matvec(A, x0) - b

    def step(carry, h):
        x, r = carry
        idx = sampler(jax.random.fold_in(key, h))
        # --- Communication: one fused Allreduce of [G | A_h^T r] ---
        Ah, local = block_gram(idx, r[:, None])           # (mu, mu+1) local
        GR = linalg.preduce(local, axis_name)
        G, rh = GR[:, :mu], GR[:, mu]
        v = linalg.power_iteration_max_eig(G, cfg.power_iters)
        eta = 1.0 / linalg.floor_eig(v)   # floored: zero block -> no-op
        g = x[idx] - eta * rh
        dx = prox(g, eta) - x[idx]
        x = x.at[idx].add(dx)
        r = r + block_apply(Ah, dx)
        obj = _objective(r, x, problem, axis_name) if cfg.track_objective else 0.0
        return (x, r), obj

    (x, r), objs = jax.lax.scan(
        step, (x0, r0), jnp.arange(start + 1, start + cfg.iterations + 1))
    return SolverResult(x=x, objective=objs,
                        aux={"residual": r,
                             "state": SolveState(start + cfg.iterations,
                                                 {"x": x, "residual": r}),
                             **spmm_aux(A, cfg, "col_gram", extra=1)})


# ---------------------------------------------------------------------------
# Accelerated BCD — paper Algorithm 1 (APPROX / Fercoq–Richtarik).
# ---------------------------------------------------------------------------

def acc_bcd_lasso(problem: LassoProblem, cfg: SolverConfig,
                  axis_name: Optional[object] = None,
                  x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Paper Algorithm 1: accelerated block coordinate descent for Lasso.

    State: z, y in R^n (replicated), ztil = Az - b, ytil = Ay in R^m
    (row-partitioned). x_h = theta_h^2 * y_h + z_h is implicit.

    x0: optional warm start — seeds z (y restarts at 0, i.e. the
    acceleration momentum resets, the standard warm-start convention).
    state: optional checkpointed :class:`SolveState` — resumes z, y,
    ztil, ytil and the theta schedule at the recorded global iteration
    (the schedule is a deterministic recurrence, so recomputing it over
    ``start + H`` steps reproduces the uninterrupted prefix bitwise).
    """
    A, b, n, mu, q, sampler, prox = _prep(problem, cfg)
    block_gram, block_apply = col_block_ops(A, cfg)
    key = jax.random.key(cfg.seed)
    H = cfg.iterations
    carry0 = resume_carry(state, x0, "acc_bcd_lasso")
    start = 0 if state is None else int(state.iteration)

    theta0 = jnp.asarray(mu / n, cfg.dtype)
    thetas = linalg.theta_schedule(theta0, start + H, q)  # (start+H+1,)

    if carry0 is not None:
        z0 = jnp.asarray(carry0["z"], cfg.dtype)
        y0 = jnp.asarray(carry0["y"], cfg.dtype)
        ztil0 = jnp.asarray(carry0["ztil"], cfg.dtype)
        ytil0 = jnp.asarray(carry0["ytil"], cfg.dtype)
    else:
        if x0 is None:
            z0 = jnp.zeros((n,), cfg.dtype)
            ztil0 = -b                                    # A z0 - b
        else:
            z0 = jnp.asarray(x0, cfg.dtype)
            ztil0 = operand_matvec(A, z0) - b
        y0 = jnp.zeros((n,), cfg.dtype)
        ytil0 = jnp.zeros_like(b)                         # A y0

    def step(carry, inputs):
        z, y, ztil, ytil = carry
        h, th_prev, th_cur = inputs
        idx = sampler(jax.random.fold_in(key, h))
        w = th_prev * th_prev * ytil + ztil               # (m_loc,)
        # --- Communication: one fused Allreduce of [G | r_h]  (lines 8-9) ---
        Ah, local = block_gram(idx, w[:, None])           # (mu, mu+1) local
        GR = linalg.preduce(local, axis_name)
        G, rh = GR[:, :mu], GR[:, mu]
        v = linalg.power_iteration_max_eig(G, cfg.power_iters)   # line 10
        eta = 1.0 / linalg.floor_eig(q * th_prev * v)     # line 11 (floored)
        g = z[idx] - eta * rh                             # line 12
        dz = prox(g, eta) - z[idx]                        # line 13
        z = z.at[idx].add(dz)                             # line 14
        Adz = block_apply(Ah, dz)                         # A_h dz (local)
        ztil = ztil + Adz                                 # line 15
        coef = (1.0 - q * th_prev) / (th_prev * th_prev)
        y = y.at[idx].add(-coef * dz)                     # line 16
        ytil = ytil - coef * Adz                          # line 17
        if cfg.track_objective:
            res = th_cur * th_cur * ytil + ztil           # A x_h - b
            x_h = th_cur * th_cur * y + z
            obj = _objective(res, x_h, problem, axis_name)
        else:
            obj = jnp.asarray(0.0, cfg.dtype)
        return (z, y, ztil, ytil), obj

    hs = jnp.arange(start + 1, start + H + 1)
    (z, y, ztil, ytil), objs = jax.lax.scan(
        step, (z0, y0, ztil0, ytil0), (hs, thetas[start:-1],
                                       thetas[start + 1:]))
    thH = thetas[-1]
    x = thH * thH * y + z                                 # line 19
    return SolverResult(x=x, objective=objs,
                        aux={"residual": thH * thH * ytil + ztil,
                             "state": SolveState(
                                 start + H, {"z": z, "y": y,
                                             "ztil": ztil, "ytil": ytil}),
                             **spmm_aux(A, cfg, "col_gram", extra=1)})


def cd_lasso(problem: LassoProblem, cfg: SolverConfig,
             axis_name: Optional[object] = None,
             x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """CD = BCD with mu = 1."""
    require_unit_block(cfg, "cd_lasso")
    return bcd_lasso(problem, cfg, axis_name, x0, state)


def acc_cd_lasso(problem: LassoProblem, cfg: SolverConfig,
                 axis_name: Optional[object] = None,
                 x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """accCD = accBCD with mu = 1."""
    require_unit_block(cfg, "acc_cd_lasso")
    return acc_bcd_lasso(problem, cfg, axis_name, x0, state)


def lasso_objective(problem: LassoProblem, x,
                    axis_name: Optional[object] = None):
    """Direct objective evaluation 1/2 ||Ax - b||^2 + g(x) (diagnostic)."""
    A = problem.A if isinstance(problem.A, SparseOperand) \
        else jnp.asarray(problem.A)
    x = jnp.asarray(x, A.dtype)
    residual = operand_matvec(A, x) - jnp.asarray(problem.b, A.dtype)
    return _objective(residual, x, problem, axis_name)


def _cli_problem(args):
    from repro.data.sparse import make_lasso_dataset
    A, b, lam_max = make_lasso_dataset(args.dataset, args.seed)
    return LassoProblem(A=A, b=b, lam=args.lam_frac * lam_max)


def _cli_describe(args, res, elapsed: float) -> str:
    import numpy as np
    obj = np.asarray(res.objective)
    nnz = int(np.sum(np.abs(np.asarray(res.x)) > 1e-8))
    return (f"lasso {args.dataset} s={args.s} mu={args.mu}: "
            f"obj {obj[0]:.4f} -> {obj[-1]:.4f}, nnz(x)={nnz}, "
            f"{elapsed:.2f}s")


@register_family(
    "lasso",
    problem_cls=LassoProblem,
    partition="row",
    default_axes="data",
    x0_layout="replicated",
    aux_out=(("residual", "partition"),),
    variants={
        "classical": "repro.core.lasso:bcd_lasso",
        "accelerated": "repro.core.lasso:acc_bcd_lasso",
        "sa": "repro.core.sa_lasso:sa_bcd_lasso",
        "sa_accelerated": "repro.core.sa_lasso:sa_acc_bcd_lasso",
    },
    objective=lasso_objective,
    costs=lambda dims, H, mu, s, P, kernel="linear": cost_model.lasso_costs(
        dims, H, mu, s, P),
    make_problem=_cli_problem,
    describe=_cli_describe,
    default_mu=8,
    bench_block_size=4,
    bench_problem_kwargs={"lam": 0.1},
    supports_symmetric_gram=True,
    state_layout=lambda cfg: (
        (("z", "replicated"), ("y", "replicated"),
         ("ztil", "partition"), ("ytil", "partition"))
        if cfg.accelerated else
        (("x", "replicated"), ("residual", "partition"))),
)
def solve_lasso(problem: LassoProblem, cfg: SolverConfig,
                axis_name: Optional[object] = None,
                x0=None, state=None) -> SolverResult:
    """Dispatch on (accelerated, s): s == 1 -> this module; s > 1 -> SA."""
    if cfg.s > 1:
        from repro.core import sa_lasso
        fn = (sa_lasso.sa_acc_bcd_lasso if cfg.accelerated
              else sa_lasso.sa_bcd_lasso)
    else:
        fn = acc_bcd_lasso if cfg.accelerated else bcd_lasso
    return fn(problem, cfg, axis_name, x0, state)
