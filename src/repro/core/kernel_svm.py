"""Kernel SVM subsystem — K-BDCD and its s-step synchronization-avoiding
unroll SA-K-BDCD (after Shao & Devarakonda, arXiv:2406.18001).

The paper's SA trick extends to kernel methods by swapping the linear
Gram block  Y Y^T  for a kernel block  K(Y, Y): the dual problem becomes

    min_a  1/2 a^T (diag(b) K(A, A) diag(b) + gamma I) a - e^T a,
    0 <= a_i <= nu

and the only structural change to (SA-)BDCD is the state vector. With a
nonlinear kernel there is no n-dimensional primal to shadow, so the
solvers maintain the replicated dual-residual vector

    f = K(A, A) (b * alpha)   in R^m

("function evaluations at every data point"). The block gradient is then
a pure gather  g_B = b_B * f[B] - 1 + gamma a_B,  and f's update needs
the m x mu kernel column block  K(A, Y)  the iteration already
communicates.

Data layout (paper Sec. V, unchanged): A is 1D-COLUMN-partitioned
(m, n_loc); alpha, b, f in R^m are replicated. Per-iteration
communication for K-BDCD: ONE fused Allreduce of the local cross
products  [A Y^T | rownorms(A)]  (the norms column rides along only for
kernels that need it, e.g. rbf). The kernel transform itself is applied
AFTER the reduction on the replicated copy, so kernelizing changes no
communication structure. SA-K-BDCD amortizes this as an engine
FamilyProgram (see ``sa_kbdcd_svm``), running the s inner updates
through the same ``repro.kernels.svm_inner`` fused Pallas kernel as the
linear solver (``cfg.use_pallas``; the chosen path lands in
``SolverResult.aux["inner_impl"]``).

``kernel="linear"`` reproduces ``bdcd_svm`` / ``sa_bdcd_svm`` iterates
exactly (f = A x by definition) — tested in tests/test_kernel_svm.py —
at O(m) replicated state instead of the (mu, mu+1) reduced message, so
``solve_svm`` keeps routing linear problems to the cheaper primal-shadow
solvers and sends everything else here.

``cfg.symmetric_gram`` does not apply (the (m, s*mu) cross block is not
symmetric) and is ignored.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model, linalg
from repro.core.engine import Ctx, FamilyProgram, run_program
from repro.core.sparse_exec import (cross_block, prep_operand,
                                    row_block_ops, spmm_aux)
from repro.core.types import (SVMProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, register_family,
                              resume_carry)
from repro.kernels import spmm
from repro.kernels.svm_inner import svm_inner_loop


def _local_norms(A, needs_norms: bool):
    """(m, 1) local partial squared row norms, computed ONCE per solve
    and re-fused into every Allreduce; None when the kernel needs
    none. Sparse operands sum their stored row values (O(nnz))."""
    if not needs_norms:
        return None
    if isinstance(A, SparseOperand):
        return jnp.sum(A.row_vals * A.row_vals, axis=1, keepdims=True)
    return jnp.sum(A * A, axis=1, keepdims=True)


def _reduce_cross(local, axis_name, norms_local):
    """ONE fused Allreduce of the LOCAL cross block ``[local | norms]``
    (the norms column rides along only when the kernel needs it)."""
    if norms_local is None:
        return linalg.preduce(local, axis_name), None
    red = linalg.preduce(
        jnp.concatenate([local, norms_local], axis=1), axis_name)
    return red[:, :-1], red[:, -1]


def _full_cross_local(A):
    """LOCAL  A A^T  (m, m) for the warm-start residual rebuild. A
    sparse A never materializes the (n_loc, m) dense transpose: the
    densified right operand is built a column-chunk at a time (chunk
    sized to ~16 MB f32) and each chunk contracts through the ELL
    arrays — peak extra memory O(n_loc * chunk), output (m, m) as the
    kernel matrix requires anyway. Values are identical to the
    unchunked product (each output entry is still one ELL row pass)."""
    if not isinstance(A, SparseOperand):
        return A @ A.T
    m, n_loc = A.shape
    chunk = int(max(1, min(m, (1 << 22) // max(n_loc, 1))))
    pieces = []
    for start in range(0, m, chunk):
        idx = jnp.arange(start, min(start + chunk, m))
        cols, vals, _ = A.gather_rows(idx)
        pieces.append(cross_block(
            A, spmm.scatter_dense(cols, vals, n_loc)))
    return jnp.concatenate(pieces, axis=1)


def _kernelize(problem: SVMProblem, cross, anorms, flat_idx, dtype):
    """Apply the registered kernel transform to the reduced cross block:
    K(A, Y)[i, j] = k(a_i, y_j), with y's norms gathered from a's."""
    spec = problem.kernel_spec
    ynorms = None if anorms is None else anorms[flat_idx]
    return spec.fn(cross, anorms, ynorms,
                   problem.kernel_params).astype(dtype)


def kernel_dual_objective(problem: SVMProblem, alpha,
                          axis_name: Optional[object] = None):
    """f_D(alpha) = 1/2 (b a)^T K (b a) + gamma/2 ||a||^2 - e^T a,
    evaluated directly from the full m x m kernel matrix (diagnostic /
    test oracle — O(m^2) memory)."""
    A = problem.A if isinstance(problem.A, SparseOperand) \
        else jnp.asarray(problem.A)
    b = jnp.asarray(problem.b, A.dtype)
    alpha = jnp.asarray(alpha, A.dtype)
    spec = problem.kernel_spec
    cross, anorms = _reduce_cross(_full_cross_local(A), axis_name,
                                  _local_norms(A, spec.needs_norms))
    Kmat = spec.fn(cross, anorms, anorms, problem.kernel_params)
    ba = b * alpha
    return 0.5 * ba @ (Kmat @ ba) \
        + 0.5 * problem.gamma * jnp.sum(alpha * alpha) - jnp.sum(alpha)


def _init_state(problem: SVMProblem, cfg: SolverConfig, axis_name,
                alpha0, carry0=None):
    """alpha, its primal shadow x = A^T (b alpha) (local shard), the
    replicated dual residual f = K(A, A)(b alpha), and the starting dual
    objective f_D(alpha0) for the incremental trace. alpha0 = None starts
    at zero, where f, x and the dual are zero without any communication.
    A restored ``carry0`` (SolveState.carry) bypasses the expensive full
    K(A, A) rebuild entirely — every leaf comes back verbatim."""
    A = prep_operand(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    if carry0 is not None:
        return (A, b, jnp.asarray(carry0["alpha"], cfg.dtype),
                jnp.asarray(carry0["x"], cfg.dtype),
                jnp.asarray(carry0["f"], cfg.dtype),
                jnp.asarray(carry0["dual"], cfg.dtype))
    if alpha0 is None:
        alpha = jnp.zeros((m,), cfg.dtype)
        f = jnp.zeros((m,), cfg.dtype)
        x = jnp.zeros((A.shape[1],), cfg.dtype)
        return A, b, alpha, x, f, jnp.asarray(0.0, cfg.dtype)
    alpha = jnp.asarray(alpha0, cfg.dtype)
    spec = problem.kernel_spec
    cross, anorms = _reduce_cross(_full_cross_local(A), axis_name,
                                  _local_norms(A, spec.needs_norms))
    Kmat = spec.fn(cross, anorms, anorms,
                   problem.kernel_params).astype(cfg.dtype)
    ba = b * alpha
    f = Kmat @ ba
    x = A.rmatvec(ba) if isinstance(A, SparseOperand) else A.T @ ba
    # f_D(alpha0), reusing the f we just built: warm-started solves resume
    # the incremental dual trace where the previous solve left it.
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    dual0 = 0.5 * ba @ f + 0.5 * gamma * jnp.sum(alpha * alpha) \
        - jnp.sum(alpha)
    return A, b, alpha, x, f, dual0


def kbdcd_svm(problem: SVMProblem, cfg: SolverConfig,
              axis_name: Optional[object] = None,
              alpha0=None, state: Optional[SolveState] = None
              ) -> SolverResult:
    """Kernel block dual coordinate descent (K-BDCD).

    Per iteration: sample a block B of mu rows, Allreduce the fused
    [A Y^T | norms] cross block (ONE message), kernelize it to the
    column block K(A, Y), and take the projected block-gradient step

        alpha_B <- clip(alpha_B - g_B / lambda_max(K_BB + gamma I), 0, nu)

    with  g_B = b_B * f[B] - 1 + gamma alpha_B  a pure gather off the
    maintained dual residual f, then  f += K(A, Y)(b_B theta). mu = 1
    skips the power iteration: the (1, 1) block k(a_i, a_i) + gamma IS
    the step size. The dual objective is tracked incrementally exactly
    as in ``bdcd_svm`` with G -> K_BB + gamma I (DESIGN.md).
    """
    mu = cfg.block_size
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    nu = jnp.asarray(problem.nu, cfg.dtype)
    key = jax.random.key(cfg.seed)
    carry0 = resume_carry(state, alpha0, "kbdcd_svm")
    start = 0 if state is None else int(state.iteration)
    A, b, alpha, x, f, dual0 = _init_state(problem, cfg, axis_name, alpha0,
                                           carry0)
    take, _, densify, apply_t = row_block_ops(A, cfg)
    norms_local = _local_norms(A, problem.kernel_spec.needs_norms)
    m = A.shape[0]
    eye_mu = jnp.eye(mu, dtype=cfg.dtype)

    def step(carry, h):
        alpha, x, f, dual = carry
        idx = linalg.sample_block(jax.random.fold_in(key, h), m, mu)
        Y = take(idx)                                    # (mu, n_loc) local
        b_B = b[idx]
        # --- Communication: ONE fused Allreduce of [A Y^T | norms] ---
        cross, anorms = _reduce_cross(
            cross_block(A, densify(Y), cfg.use_pallas), axis_name,
            norms_local)
        Kcol = _kernelize(problem, cross, anorms, idx, cfg.dtype)
        KBB = Kcol[idx] + gamma * eye_mu                 # (mu, mu)
        a_B = alpha[idx]
        g = b_B * f[idx] - 1.0 + gamma * a_B
        # mu = 1: the (1, 1) block IS the eigenvalue — skip the power loop.
        v = KBB[0, 0] if mu == 1 \
            else linalg.power_iteration_max_eig(KBB, cfg.power_iters)
        gbar = jnp.abs(jnp.clip(a_B - g, 0.0, nu) - a_B)
        theta = jnp.where(
            gbar != 0.0,
            jnp.clip(a_B - g / v, 0.0, nu) - a_B,
            0.0)
        alpha = alpha.at[idx].add(theta)
        bt = b_B * theta
        f = f + Kcol @ bt                                # replicated, local
        x = x + apply_t(Y, bt)                           # primal shadow
        dual = dual + jnp.sum(theta * g) + 0.5 * bt @ (KBB @ bt)
        obj = dual if cfg.track_objective else jnp.asarray(0.0, cfg.dtype)
        return (alpha, x, f, dual), obj

    (alpha, x, f, dual), objs = jax.lax.scan(
        step, (alpha, x, f, dual0),
        jnp.arange(start + 1, start + cfg.iterations + 1))
    return SolverResult(x=x, objective=objs,
                        aux={"alpha": alpha, "dual": dual, "f": f,
                             "state": SolveState(
                                 start + cfg.iterations,
                                 {"alpha": alpha, "x": x, "f": f,
                                  "dual": dual}),
                             **spmm_aux(A, cfg, "cross")})


def _sak_setup(problem, cfg, axis_name, alpha0, carry0):
    A, b, alpha, x, f, dual0 = _init_state(problem, cfg, axis_name, alpha0,
                                           carry0)
    take, _, densify, apply_t = row_block_ops(A, cfg)
    ctx = Ctx(A=A, b=b, m=A.shape[0], mu=cfg.block_size,
              gamma=jnp.asarray(problem.gamma, cfg.dtype),
              gamma_f=float(problem.gamma), nu_f=float(problem.nu),
              take=take, densify=densify, apply_t=apply_t,
              norms_local=_local_norms(A, problem.kernel_spec.needs_norms),
              problem=problem, cfg=cfg, axis_name=axis_name)
    return ctx, (alpha, x, f, dual0)


def _sak_assemble(ctx, carry, idxs, s_grp):
    flat = idxs.reshape(s_grp * ctx.mu)
    Y = ctx.take(flat)                                # (s_grp*mu, n_loc)
    # LOCAL half of the fused [A Y^T | norms] cross block — the norms
    # column rides along only when the kernel needs it (rbf).
    local = cross_block(ctx.A, ctx.densify(Y), ctx.cfg.use_pallas)
    if ctx.norms_local is not None:
        local = jnp.concatenate([local, ctx.norms_local], axis=1)
    return Y, local


def _sak_reduce(ctx, local, idxs, s_grp):
    # the group's ONE Allreduce, then kernelize the replicated copy:
    # K(A, Y_group) + the regularized (s*mu, s*mu) block K(Y, Y), whose
    # off-diagonal blocks carry the inner cross terms.
    flat = idxs.reshape(s_grp * ctx.mu)
    red = linalg.preduce(local, ctx.axis_name)
    cross, anorms = (red, None) if ctx.norms_local is None \
        else (red[:, :-1], red[:, -1])
    Kfull = _kernelize(ctx.problem, cross, anorms, flat, ctx.cfg.dtype)
    G = Kfull[flat] \
        + ctx.gamma * jnp.eye(s_grp * ctx.mu, dtype=ctx.cfg.dtype)
    return G, Kfull


def _sak_inner(ctx, carry, Y, payload, idxs, win, s_grp):
    alpha, _, f, _ = carry
    cfg = ctx.cfg
    G, Kfull = payload
    flat = idxs.reshape(s_grp * ctx.mu)
    b_sel = ctx.b[flat].reshape(s_grp, ctx.mu)
    theta, deltas = svm_inner_loop(
        G, f[flat].reshape(s_grp, ctx.mu), b_sel,      # proj = f_sk gather
        alpha[flat].reshape(s_grp, ctx.mu), idxs, gamma=ctx.gamma_f,
        nu=ctx.nu_f, power_iters=cfg.power_iters,
        use_pallas=cfg.use_pallas)
    return carry, (theta.astype(cfg.dtype), deltas.astype(cfg.dtype),
                   b_sel, flat)


def _sak_defer(ctx, carry, Y, inner_out, payload, idxs, win, s_grp):
    alpha, x, f, dual = carry
    _, Kfull = payload
    theta, deltas, b_sel, flat = inner_out
    bt = (b_sel * theta).reshape(s_grp * ctx.mu)
    alpha = alpha.at[flat].add(theta.reshape(s_grp * ctx.mu))
    f = f + Kfull @ bt                                # deferred GEMV
    x = x + ctx.apply_t(Y, bt)                        # primal shadow
    objs = dual + jnp.cumsum(deltas) if ctx.cfg.track_objective \
        else jnp.zeros((s_grp,), ctx.cfg.dtype)
    dual = dual + jnp.sum(deltas)
    return (alpha, x, f, dual), objs


_SAK_PROGRAM = FamilyProgram(
    name="sa_kbdcd_svm", setup=_sak_setup,
    sample=lambda ctx, key: linalg.sample_block(key, ctx.m, ctx.mu),
    assemble=_sak_assemble, reduce=_sak_reduce, inner=_sak_inner,
    defer=_sak_defer,
    finalize=lambda ctx, carry, sched: (
        carry[1], {"alpha": carry[0], "dual": carry[3], "f": carry[2]}),
    carry_names=("alpha", "x", "f", "dual"), uses_svm_inner=True,
    spmm_kind="cross")


def sa_kbdcd_svm(problem: SVMProblem, cfg: SolverConfig,
                 axis_name: Optional[object] = None,
                 alpha0=None, state: Optional[SolveState] = None
                 ) -> SolverResult:
    """s-step unrolled K-BDCD: identical iterates to ``kbdcd_svm`` in
    exact arithmetic, ONE Allreduce of the (m, s*mu [+1]) cross block
    per s inner iterations. The inner projections are the gathered
    f_sk[idx] — no projection communication at all, unlike the linear
    solver. Deferred per group: f += K(A, Y) vec(b theta) + the primal
    shadow GEMV."""
    return run_program(_SAK_PROGRAM, problem, cfg, axis_name, alpha0,
                       state)


def _cli_kernel(args) -> str:
    """--kernel is None when unset; this family defaults to rbf, but an
    EXPLICIT --kernel linear is honored (the kernelized linear path
    reproduces BDCD iterates — a communication-cost choice)."""
    return args.kernel or "rbf"


def _cli_problem(args):
    from repro.data.sparse import make_svm_dataset
    from repro.core.types import build_kernel_params
    A, b = make_svm_dataset(args.dataset, args.seed)
    kernel = _cli_kernel(args)
    return SVMProblem(A=A, b=b, lam=1.0, loss=args.svm_loss, kernel=kernel,
                      kernel_params=build_kernel_params(kernel, args))


def _cli_describe(args, res, elapsed: float) -> str:
    import numpy as np
    obj = np.asarray(res.objective)
    return (f"ksvm-{args.svm_loss}[{_cli_kernel(args)}] {args.dataset} "
            f"s={args.s} mu={args.mu}: "
            f"dual {obj[0]:.5f} -> {obj[-1]:.5f}, {elapsed:.2f}s")


@register_family(
    "ksvm",
    problem_cls=SVMProblem,
    partition="col",
    default_axes="model",
    x0_layout="replicated",          # warm start = dual alpha in R^m
    aux_out=(("alpha", "replicated"), ("f", "replicated")),
    accepts=lambda p: getattr(p, "kernel", "linear") != "linear",
    variants={
        "classical": "repro.core.kernel_svm:kbdcd_svm",
        "sa": "repro.core.kernel_svm:sa_kbdcd_svm",
    },
    objective=kernel_dual_objective,
    # kernel threads through from the caller's problem.kernel (default =
    # this family's CLI/bench default, rbf) — poly/linear-kernelized
    # problems used to report rbf eval flops from a hardcoded kernel.
    costs=lambda dims, H, mu, s, P, kernel="rbf": cost_model.svm_costs(
        dims, H, s, P, mu=mu, kernel=kernel),
    make_problem=_cli_problem,
    describe=_cli_describe,
    default_mu=1,
    bench_block_size=2,
    bench_problem_kwargs={"lam": 1.0, "kernel": "rbf",
                          "kernel_params": {"gamma": 0.1}},
    # the kernelized message is the (m, s*mu) cross block — replicated
    # memory grows with s*mu, so the candidate grid stays smaller.
    tune_space={"s": (1, 2, 4, 8, 16, 32), "mu": (1, 2, 4, 8)},
    state_layout=lambda cfg: (("alpha", "replicated"), ("x", "partition"),
                              ("f", "replicated"), ("dual", "replicated")),
)
def solve_ksvm(problem: SVMProblem, cfg: SolverConfig,
               axis_name: Optional[object] = None,
               x0=None, state=None) -> SolverResult:
    """Dispatch on cfg.s. x0: optional warm start for the dual alpha
    (replicated (m,)); rebuilding f = K(b alpha) costs one setup
    Allreduce (zero start and ``state=`` resume cost none)."""
    if cfg.s > 1:
        return sa_kbdcd_svm(problem, cfg, axis_name, x0, state)
    return kbdcd_svm(problem, cfg, axis_name, x0, state)
