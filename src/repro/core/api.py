"""The unified solver facade: ``repro.api.solve(problem, cfg, ...)``.

One entry point, two registry axes:

* **family** — which problem class (``FAMILIES`` in ``repro.core.types``,
  populated by ``@register_family`` in each family's own module). The
  family is inferred from the problem's type (plus its ``accepts`` hook,
  which is how linear and kernel SVM share ``SVMProblem``), or forced
  with ``family="..."``.
* **backend** — where it runs (``BACKENDS`` here): ``"local"`` calls the
  family's dispatch directly (optionally inside a caller-managed
  ``shard_map`` via ``axis_name``); ``"sharded"`` wraps the SAME solver
  in the generic distributed driver below, which builds the
  shard_map/pad/unpad plumbing from the family's declared partition
  axis — the paper's Fig. 1 row layout and Sec. V column layout are the
  two values of one field, not two hand-written drivers.

Every legacy entry point (``solve_lasso``, ``solve_svm_sharded``,
``lower_svm_step``, ...) is a thin shim over this module, so the two
paths are the same compiled program — bit-identical results, by
construction and by test (tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.types import (FAMILIES, ProblemFamily, SolveState,
                              SolverConfig, SolverResult, SparseOperand)

# Importing the family modules is what populates FAMILIES: each family
# self-registers from its own module (the ``KERNELS`` pattern). A new
# family only needs to be imported somewhere — these five lines are the
# complete dispatch "table".
import repro.core.lasso       # noqa: F401  (registers "lasso")
import repro.core.svm         # noqa: F401  (registers "svm")
import repro.core.kernel_svm  # noqa: F401  (registers "ksvm")
import repro.core.logreg      # noqa: F401  (registers "logreg")
import repro.core.sfista      # noqa: F401  (registers "sfista")

AxisNames = Union[str, Tuple[str, ...]]

__all__ = [
    "solve", "solve_sharded", "lower_solve", "resolve_family", "families",
    "BACKENDS", "TracedSolve", "trace_sharded",
]


def families() -> Tuple[str, ...]:
    """Registered family names, sorted."""
    return tuple(sorted(FAMILIES))


def resolve_family(problem=None, family: Optional[object] = None
                   ) -> ProblemFamily:
    """Resolve a family from an explicit name or the problem's type."""
    if family is not None:
        if isinstance(family, ProblemFamily):
            return family
        if family not in FAMILIES:
            raise ValueError(
                f"unknown family {family!r}; registered: {sorted(FAMILIES)}")
        return FAMILIES[family]
    matched = [f for f in FAMILIES.values() if f.matches(problem)]
    if not matched:
        raise ValueError(
            f"no registered problem family handles "
            f"{type(problem).__name__}; registered: {sorted(FAMILIES)}")
    if len(matched) > 1:
        raise ValueError(
            f"problem matches several families "
            f"({sorted(f.name for f in matched)}); disambiguate with "
            f"family=...")
    return matched[0]


# ---------------------------------------------------------------------------
# The generic sharded driver: ONE implementation of the pad/shard_map/
# unpad plumbing, parameterized by the family's declared partition axis.
# ---------------------------------------------------------------------------

def _pad_to(x: np.ndarray, size: int, axis: int) -> np.ndarray:
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths)


def _axis_size(mesh: Mesh, axes: AxisNames) -> int:
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _stack_sparse_shards(op: SparseOperand, n_shards: int, part_axis: int,
                         padded: int, dtype) -> SparseOperand:
    """Split a SparseOperand into per-shard operands along the partition
    axis and stack their blocked-ELL leaves with a leading shard axis —
    the form ``shard_map`` partitions with a single leading-axis spec.

    Zero-padding the partitioned axis is exact: padded rows/columns
    store no nonzeros (zero ELL blocks), so they contribute nothing to
    any Gram/cross product and the corresponding state coordinates stay
    0. Per-shard ELL arrays are rebuilt so indices are shard-LOCAL, with
    widths padded to the max across shards (uniform leaves); the BCOO
    form does not cross shard_map (dropped — ``squeeze_shard`` inside
    rebuilds a pure-ELL local operand).
    """
    from repro.core.types import ell_width

    m, n = op.shape
    rows, cols, vals = op.host_coo()
    vals = vals.astype(np.dtype(dtype) if dtype is not None else vals.dtype)
    size = padded // n_shards
    part = rows if part_axis == 0 else cols
    loc_shape = (size, n) if part_axis == 0 else (m, size)
    pieces = []
    for k in range(n_shards):
        sel = (part >= k * size) & (part < (k + 1) * size)
        r = rows[sel] - (k * size if part_axis == 0 else 0)
        c = cols[sel] - (k * size if part_axis == 1 else 0)
        pieces.append((r, c, vals[sel]))
    # uniform leaf widths across shards (so the stack is rectangular):
    # the max per-row/column count over all shards, block-rounded.
    rw = ell_width(max((np.bincount(r, minlength=loc_shape[0]).max()
                        if r.size else 0) for r, _, _ in pieces),
                   op.ell_block)
    cw = ell_width(max((np.bincount(c, minlength=loc_shape[1]).max()
                        if c.size else 0) for _, c, _ in pieces),
                   op.ell_block)
    built = [SparseOperand.from_coo(r, c, v, loc_shape,
                                    ell_block=op.ell_block,
                                    row_width=rw, col_width=cw)
             for r, c, v in pieces]

    def stack(get):
        return jnp.stack([get(o) for o in built])

    return SparseOperand(
        stack(lambda o: o.row_cols), stack(lambda o: o.row_vals),
        stack(lambda o: o.row_blocks), stack(lambda o: o.col_rows),
        stack(lambda o: o.col_vals), stack(lambda o: o.col_blocks),
        None, op.ell_block)


def _specs(fam: ProblemFamily, axes: AxisNames):
    """PartitionSpecs implied by the family's partition axis: the sharded
    vector spec, A's spec, b's spec, and the solution's output spec."""
    part = axes if isinstance(axes, str) else tuple(axes)
    vec = P(part)
    if fam.partition == "row":
        # Fig. 1: data points sharded; b rides with A; solutions and all
        # R^(s mu)-sized reductions replicated.
        return vec, P(part, None), vec, P()
    # Sec. V: features sharded; everything in R^m replicated; the
    # solution lives on the feature axis.
    return vec, P(None, part), P(), vec


def solve_sharded(problem, cfg: SolverConfig, mesh: Mesh,
                  axes: Optional[AxisNames] = None,
                  family: Optional[object] = None,
                  x0=None, state: Optional[SolveState] = None
                  ) -> SolverResult:
    """Distributed solve for ANY registered family.

    Pads the partitioned axis of A to a multiple of the shard count
    (zero padding is exact for every family — padded rows/columns
    contribute 0 to every Gram/cross product and the corresponding
    state coordinates stay 0; a ``SparseOperand`` A is split into
    per-shard operands whose padded rows/columns store no nonzeros at
    all — see ``_stack_sparse_shards``), runs the family's own solver inside
    ``shard_map`` with ``axis_name=axes``, and unpads the outputs. The
    whole solve jits to ONE compiled program whose HLO carries exactly
    ceil(H/s) all-reduces — see ``benchmarks/collective_count.py``.

    ``axes`` may be a single mesh axis or a tuple (e.g. ('pod', 'data'))
    — reductions then span pods hierarchically.

    ``state``: a LOGICAL (unpadded) :class:`SolveState` from a previous
    solve's ``aux["state"]`` — its "partition" leaves (per the family's
    ``state_layout``) are zero-padded and re-sharded onto THIS mesh, so
    a state checkpointed on one mesh resumes on any other (the elastic
    recovery path). The returned ``aux["state"]`` is logical again:
    partition leaves are unpadded before they leave this function.
    """
    fam = resolve_family(problem, family)
    if axes is None:
        axes = fam.default_axes
    n_shards = _axis_size(mesh, axes)
    sparse = isinstance(problem.A, SparseOperand)
    part_axis = 0 if fam.partition == "row" else 1
    orig = problem.A.shape[part_axis]
    padded = -(-orig // n_shards) * n_shards
    if sparse:
        A_arg = _stack_sparse_shards(problem.A, n_shards, part_axis,
                                     padded, cfg.dtype)
    else:
        A_arg = jnp.asarray(
            _pad_to(np.asarray(problem.A), padded, part_axis), cfg.dtype)
    b = np.asarray(problem.b)
    if fam.partition == "row":
        b = _pad_to(b, padded, 0)

    vec, a_spec, b_spec, x_out = _specs(fam, axes)
    aux_specs = tuple(vec if layout == "partition" else P()
                      for _, layout in fam.aux_out)
    layout = fam.state_layout(cfg) if fam.state_layout is not None else ()
    state_specs = tuple(vec if lay == "partition" else P()
                        for _, lay in layout)
    # a sparse operand's leaves all carry a leading stacked-shard axis,
    # so ONE leading-axis spec partitions the whole pytree.
    in_specs = [vec if sparse else a_spec, b_spec]
    args = [A_arg, jnp.asarray(b, cfg.dtype)]
    if x0 is not None:
        x0 = np.asarray(x0)
        if fam.x0_layout == "partition":
            x0 = _pad_to(x0, padded, 0)
            in_specs.append(vec)
        else:
            in_specs.append(P())
        args.append(jnp.asarray(x0, cfg.dtype))
    n_x0 = len(args) - 2
    if state is not None:
        if not layout:
            raise ValueError(
                f"family {fam.name!r} declares no state_layout — it "
                f"cannot resume from a SolveState")
        for name, lay in layout:
            leaf = np.asarray(state.carry[name])
            if lay == "partition":
                leaf = _pad_to(leaf, padded, 0)
                in_specs.append(vec)
            else:
                in_specs.append(P())
            args.append(jnp.asarray(leaf, cfg.dtype))

    def local_solve(A_loc, b_loc, *rest):
        if sparse:
            A_loc = A_loc.squeeze_shard()
        local = dataclasses.replace(problem, A=A_loc, b=b_loc)
        kw = {}
        if state is not None:
            kw["state"] = SolveState(
                int(state.iteration),
                {name: leaf for (name, _), leaf
                 in zip(layout, rest[n_x0:])})
        res = fam.solve(local, cfg, axis_name=axes,
                        x0=rest[0] if n_x0 else None, **kw)
        outs = (res.x, res.objective) \
            + tuple(res.aux[k] for k, _ in fam.aux_out)
        if layout:
            outs += tuple(res.aux["state"].carry[name]
                          for name, _ in layout)
        return outs

    fn = shard_map(local_solve, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(x_out, P()) + aux_specs + state_specs,
                   check_rep=False)
    out = jax.jit(fn)(*args)
    x, objective = out[0], out[1]
    if fam.partition == "col":
        x = x[:orig]
    n_aux = len(fam.aux_out)
    aux = {k: (v[:orig] if layout_ == "partition" else v)
           for (k, layout_), v in zip(fam.aux_out, out[2:2 + n_aux])}
    if layout:
        start = 0 if state is None else int(state.iteration)
        aux["state"] = SolveState(
            start + cfg.iterations,
            {name: (v[:orig] if lay == "partition" else v)
             for (name, lay), v in zip(layout, out[2 + n_aux:])})
    return SolverResult(x=x, objective=objective, aux=aux)


def lower_solve(family: object, cfg: SolverConfig, mesh: Mesh,
                m: int, n: int, axes: Optional[AxisNames] = None,
                dtype=jnp.float32,
                problem_kwargs: Optional[Dict[str, Any]] = None):
    """Lower (without executing) a full distributed solve of any
    registered family for shape (m, n) — the dry-run/collective-count
    entry. Returns the ``jax.stages.Lowered`` object.

    ``problem_kwargs`` fills the family's non-(A, b) problem fields;
    defaults to the family's ``bench_problem_kwargs``.
    """
    fam = resolve_family(family=family)
    if axes is None:
        axes = fam.default_axes
    kwargs = dict(fam.bench_problem_kwargs if problem_kwargs is None
                  else problem_kwargs)
    _, a_spec, b_spec, x_out = _specs(fam, axes)

    def local_solve(A_loc, b_loc):
        prob = fam.problem_cls(A=A_loc, b=b_loc, **kwargs)
        res = fam.solve(prob, cfg, axis_name=axes)
        return res.x, res.objective

    fn = shard_map(local_solve, mesh=mesh, in_specs=(a_spec, b_spec),
                   out_specs=(x_out, P()), check_rep=False)
    return jax.jit(fn).lower(jax.ShapeDtypeStruct((m, n), dtype),
                             jax.ShapeDtypeStruct((m,), dtype))


@dataclasses.dataclass(frozen=True)
class TracedSolve:
    """A sharded solve as a jaxpr plus its DECLARED output contract —
    the static-analysis view of :func:`solve_sharded` (repro.analysis).

    jaxpr:       the ``ClosedJaxpr`` of the full shard_map'd solve.
    out_layout:  ``(name, layout)`` per output, in output order, with
                 layout in {"replicated", "partition"} — exactly what
                 the family registered (solution/objective/aux_out/
                 state_layout), i.e. the contract the replicated-taint
                 pass verifies the dataflow against.
    axes:        the mesh axis name(s) the solve reduces over.
    """

    jaxpr: Any
    out_layout: Tuple[Tuple[str, str], ...]
    axes: AxisNames


def trace_sharded(family: object, cfg: SolverConfig, mesh: Mesh,
                  m: Optional[int] = None, n: Optional[int] = None,
                  axes: Optional[AxisNames] = None,
                  dtype=jnp.float32,
                  problem_kwargs: Optional[Dict[str, Any]] = None,
                  operand: Optional[SparseOperand] = None
                  ) -> TracedSolve:
    """Trace (without lowering or executing) a full sharded solve for
    shape (m, n), with the family's ``aux_out`` vectors AND
    ``state_layout`` carry leaves as outputs — the same output structure
    :func:`solve_sharded` runs, so a static pass over this jaxpr checks
    the program the driver actually executes. ``repro.analysis`` builds
    its collective-budget, replicated-taint and cost-certification
    passes on this entry; a 1-device mesh suffices (divergence is
    symbolic in the jaxpr).

    ``operand``: an optional concrete :class:`SparseOperand` A. The
    trace then follows the SPARSE execution path — the operand is split
    and stacked exactly as :func:`solve_sharded` does it (the blocked-
    ELL leaves cross shard_map with one leading-axis spec), so the
    jaxpr's flop counts reflect the O(nnz) gather/scatter products,
    which is what the cost certifier's nnz-scaling check measures.
    (m, n) then come from ``operand.shape`` and must not be passed."""
    fam = resolve_family(family=family)
    if axes is None:
        axes = fam.default_axes
    if operand is not None:
        if m is not None or n is not None:
            raise ValueError(
                "trace_sharded: pass either operand= (sparse; shape "
                "comes from the operand) or m=/n= (dense), not both")
        m, n = operand.shape
    elif m is None or n is None:
        raise ValueError("trace_sharded: a dense trace needs m= and n=")
    kwargs = dict(fam.bench_problem_kwargs if problem_kwargs is None
                  else problem_kwargs)
    vec, a_spec, b_spec, x_out = _specs(fam, axes)
    layout = fam.state_layout(cfg) if fam.state_layout is not None else ()
    sparse = operand is not None
    if sparse:
        n_shards = _axis_size(mesh, axes)
        part_axis = 0 if fam.partition == "row" else 1
        padded = -(-operand.shape[part_axis] // n_shards) * n_shards
        A_arg = _stack_sparse_shards(operand, n_shards, part_axis,
                                     padded, dtype)
        b_len = padded if fam.partition == "row" else m
    else:
        A_arg = jax.ShapeDtypeStruct((m, n), dtype)
        b_len = m

    def local_solve(A_loc, b_loc):
        if sparse:
            A_loc = A_loc.squeeze_shard()
        prob = fam.problem_cls(A=A_loc, b=b_loc, **kwargs)
        res = fam.solve(prob, cfg, axis_name=axes)
        outs = (res.x, res.objective) \
            + tuple(res.aux[k] for k, _ in fam.aux_out)
        if layout:
            outs += tuple(res.aux["state"].carry[name]
                          for name, _ in layout)
        return outs

    aux_specs = tuple(vec if lay == "partition" else P()
                      for _, lay in fam.aux_out)
    state_specs = tuple(vec if lay == "partition" else P()
                        for _, lay in layout)
    fn = shard_map(local_solve, mesh=mesh,
                   in_specs=(vec if sparse else a_spec, b_spec),
                   out_specs=(x_out, P()) + aux_specs + state_specs,
                   check_rep=False)
    jaxpr = jax.make_jaxpr(fn)(A_arg,
                               jax.ShapeDtypeStruct((b_len,), dtype))
    out_layout = (
        ("x", "partition" if fam.partition == "col" else "replicated"),
        ("objective", "replicated"),
    ) + tuple(fam.aux_out) + tuple(("state." + name, lay)
                                   for name, lay in layout)
    return TracedSolve(jaxpr=jaxpr, out_layout=out_layout, axes=axes)


# ---------------------------------------------------------------------------
# The facade.
# ---------------------------------------------------------------------------

def _local_backend(fam: ProblemFamily, problem, cfg: SolverConfig, *,
                   axis_name=None, mesh=None, axes=None, x0=None,
                   state=None) -> SolverResult:
    if mesh is not None or axes is not None:
        raise ValueError(
            "mesh=/axes= are only meaningful with backend='sharded' "
            "(the local backend runs single-host, or inside a "
            "caller-managed shard_map via axis_name=)")
    # keyword only when set: families registered WITHOUT resume support
    # (no `state` parameter) keep working for ordinary solves.
    kw = {} if state is None else {"state": state}
    return fam.solve(problem, cfg, axis_name=axis_name, x0=x0, **kw)


def _sharded_backend(fam: ProblemFamily, problem, cfg: SolverConfig, *,
                     axis_name=None, mesh=None, axes=None, x0=None,
                     state=None) -> SolverResult:
    if mesh is None:
        raise ValueError("backend='sharded' requires mesh=...")
    if axis_name is not None:
        raise ValueError(
            "axis_name= is managed by the sharded backend; pass axes= "
            "to choose the mesh axes")
    return solve_sharded(problem, cfg, mesh, axes=axes, family=fam, x0=x0,
                         state=state)


BACKENDS: Dict[str, Callable] = {
    "local": _local_backend,
    "sharded": _sharded_backend,
}


def solve(problem, cfg: Optional[SolverConfig] = None,
          backend: str = "local", *,
          family: Optional[object] = None,
          axis_name=None, mesh: Optional[Mesh] = None,
          axes: Optional[AxisNames] = None, x0=None,
          state: Optional[SolveState] = None,
          tune: Optional[str] = None,
          callbacks: Optional[Sequence[Callable]] = None) -> SolverResult:
    """Solve any registered problem family on any registered backend.

    problem:  a registered problem dataclass (LassoProblem, SVMProblem,
              LogRegProblem, ...); its type picks the family.
    cfg:      SolverConfig (defaults to ``SolverConfig()``); cfg.s and
              cfg.accelerated pick the variant inside the family.
    backend:  "local" (single host / caller-managed shard_map) or
              "sharded" (the generic distributed driver; needs mesh=).
    family:   optional explicit family name, overriding type inference.
    x0:       optional warm start in the family's iterate space (Lasso
              x, SVM/K-SVM dual alpha, logreg w) — threaded through to
              every solver; the objective trace resumes where a previous
              solve's left off.
    state:    optional :class:`SolveState` from a previous solve's
              ``result.aux["state"]`` — resumes the FULL recurrence
              state (all carries + RNG/θ-schedule offset), so the
              continued solve is bit-identical to an uninterrupted one
              on the same mesh. Mutually exclusive with x0. On the
              sharded backend the state is re-padded/re-sharded, so a
              state saved on one mesh restores onto any other (elastic
              recovery; see ``repro.runtime.elastic``).
    tune:     ``"auto"`` replaces cfg's tunables (s, block_size,
              use_pallas, symmetric_gram) with ``repro.tune.autotune``'s
              calibrated-model selection before solving — iterations,
              dtype, seed etc. are preserved, and the calibrated machine
              is cached per host/regime under ``results/tuned/`` so only
              the first solve of a regime pays the pilot measurements.
              The config actually used lands in
              ``result.aux["tuned_config"]``. None/"off" solves cfg
              as given.
    callbacks: optional callables, each invoked as ``cb(result)`` after
              the solve (the solvers are single jitted programs, so
              per-iteration hooks would force a host round-trip; consume
              ``result.objective`` instead).
    """
    fam = resolve_family(problem, family)
    if cfg is None:
        cfg = SolverConfig()
    if backend not in BACKENDS:
        raise ValueError(
            f"unknown backend {backend!r}; registered: {sorted(BACKENDS)}")
    tuned = False
    if tune not in (None, False, "off"):
        if tune not in ("auto", True):
            raise ValueError(
                f"unknown tune mode {tune!r}; expected 'auto' (or "
                f"None/'off' to solve cfg as given)")
        if backend != "local":
            # autotune calibrates with LOCAL single-host pilot solves
            # and selects at P=1 — silently applying that to a sharded
            # solve would tune for the wrong machine and topology.
            raise ValueError(
                "tune='auto' only supports backend='local' (pilot "
                "solves run unsharded at P=1); for a sharded solve, "
                "call repro.tune.select_config explicitly with a "
                "calibrated/hand-built Machine and P = the shard "
                "count")
        from repro import tune as tune_mod
        cfg = tune_mod.autotune(problem, cfg, family=fam)
        tuned = True
    result = BACKENDS[backend](fam, problem, cfg, axis_name=axis_name,
                               mesh=mesh, axes=axes, x0=x0, state=state)
    if tuned:
        result.aux["tuned_config"] = cfg
    for cb in callbacks or ():
        cb(result)
    return result
