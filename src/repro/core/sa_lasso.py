"""Synchronization-Avoiding coordinate-descent solvers for proximal
least-squares — paper Algorithm 2 (SA-accBCD) and the non-accelerated
SA-BCD / SA-CD variants.

The transformation (paper Sec. III): unroll the recurrences s iterations,
sample all s*mu coordinates up front, compute ONE (s*mu) x (s*mu) Gram
matrix plus the projections Y^T [ytil, ztil] with a SINGLE Allreduce, then
run the s inner updates redundantly on replicated O(s*mu)-sized data, and
apply the deferred m-dimensional vector updates (paper Eqs. 6-9) as local
GEMVs. Latency drops by s; flops/bandwidth grow by s (paper Table I). The
iterate sequence is identical to Algorithm 1 in exact arithmetic.

The hot spots map to the two Pallas kernels:
  * ``repro.kernels.gram``     — the fused  Y^T [Y | ytil | ztil]  GEMM
  * ``repro.kernels.sa_inner`` — the s-step inner loop, entirely in VMEM
Both have pure-jnp paths (used on CPU and inside the multi-device dry-run).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.lasso import _objective, _prep
from repro.core.sa_loop import run_grouped
from repro.core.sparse_exec import col_block_ops, spmm_aux
from repro.core.types import (LassoProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, operand_matvec,
                              require_unit_block, resume_carry)
from repro.kernels import spmm
from repro.kernels.gram import gram_t


def _reduce_gram_proj(local, smu, vec_cols, axis_name,
                      symmetric: bool = False):
    """ONE fused Allreduce of the LOCAL (smu, smu + k) Gram/projection
    block -> (G, P) replicated, with G (smu, smu) and P (smu, k).

    symmetric (``SolverConfig.symmetric_gram``, paper footnote 3): G is
    symmetric, so communicating only its lower triangle halves the message
    size — ~2x less W at O(s^2 mu^2) local pack/unpack reshuffling. The
    reduced values are identical, only their layout changes.
    """
    if symmetric:
        il, jl = jnp.tril_indices(smu)
        packed = jnp.concatenate(
            [local[:, :smu][il, jl], local[:, smu:].reshape(-1)])
        packed = linalg.preduce(packed, axis_name)
        ntri = il.shape[0]
        G = jnp.zeros((smu, smu), local.dtype).at[il, jl].set(packed[:ntri])
        G = G + jnp.tril(G, -1).T
        P = packed[ntri:].reshape(smu, vec_cols)
        return G, P
    out = linalg.preduce(local, axis_name)
    return out[:, :smu], out[:, smu:]


def _gram_and_proj(Y, vecs, axis_name, symmetric: bool = False,
                   use_pallas: bool = False):
    """ONE fused Allreduce:  Y^T @ [Y | vecs]  (paper Alg. 2 lines 11-12).

    Y: (m_loc, s*mu) sampled columns; vecs: (m_loc, k) residual-like vectors.
    Returns (G, P) with G (s*mu, s*mu) and P (s*mu, k), replicated.

    use_pallas routes the local GEMM through the ``repro.kernels.gram``
    Pallas kernel (f32 MXU accumulation); the plain-jnp path otherwise.
    (Sparse operands compute the same local block via the blocked-ELL
    SpMM in the solvers below and share :func:`_reduce_gram_proj`.)
    """
    rhs = jnp.concatenate([Y, vecs], axis=1)
    if use_pallas:
        local = gram_t(Y, rhs, use_pallas=True).astype(Y.dtype)
    else:
        local = Y.T @ rhs
    return _reduce_gram_proj(local, Y.shape[1], vecs.shape[1], axis_name,
                             symmetric)


def _sample_all(key, sampler, start, s_grp):
    """Sample the s_grp blocks of the outer group starting after global
    iteration id ``start``, matching the non-SA fold_in indices
    (h = start + j, j = 1..s_grp) so SA and non-SA draw bit-identical
    coordinate sequences."""
    hs = start + 1 + jnp.arange(s_grp)
    return jax.vmap(lambda h: sampler(jax.random.fold_in(key, h)))(hs)


# ---------------------------------------------------------------------------
# SA-BCD (non-accelerated): r_j = A_j^T r_sk + sum_{t<j} G[j,t] dx_t
# ---------------------------------------------------------------------------

def sa_bcd_lasso(problem: LassoProblem, cfg: SolverConfig,
                 axis_name: Optional[object] = None,
                 x0=None, state: Optional[SolveState] = None) -> SolverResult:
    A, b, n, mu, q, sampler, prox = _prep(problem, cfg)
    sparse = isinstance(A, SparseOperand)
    block_gram, _ = col_block_ops(A, cfg)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    m_loc = A.shape[0]
    carry0 = resume_carry(state, x0, "sa_bcd_lasso")
    h0 = 0 if state is None else int(state.iteration)

    if carry0 is not None:
        x0 = jnp.asarray(carry0["x"], cfg.dtype)
        r0 = jnp.asarray(carry0["residual"], cfg.dtype)
    elif x0 is None:
        x0 = jnp.zeros((n,), cfg.dtype)
        r0 = -b
    else:
        x0 = jnp.asarray(x0, cfg.dtype)
        r0 = operand_matvec(A, x0) - b

    def group(carry, start, s):
        x, r = carry
        idxs = _sample_all(key, sampler, start, s)        # (s, mu)
        # --- Communication: ONE fused Allreduce ---
        if sparse:
            handle, local = block_gram(idxs.reshape(s * mu), r[:, None])
            G, P = _reduce_gram_proj(local, s * mu, 1, axis_name,
                                     cfg.symmetric_gram)
        else:
            Y = A[:, idxs.reshape(s * mu)]                # (m_loc, s*mu) local
            G, P = _gram_and_proj(Y, r[:, None], axis_name,
                                  symmetric=cfg.symmetric_gram,
                                  use_pallas=cfg.use_pallas)
        G4 = G.reshape(s, mu, s, mu)
        r_proj = P[:, 0].reshape(s, mu)

        def inner(inner_carry, j):
            x, dx_buf = inner_carry
            idx_j = idxs[j]
            Gj = G4[j]                                    # (mu, s, mu)
            cross = jnp.einsum("ptq,tq->tp", Gj, dx_buf)  # (s, mu)
            mask = (jnp.arange(s) < j).astype(cfg.dtype)
            rj = r_proj[j] + jnp.einsum("t,tp->p", mask, cross)
            v = linalg.power_iteration_max_eig(Gj[:, j, :], cfg.power_iters)
            eta = 1.0 / linalg.floor_eig(v)  # floored: zero block -> no-op
            g = x[idx_j] - eta * rj
            dx = prox(g, eta) - x[idx_j]
            x = x.at[idx_j].add(dx)
            dx_buf = dx_buf.at[j].set(dx)
            return (x, dx_buf), None

        (x, dx_buf), _ = jax.lax.scan(
            inner, (x, jnp.zeros((s, mu), cfg.dtype)), jnp.arange(s))

        # Deferred residual update (paper Eq. 7 analogue): local GEMV
        # (sparse: O(nnz of the sampled columns) scatter-adds).
        if sparse:
            rows_g, vals_g, _ = handle
            steps = spmm.scatter_steps(rows_g.reshape(s, mu, -1),
                                       vals_g.reshape(s, mu, -1),
                                       dx_buf, m_loc)
        else:
            steps = jnp.einsum("msc,sc->sm", Y.reshape(m_loc, s, mu), dx_buf)
        r_new = r + jnp.sum(steps, axis=0)

        if cfg.track_objective:
            r_steps = r[None, :] + jnp.cumsum(steps, axis=0)
            dx_full = jnp.zeros((s, n), cfg.dtype).at[
                jnp.arange(s)[:, None], idxs].add(dx_buf)
            x_steps = (x - jnp.sum(dx_full, 0))[None, :] \
                + jnp.cumsum(dx_full, axis=0)
            objs = jax.vmap(
                lambda rr, xx: _objective(rr, xx, problem, axis_name))(
                r_steps, x_steps)
        else:
            objs = jnp.zeros((s,), cfg.dtype)
        return (x, r_new), objs

    (x, r), objs = run_grouped(group, (x0, r0), H, s, cfg.dtype, start=h0)
    return SolverResult(x=x, objective=objs,
                        aux={"residual": r,
                             "state": SolveState(h0 + H,
                                                 {"x": x, "residual": r}),
                             **spmm_aux(A, cfg, "col_gram", H=H, extra=1)})


# ---------------------------------------------------------------------------
# SA-accBCD — paper Algorithm 2.
# ---------------------------------------------------------------------------

def sa_acc_bcd_lasso(problem: LassoProblem, cfg: SolverConfig,
                     axis_name: Optional[object] = None,
                     x0=None, state: Optional[SolveState] = None
                     ) -> SolverResult:
    A, b, n, mu, q, sampler, prox = _prep(problem, cfg)
    sparse = isinstance(A, SparseOperand)
    block_gram, _ = col_block_ops(A, cfg)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    m_loc = A.shape[0]
    carry0 = resume_carry(state, x0, "sa_acc_bcd_lasso")
    h0 = 0 if state is None else int(state.iteration)

    theta0 = jnp.asarray(mu / n, cfg.dtype)
    thetas = linalg.theta_schedule(theta0, h0 + H, q)     # (h0+H+1,)

    if carry0 is not None:
        z0 = jnp.asarray(carry0["z"], cfg.dtype)
        y0 = jnp.asarray(carry0["y"], cfg.dtype)
        ztil0 = jnp.asarray(carry0["ztil"], cfg.dtype)
        ytil0 = jnp.asarray(carry0["ytil"], cfg.dtype)
    else:
        if x0 is None:
            z0 = jnp.zeros((n,), cfg.dtype)
            ztil0 = -b
        else:
            z0 = jnp.asarray(x0, cfg.dtype)
            ztil0 = operand_matvec(A, z0) - b
        y0 = jnp.zeros((n,), cfg.dtype)
        ytil0 = jnp.zeros_like(b)

    def group(carry, start, s):
        z, y, ztil, ytil = carry
        idxs = _sample_all(key, sampler, start, s)        # (s, mu)
        # --- Communication: ONE fused Allreduce (Alg. 2 lines 11-12) ---
        if sparse:
            handle, local = block_gram(idxs.reshape(s * mu),
                                       jnp.stack([ytil, ztil], axis=1))
            G, P = _reduce_gram_proj(local, s * mu, 2, axis_name,
                                     cfg.symmetric_gram)
        else:
            Y = A[:, idxs.reshape(s * mu)]                # (m_loc, s*mu) local
            G, P = _gram_and_proj(Y, jnp.stack([ytil, ztil], axis=1),
                                  axis_name,
                                  symmetric=cfg.symmetric_gram,
                                  use_pallas=cfg.use_pallas)
        G4 = G.reshape(s, mu, s, mu)
        y_proj = P[:, 0].reshape(s, mu)                   # A_j^T ytil_sk
        z_proj = P[:, 1].reshape(s, mu)                   # A_j^T ztil_sk
        th_prev = jax.lax.dynamic_slice(thetas, (start,), (s,))
        th_cur = jax.lax.dynamic_slice(thetas, (start + 1,), (s,))
        coefU = (1.0 - q * th_prev) / (th_prev * th_prev)  # lines 21-22 coeff

        def inner(inner_carry, j):
            z, y, dz_buf = inner_carry
            idx_j = idxs[j]
            thp = th_prev[j]
            Gj = G4[j]                                    # (mu, s, mu)
            cross = jnp.einsum("ptq,tq->tp", Gj, dz_buf)  # (s, mu)
            # Eq. (3): coefficient (theta_{j-1}^2 * coefU_t - 1) on G[j,t] dz_t
            coef_t = thp * thp * coefU - 1.0              # (s,)
            mask = (jnp.arange(s) < j).astype(cfg.dtype)
            rj = thp * thp * y_proj[j] + z_proj[j] \
                - jnp.einsum("t,t,tp->p", mask, coef_t, cross)
            v = linalg.power_iteration_max_eig(Gj[:, j, :],
                                               cfg.power_iters)  # line 14
            eta = 1.0 / linalg.floor_eig(q * thp * v)     # line 15 (floored)
            g = z[idx_j] - eta * rj                       # Eq. (4)
            dz = prox(g, eta) - z[idx_j]                  # Eq. (5)
            z = z.at[idx_j].add(dz)                       # line 19
            y = y.at[idx_j].add(-coefU[j] * dz)           # line 21
            dz_buf = dz_buf.at[j].set(dz)
            return (z, y, dz_buf), None

        (z, y, dz_buf), _ = jax.lax.scan(
            inner, (z, y, jnp.zeros((s, mu), cfg.dtype)), jnp.arange(s))

        # Deferred m-dimensional updates (paper Eqs. 7 & 9): local GEMVs
        # (sparse: O(nnz of the sampled columns) scatter-adds).
        if sparse:
            rows_g, vals_g, _ = handle
            steps = spmm.scatter_steps(rows_g.reshape(s, mu, -1),
                                       vals_g.reshape(s, mu, -1),
                                       dz_buf, m_loc)
        else:
            steps = jnp.einsum("msc,sc->sm", Y.reshape(m_loc, s, mu), dz_buf)
        ztil_new = ztil + jnp.sum(steps, axis=0)
        ytil_new = ytil - jnp.einsum("t,tm->m", coefU, steps)

        if cfg.track_objective:
            ztil_steps = ztil[None, :] + jnp.cumsum(steps, axis=0)
            ytil_steps = ytil[None, :] - jnp.cumsum(
                coefU[:, None] * steps, axis=0)
            dz_full = jnp.zeros((s, n), cfg.dtype).at[
                jnp.arange(s)[:, None], idxs].add(dz_buf)
            z_steps = (z - jnp.sum(dz_full, 0))[None, :] \
                + jnp.cumsum(dz_full, axis=0)
            y_steps = (y + jnp.sum(coefU[:, None] * dz_full, 0))[None, :] \
                - jnp.cumsum(coefU[:, None] * dz_full, axis=0)
            th2 = (th_cur * th_cur)[:, None]
            objs = jax.vmap(
                lambda rr, xx: _objective(rr, xx, problem, axis_name))(
                th2 * ytil_steps + ztil_steps, th2 * y_steps + z_steps)
        else:
            objs = jnp.zeros((s,), cfg.dtype)
        return (z, y, ztil_new, ytil_new), objs

    (z, y, ztil, ytil), objs = run_grouped(
        group, (z0, y0, ztil0, ytil0), H, s, cfg.dtype, start=h0)
    thH = thetas[-1]
    x = thH * thH * y + z
    return SolverResult(x=x, objective=objs,
                        aux={"residual": thH * thH * ytil + ztil,
                             "state": SolveState(
                                 h0 + H, {"z": z, "y": y,
                                          "ztil": ztil, "ytil": ytil}),
                             **spmm_aux(A, cfg, "col_gram", H=H, extra=2)})


def sa_cd_lasso(problem, cfg, axis_name=None, x0=None, state=None):
    require_unit_block(cfg, "sa_cd_lasso")
    return sa_bcd_lasso(problem, cfg, axis_name, x0, state)


def sa_acc_cd_lasso(problem, cfg, axis_name=None, x0=None, state=None):
    require_unit_block(cfg, "sa_acc_cd_lasso")
    return sa_acc_bcd_lasso(problem, cfg, axis_name, x0, state)
