"""Synchronization-Avoiding coordinate-descent solvers for proximal
least-squares — paper Algorithm 2 (SA-accBCD) and the non-accelerated
SA-BCD / SA-CD variants, expressed as :class:`repro.core.engine`
FamilyPrograms.

The transformation (paper Sec. III): unroll the recurrences s iterations,
sample all s*mu coordinates up front, compute ONE (s*mu) x (s*mu) Gram
matrix plus the projections Y^T [ytil, ztil] with a SINGLE Allreduce, then
run the s inner updates redundantly on replicated O(s*mu)-sized data, and
apply the deferred m-dimensional vector updates (paper Eqs. 6-9) as local
GEMVs. Latency drops by s; flops/bandwidth grow by s (paper Table I). The
iterate sequence is identical to Algorithm 1 in exact arithmetic.

Only the algorithm lives here — sampled-block assembly, the fused
payload, the inner recurrence, the deferred application and the
objective stitching. All s-step scheduling (grouping, remainder tails,
fold_in ids, SolveState resume, the θ schedule windows) is owned by
:func:`repro.core.engine.run_program`.

The hot spots map to the two Pallas kernels:
  * ``repro.kernels.gram``     — the fused  Y^T [Y | ytil | ztil]  GEMM
  * ``repro.kernels.sa_inner`` — the s-step inner loop, entirely in VMEM
Both have pure-jnp paths (used on CPU and inside the multi-device dry-run).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
# Compatibility aliases: these helpers moved into the engine.
from repro.core.engine import (Ctx, FamilyProgram, deferred_steps,
                               gram_and_proj as _gram_and_proj,
                               gram_local,
                               reduce_gram_proj as _reduce_gram_proj,
                               run_program,
                               sample_all as _sample_all)
from repro.core.lasso import _objective, _prep
from repro.core.sparse_exec import col_block_ops
from repro.core.types import (LassoProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, operand_matvec,
                              require_unit_block)


def _lasso_ctx(problem, cfg, axis_name):
    A, b, n, mu, q, sampler, prox = _prep(problem, cfg)
    return Ctx(A=A, b=b, n=n, mu=mu, q=q, sampler=sampler, prox=prox,
               sparse=isinstance(A, SparseOperand),
               block_gram=col_block_ops(A, cfg)[0],
               m_loc=A.shape[0], problem=problem, cfg=cfg,
               axis_name=axis_name)


def _lasso_sample(ctx, key):
    return ctx.sampler(key)


def _lasso_assemble(ctx, vecs, idxs, s_grp):
    """LOCAL fused Gram/projection payload for the group's sampled
    columns: (handle, Y^T [Y | vecs]). ``handle`` (the dense sampled
    columns, or the sparse gather triple) feeds the deferred GEMVs."""
    flat = idxs.reshape(s_grp * ctx.mu)
    if ctx.sparse:
        return ctx.block_gram(flat, vecs)
    Y = ctx.A[:, flat]                                # (m_loc, s*mu) local
    return Y, gram_local(Y, vecs, ctx.cfg.use_pallas)


def _lasso_reduce(ctx, local, idxs, s_grp, vec_cols):
    return _reduce_gram_proj(local, s_grp * ctx.mu, vec_cols,
                             ctx.axis_name, ctx.cfg.symmetric_gram)


def _stepped_iterates(x, idxs, buf, s_grp, n, dtype):
    """Reconstruct the per-inner-iteration coordinate iterates from the
    final x and the step buffer, for objective stitching: (s_grp, n)."""
    dfull = jnp.zeros((s_grp, n), dtype).at[
        jnp.arange(s_grp)[:, None], idxs].add(buf)
    return (x - jnp.sum(dfull, 0))[None, :] + jnp.cumsum(dfull, axis=0), \
        dfull


# ---------------------------------------------------------------------------
# SA-BCD (non-accelerated): r_j = A_j^T r_sk + sum_{t<j} G[j,t] dx_t
# ---------------------------------------------------------------------------

def _bcd_setup(problem, cfg, axis_name, x0, carry0):
    ctx = _lasso_ctx(problem, cfg, axis_name)
    if carry0 is not None:
        x = jnp.asarray(carry0["x"], cfg.dtype)
        r = jnp.asarray(carry0["residual"], cfg.dtype)
    elif x0 is None:
        x = jnp.zeros((ctx.n,), cfg.dtype)
        r = -ctx.b
    else:
        x = jnp.asarray(x0, cfg.dtype)
        r = operand_matvec(ctx.A, x) - ctx.b
    return ctx, (x, r)


def _bcd_assemble(ctx, carry, idxs, s_grp):
    return _lasso_assemble(ctx, carry[1][:, None], idxs, s_grp)


def _bcd_reduce(ctx, local, idxs, s_grp):
    return _lasso_reduce(ctx, local, idxs, s_grp, 1)


def _bcd_inner(ctx, carry, handle, payload, idxs, win, s):
    x, r = carry
    cfg, mu = ctx.cfg, ctx.mu
    G, P = payload
    G4 = G.reshape(s, mu, s, mu)
    r_proj = P[:, 0].reshape(s, mu)

    def inner(inner_carry, j):
        x, dx_buf = inner_carry
        idx_j = idxs[j]
        Gj = G4[j]                                    # (mu, s, mu)
        cross = jnp.einsum("ptq,tq->tp", Gj, dx_buf)  # (s, mu)
        mask = (jnp.arange(s) < j).astype(cfg.dtype)
        rj = r_proj[j] + jnp.einsum("t,tp->p", mask, cross)
        v = linalg.power_iteration_max_eig(Gj[:, j, :], cfg.power_iters)
        eta = 1.0 / linalg.floor_eig(v)  # floored: zero block -> no-op
        g = x[idx_j] - eta * rj
        dx = ctx.prox(g, eta) - x[idx_j]
        x = x.at[idx_j].add(dx)
        dx_buf = dx_buf.at[j].set(dx)
        return (x, dx_buf), None

    (x, dx_buf), _ = jax.lax.scan(
        inner, (x, jnp.zeros((s, mu), cfg.dtype)), jnp.arange(s))
    return (x, r), dx_buf


def _bcd_defer(ctx, carry, handle, dx_buf, payload, idxs, win, s):
    x, r = carry
    cfg = ctx.cfg
    # Deferred residual update (Eq. 7): local GEMV / sparse scatter-adds
    steps = deferred_steps(ctx, handle, dx_buf, s)
    r_new = r + jnp.sum(steps, axis=0)

    if cfg.track_objective:
        r_steps = r[None, :] + jnp.cumsum(steps, axis=0)
        x_steps, _ = _stepped_iterates(x, idxs, dx_buf, s, ctx.n, cfg.dtype)
        objs = jax.vmap(
            lambda rr, xx: _objective(rr, xx, ctx.problem, ctx.axis_name))(
            r_steps, x_steps)
    else:
        objs = jnp.zeros((s,), cfg.dtype)
    return (x, r_new), objs


def _bcd_finalize(ctx, carry, sched):
    x, r = carry
    return x, {"residual": r}


_BCD_PROGRAM = FamilyProgram(
    name="sa_bcd_lasso", setup=_bcd_setup, sample=_lasso_sample,
    assemble=_bcd_assemble, reduce=_bcd_reduce, inner=_bcd_inner,
    defer=_bcd_defer, finalize=_bcd_finalize,
    carry_names=("x", "residual"), spmm_kind="col_gram", spmm_extra=1)


def sa_bcd_lasso(problem: LassoProblem, cfg: SolverConfig,
                 axis_name: Optional[object] = None,
                 x0=None, state: Optional[SolveState] = None) -> SolverResult:
    return run_program(_BCD_PROGRAM, problem, cfg, axis_name, x0, state)


# ---------------------------------------------------------------------------
# SA-accBCD — paper Algorithm 2.
# ---------------------------------------------------------------------------

def _acc_setup(problem, cfg, axis_name, x0, carry0):
    ctx = _lasso_ctx(problem, cfg, axis_name)
    if carry0 is not None:
        z = jnp.asarray(carry0["z"], cfg.dtype)
        y = jnp.asarray(carry0["y"], cfg.dtype)
        ztil = jnp.asarray(carry0["ztil"], cfg.dtype)
        ytil = jnp.asarray(carry0["ytil"], cfg.dtype)
    else:
        if x0 is None:
            z = jnp.zeros((ctx.n,), cfg.dtype)
            ztil = -ctx.b
        else:
            z = jnp.asarray(x0, cfg.dtype)
            ztil = operand_matvec(ctx.A, z) - ctx.b
        y = jnp.zeros((ctx.n,), cfg.dtype)
        ytil = jnp.zeros_like(ctx.b)
    return ctx, (z, y, ztil, ytil)


def _acc_schedule(ctx, cfg, total):
    theta0 = jnp.asarray(ctx.mu / ctx.n, cfg.dtype)
    return linalg.theta_schedule(theta0, total, ctx.q)    # (total+1,)


def _acc_assemble(ctx, carry, idxs, s_grp):
    z, y, ztil, ytil = carry
    return _lasso_assemble(ctx, jnp.stack([ytil, ztil], axis=1), idxs,
                           s_grp)


def _acc_reduce(ctx, local, idxs, s_grp):
    return _lasso_reduce(ctx, local, idxs, s_grp, 2)


def _acc_coefU(ctx, th_prev):
    """Alg. 2 lines 21-22 coefficient (1 - q θ_{j-1}) / θ_{j-1}^2."""
    return (1.0 - ctx.q * th_prev) / (th_prev * th_prev)


def _acc_inner(ctx, carry, handle, payload, idxs, win, s):
    z, y, ztil, ytil = carry
    cfg, mu, q = ctx.cfg, ctx.mu, ctx.q
    G, P = payload
    G4 = G.reshape(s, mu, s, mu)
    y_proj = P[:, 0].reshape(s, mu)                   # A_j^T ytil_sk
    z_proj = P[:, 1].reshape(s, mu)                   # A_j^T ztil_sk
    th_prev, _ = win
    coefU = _acc_coefU(ctx, th_prev)

    def inner(inner_carry, j):
        z, y, dz_buf = inner_carry
        idx_j = idxs[j]
        thp = th_prev[j]
        Gj = G4[j]                                    # (mu, s, mu)
        cross = jnp.einsum("ptq,tq->tp", Gj, dz_buf)  # (s, mu)
        # Eq. (3): coefficient (theta_{j-1}^2 * coefU_t - 1) on G[j,t] dz_t
        coef_t = thp * thp * coefU - 1.0              # (s,)
        mask = (jnp.arange(s) < j).astype(cfg.dtype)
        rj = thp * thp * y_proj[j] + z_proj[j] \
            - jnp.einsum("t,t,tp->p", mask, coef_t, cross)
        v = linalg.power_iteration_max_eig(Gj[:, j, :],
                                           cfg.power_iters)  # line 14
        eta = 1.0 / linalg.floor_eig(q * thp * v)     # line 15 (floored)
        g = z[idx_j] - eta * rj                       # Eq. (4)
        dz = ctx.prox(g, eta) - z[idx_j]              # Eq. (5)
        z = z.at[idx_j].add(dz)                       # line 19
        y = y.at[idx_j].add(-coefU[j] * dz)           # line 21
        dz_buf = dz_buf.at[j].set(dz)
        return (z, y, dz_buf), None

    (z, y, dz_buf), _ = jax.lax.scan(
        inner, (z, y, jnp.zeros((s, mu), cfg.dtype)), jnp.arange(s))
    return (z, y, ztil, ytil), dz_buf


def _acc_defer(ctx, carry, handle, dz_buf, payload, idxs, win, s):
    z, y, ztil, ytil = carry
    cfg = ctx.cfg
    th_prev, th_cur = win
    coefU = _acc_coefU(ctx, th_prev)
    # Deferred m-dimensional updates (paper Eqs. 7 & 9): local GEMVs
    # (sparse: O(nnz of the sampled columns) scatter-adds).
    steps = deferred_steps(ctx, handle, dz_buf, s)
    ztil_new = ztil + jnp.sum(steps, axis=0)
    ytil_new = ytil - jnp.einsum("t,tm->m", coefU, steps)

    if cfg.track_objective:
        ztil_steps = ztil[None, :] + jnp.cumsum(steps, axis=0)
        ytil_steps = ytil[None, :] - jnp.cumsum(
            coefU[:, None] * steps, axis=0)
        dz_full = jnp.zeros((s, ctx.n), cfg.dtype).at[
            jnp.arange(s)[:, None], idxs].add(dz_buf)
        z_steps = (z - jnp.sum(dz_full, 0))[None, :] \
            + jnp.cumsum(dz_full, axis=0)
        y_steps = (y + jnp.sum(coefU[:, None] * dz_full, 0))[None, :] \
            - jnp.cumsum(coefU[:, None] * dz_full, axis=0)
        th2 = (th_cur * th_cur)[:, None]
        objs = jax.vmap(
            lambda rr, xx: _objective(rr, xx, ctx.problem, ctx.axis_name))(
            th2 * ytil_steps + ztil_steps, th2 * y_steps + z_steps)
    else:
        objs = jnp.zeros((s,), cfg.dtype)
    return (z, y, ztil_new, ytil_new), objs


def _acc_finalize(ctx, carry, sched):
    z, y, ztil, ytil = carry
    thH = sched[-1]
    return thH * thH * y + z, {"residual": thH * thH * ytil + ztil}


_ACC_PROGRAM = FamilyProgram(
    name="sa_acc_bcd_lasso", setup=_acc_setup, sample=_lasso_sample,
    assemble=_acc_assemble, reduce=_acc_reduce, inner=_acc_inner,
    defer=_acc_defer, finalize=_acc_finalize,
    carry_names=("z", "y", "ztil", "ytil"), schedule=_acc_schedule,
    spmm_kind="col_gram", spmm_extra=2)


def sa_acc_bcd_lasso(problem: LassoProblem, cfg: SolverConfig,
                     axis_name: Optional[object] = None,
                     x0=None, state: Optional[SolveState] = None
                     ) -> SolverResult:
    return run_program(_ACC_PROGRAM, problem, cfg, axis_name, x0, state)


def sa_cd_lasso(problem, cfg, axis_name=None, x0=None, state=None):
    require_unit_block(cfg, "sa_cd_lasso")
    return sa_bcd_lasso(problem, cfg, axis_name, x0, state)


def sa_acc_cd_lasso(problem, cfg, axis_name=None, x0=None, state=None):
    require_unit_block(cfg, "sa_acc_cd_lasso")
    return sa_acc_bcd_lasso(problem, cfg, axis_name, x0, state)
