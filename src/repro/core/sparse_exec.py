"""Operand-polymorphic block operations: the one place the solver
families branch on dense array vs :class:`~repro.core.types.SparseOperand`.

Each factory returns closures over the prepared operand, so the solver
bodies stay a single code path — they call ``take`` / ``gram`` /
``apply`` and never touch the layout. The dense closures are the exact
expressions the solvers used before sparse operands existed (same
operation order — the dense paths stay bit-identical); the sparse
closures execute only nnz work via ``repro.kernels.spmm``:

  * column layout (Lasso, A row-partitioned, COLUMNS sampled):
    ``col_block_ops`` — the fused (mu, mu + k) Gram/projection block
    A_B^T [A_B | vecs] and the deferred residual update A_B @ dx;
  * row layout (SVM / K-SVM / logreg, A column-partitioned, ROWS
    sampled): ``row_block_ops`` — the fused Y [Y^T | vecs] block, the
    densified sample Y^T (the cross product's right operand), and the
    deferred shard update Y^T @ coef;
  * ``cross_block`` — the (m, c) cross product A @ Y^T the kernel-SVM
    and logreg families communicate.

All local (pre-Allreduce) quantities; communication stays in the
solvers. ``use_pallas`` routes the SpMM through the blocked-ELL Pallas
kernel (``repro.kernels.spmm``), subject to its VMEM guard.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.types import SparseOperand
from repro.kernels import spmm


def prep_operand(A, dtype):
    """Cast a problem's data matrix — dense or sparse — to the solver
    dtype (the sparse analogue of ``jnp.asarray(A, dtype)``)."""
    if isinstance(A, SparseOperand):
        return A.astype(dtype)
    return jnp.asarray(A, dtype)


def col_block_ops(A, cfg):
    """(block_gram, block_apply) for the column-sampling (Lasso) layout.

    block_gram(idx, vecs) -> (handle, local) with
        local = A_B^T [A_B | vecs]   (mu, mu + k), LOCAL (pre-reduce);
    block_apply(handle, coef) -> A_B @ coef   (m_loc,).
    """
    if isinstance(A, SparseOperand):
        m_loc = A.shape[0]

        def block_gram(idx, vecs):
            handle = A.gather_cols(idx)
            rows, vals, nnb = handle
            Yd = spmm.scatter_dense(rows, vals, m_loc)
            local = spmm.ell_spmm(vals, rows, nnb,
                                  jnp.concatenate([Yd, vecs], axis=1),
                                  ell_block=A.ell_block,
                                  use_pallas=cfg.use_pallas)
            return handle, local.astype(A.dtype)

        def block_apply(handle, coef):
            rows, vals, _ = handle
            return spmm.scatter_add(jnp.zeros((m_loc,), A.dtype),
                                    rows, vals, coef)

        return block_gram, block_apply

    def block_gram(idx, vecs):
        Ah = A[:, idx]
        return Ah, Ah.T @ jnp.concatenate([Ah, vecs], axis=1)

    def block_apply(Ah, coef):
        return Ah @ coef

    return block_gram, block_apply


def row_block_ops(A, cfg):
    """(take, gram, densify, apply_t) for the row-sampling (SVM/logreg)
    layout.

    take(idx) -> handle for the sampled rows Y = A[idx];
    gram(handle, vecs) -> Y [Y^T | vecs]   (r, r + k), LOCAL;
    densify(handle) -> Y^T   (n_loc, r) dense (the cross product's
        right operand);
    apply_t(handle, coef) -> Y^T @ coef   (n_loc,).
    """
    if isinstance(A, SparseOperand):
        n_loc = A.shape[1]

        def take(idx):
            return A.gather_rows(idx)

        def gram(handle, vecs):
            cols, vals, nnb = handle
            local = spmm.ell_spmm(
                vals, cols, nnb,
                jnp.concatenate([spmm.scatter_dense(cols, vals, n_loc),
                                 vecs], axis=1),
                ell_block=A.ell_block, use_pallas=cfg.use_pallas)
            return local.astype(A.dtype)

        def densify(handle):
            cols, vals, _ = handle
            return spmm.scatter_dense(cols, vals, n_loc)

        def apply_t(handle, coef):
            cols, vals, _ = handle
            return spmm.scatter_add(jnp.zeros((n_loc,), A.dtype),
                                    cols, vals, coef)

        return take, gram, densify, apply_t

    def take(idx):
        return A[idx]

    def gram(Y, vecs):
        return Y @ jnp.concatenate([Y.T, vecs], axis=1)

    def densify(Y):
        return Y.T

    def apply_t(Y, coef):
        return Y.T @ coef

    return take, gram, densify, apply_t


def spmm_aux(A, cfg, kind: str, H=None, extra: int = 0) -> dict:
    """The ``aux["spmm_impl"]`` entry for a sparse solve — empty for
    dense operands. ONE place derives the (R, K, C, Q) SpMM shape from
    the layout, so the surfaced label cannot drift from the shapes the
    solver actually dispatches:

      * "col_gram" — Lasso fused  A_B^T [A_B | vecs]  (columns sampled);
      * "row_gram" — SVM fused    Y [Y^T | vecs]      (rows sampled);
      * "cross"    — K-SVM/logreg cross block  A Y^T.

    ``extra`` is the appended-vector count k. H=None labels a classical
    (one block per iteration) solve; otherwise the grouped main+tail
    label over the SA schedule (H, cfg.s).
    """
    if not isinstance(A, SparseOperand):
        return {}
    mu = cfg.block_size
    if kind == "col_gram":
        K, C = A.col_rows.shape[1], A.shape[0]
        def shape(g):
            return (g * mu, K, C, g * mu + extra)
    elif kind == "row_gram":
        K, C = A.row_cols.shape[1], A.shape[1]
        def shape(g):
            return (g * mu, K, C, g * mu + extra)
    elif kind == "cross":
        K, C = A.row_cols.shape[1], A.shape[1]
        def shape(g):
            return (A.shape[0], K, C, g * mu)
    else:
        raise ValueError(f"unknown spmm layout kind {kind!r}")
    itemsize = jnp.dtype(cfg.dtype).itemsize
    if H is None:
        return {"spmm_impl": spmm.spmm_impl(*shape(1), cfg.use_pallas,
                                            itemsize)}
    return {"spmm_impl": spmm.grouped_spmm_label(H, cfg.s, shape,
                                                 cfg.use_pallas,
                                                 itemsize)}


def cross_block(A, YT, use_pallas: bool = False):
    """LOCAL cross product A @ Y^T: the (m, c) block the kernel-SVM and
    logreg families Allreduce. ``YT`` is the (n_loc, c) dense right
    operand (``densify(handle)`` for a sampled block, ``A.T`` for the
    full-matrix oracle paths); a sparse A contracts its row-major ELL
    arrays — O(nnz * c) instead of O(m * n_loc * c)."""
    if isinstance(A, SparseOperand):
        local = spmm.ell_spmm(A.row_vals, A.row_cols, A.row_blocks, YT,
                              ell_block=A.ell_block,
                              use_pallas=use_pallas)
        return local.astype(A.dtype)
    return A @ YT
