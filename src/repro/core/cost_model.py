"""Analytical cost model — paper Table I — plus an alpha-beta-gamma machine
model that predicts running times, speedups, and the optimal unrolling
parameter s. Used by ``benchmarks/paper/table1_costs.py``,
``fig4_scaling.py`` and ``table5_svm_speedup.py``.

Paper Table I (critical-path costs; A sparse with density f, H iterations,
block size mu, P processors, s = unrolling parameter):

  accBCD:     F = O(H mu^2 f m / P + H mu^3)    L = O(H log P)
              W = O(H mu^2 log P)               M = O(fmn/P + m/P + mu^2 + n)
  SA-accBCD:  F = O(H mu^2 s f m / P + H mu^3)  L = O(H/s log P)
              W = O(H s mu^2 log P)             M = O(fmn/P + m/P + mu^2 s^2 + n)

The machine model assigns time
  T = gamma * F  +  beta * W  +  alpha * L
with per-flop time gamma, per-word time beta, per-message latency alpha.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict


@dataclasses.dataclass(frozen=True)
class Machine:
    """alpha-beta-gamma-kappa machine parameters (seconds, words = 8 B).

    kappa is the per-inner-iteration serial overhead (BLAS dispatch,
    subproblem solve bookkeeping) that communication-avoiding does NOT
    remove — both classical and SA execute H inner iterations. Without it
    the model predicts speedup -> alpha*logP/0 as s grows; with it the
    speedup saturates at ~(alpha*logP + kappa)/kappa, which is what the
    paper measures (1.2x-5.1x)."""
    name: str
    alpha: float     # latency per message (s)
    beta: float      # inverse bandwidth, per 8-byte word (s/word)
    gamma: float     # time per flop (s/flop)
    kappa: float = 0.0   # per-inner-iteration overhead (s)

    @classmethod
    def cray_xc30(cls) -> "Machine":
        # Aries interconnect: ~1.3 us latency, ~8 GB/s per-core effective BW,
        # ~10 GFLOP/s per-core DGEMM, ~3 us per-iteration serial overhead.
        return cls("cray-xc30", alpha=1.3e-6, beta=8.0 / 8e9,
                   gamma=1.0 / 10e9, kappa=3.0e-6)

    @classmethod
    def tpu_v5e_pod(cls) -> "Machine":
        # Per-chip: 197 TFLOP/s bf16, ICI ~50 GB/s/link; collective launch
        # overhead on the order of ~5 us; ~1 us per fused inner step (the
        # sa_inner kernel runs all s steps in one launch).
        return cls("tpu-v5e", alpha=5.0e-6, beta=8.0 / 50e9,
                   gamma=1.0 / 197e12, kappa=1.0e-6)


@dataclasses.dataclass(frozen=True)
class ProblemDims:
    m: int           # data points
    n: int           # features
    f: float         # density (nnz / (m*n))


def lasso_costs(dims: ProblemDims, H: int, mu: int, s: int, P: int
                ) -> Dict[str, float]:
    """Table I entries for (SA-)accBCD. s=1 gives the classical column."""
    logP = max(math.log2(max(P, 2)), 1.0)
    F = H * mu * mu * s * dims.f * dims.m / P + H * mu ** 3
    L = (H / s) * logP
    W = H * s * mu * mu * logP
    M = (dims.f * dims.m * dims.n + dims.m) / P + mu * mu * s * s + dims.n
    return {"F": F, "L": L, "W": W, "M": M, "I": float(H)}


# Approximate flop cost of one kernel-function evaluation, given the
# already-computed linear cross product (transform applied on the
# replicated post-Allreduce block): exp/pow and the norm combine.
KERNEL_EVAL_FLOPS = {"linear": 0.0, "poly": 3.0, "rbf": 5.0}


def svm_costs(dims: ProblemDims, H: int, s: int, P: int,
              mu: int = 1, kernel: str = "linear") -> Dict[str, float]:
    """(SA-)BDCD SVM analogue of Table I: mu dual coordinates per
    iteration, Gram is (s*mu) x (s*mu). mu = 1, s = 1 is classical DCD.

    Linear (kernel="linear", the paper's Alg. 3-4 / BDCD): per inner
    iteration the Gram/projection GEMM costs mu^2 s f n / P flops
    (amortized over the outer group), the redundant inner updates cost
    s mu^2 (cross terms), the mu x mu subproblem mu^3 (power iteration).
    The Allreduce moves s mu^2 words every s iterations ->
    W = H s mu^2 log P at L = (H/s) log P messages.

    Kernelized ((SA-)K-BDCD, arXiv:2406.18001): the per-group message is
    the (m, s*mu) cross block A Y^T (the m-dimensional dual residual f
    replaces the n/P-partitioned primal), so W grows to H mu m log P and
    F gains the cross-product GEMM m mu s f n / P plus the
    kernel-evaluation transform c_k m mu per inner iteration
    (c_k = KERNEL_EVAL_FLOPS[kernel], applied on the replicated reduced
    block — kernelizing adds NO messages and NO latency). L is unchanged:
    still one Allreduce per outer iteration.
    """
    logP = max(math.log2(max(P, 2)), 1.0)
    F = H * mu * mu * s * dims.f * dims.n / P + H * s * mu * mu \
        + H * mu ** 3
    L = (H / s) * logP
    W = H * s * mu * mu * logP
    M = (dims.f * dims.m * dims.n) / P + dims.m + s * s * mu * mu \
        + dims.n / P
    if kernel != "linear":
        if kernel not in KERNEL_EVAL_FLOPS:
            raise ValueError(
                f"unknown kernel {kernel!r}; known: "
                f"{sorted(KERNEL_EVAL_FLOPS)}")
        ck = KERNEL_EVAL_FLOPS[kernel]
        # cross-product GEMM + kernel transform + the f/alpha GEMV work,
        # all per inner iteration (amortized over the outer group).
        F = H * mu * dims.m * dims.f * dims.n / P \
            + ck * H * mu * dims.m + H * s * mu * mu + H * mu ** 3 \
            + H * mu * dims.m
        W = H * mu * dims.m * logP
        M = (dims.f * dims.m * dims.n) / P + 3.0 * dims.m \
            + s * mu * dims.m + s * s * mu * mu
    return {"F": F, "L": L, "W": W, "M": M, "I": float(H)}


def logreg_costs(dims: ProblemDims, H: int, mu: int, s: int, P: int
                 ) -> Dict[str, float]:
    """(SA-)BCD logistic regression (arXiv:2011.08281 regime): the
    per-group message is the (m, s*mu) cross block A Y^T (the replicated
    margin vector f plays the role the kernel SVM's dual residual does),
    so W = H mu m log P at L = (H/s) log P messages — kernel-SVM message
    shape with linear-SVM flops: the cross GEMM mu s f n / P plus the
    O(m mu) margin update and the mu^3 subproblem per inner iteration.
    """
    logP = max(math.log2(max(P, 2)), 1.0)
    F = H * mu * dims.m * dims.f * dims.n / P + H * mu * dims.m \
        + H * s * mu * mu + H * mu ** 3
    L = (H / s) * logP
    W = H * mu * dims.m * logP
    M = (dims.f * dims.m * dims.n) / P + 3.0 * dims.m + s * mu * dims.m \
        + dims.n / P
    return {"F": F, "L": L, "W": W, "M": M, "I": float(H)}


def logreg_speedup(dims: ProblemDims, H: int, s: int, P: int,
                   machine: Machine, mu: int = 1) -> float:
    t1 = predicted_time(logreg_costs(dims, H, mu, 1, P), machine)
    ts = predicted_time(logreg_costs(dims, H, mu, s, P), machine)
    return t1 / ts


# The machine model is LINEAR in the machine parameters: T = theta . c
# with theta = (gamma, beta, alpha, kappa) and c = (F, W, L, I). The
# autotuner (repro.tune) exploits this — calibration is a (weighted)
# least-squares fit of theta to measured pilot solves, so the per-term
# cost vectors are public alongside the summed predicted_time.
COST_TERMS = ("F", "W", "L", "I")


def cost_vector(costs: Dict[str, float]):
    """The (F, W, L, I) per-term cost vector of a Table-I cost dict —
    the calibration feature row for one (s, mu) configuration. F/W/L
    are required (a malformed costs hook must fail loudly, not predict
    a near-zero time the tuner would then 'prefer'); I defaults to 0
    for cost dicts that predate the kappa term."""
    return (float(costs["F"]), float(costs["W"]), float(costs["L"]),
            float(costs.get("I", 0.0)))


def machine_vector(machine: Machine):
    """(gamma, beta, alpha, kappa) — the parameter vector paired with
    :func:`cost_vector` (same term order)."""
    return (machine.gamma, machine.beta, machine.alpha, machine.kappa)


def machine_from_vector(vec, name: str = "calibrated") -> Machine:
    """Inverse of :func:`machine_vector`."""
    gamma, beta, alpha, kappa = (float(v) for v in vec)
    return Machine(name=name, alpha=alpha, beta=beta, gamma=gamma,
                   kappa=kappa)


def time_breakdown(costs: Dict[str, float], machine: Machine
                   ) -> Dict[str, float]:
    """Per-term seconds — which of flops / bandwidth / latency /
    per-iteration overhead dominates a configuration's predicted time."""
    return {term: p * c for term, p, c in
            zip(COST_TERMS, machine_vector(machine), cost_vector(costs))}


def predicted_time(costs: Dict[str, float], machine: Machine) -> float:
    return sum(p * c for p, c in
               zip(machine_vector(machine), cost_vector(costs)))


def lasso_speedup(dims: ProblemDims, H: int, mu: int, s: int, P: int,
                  machine: Machine) -> float:
    """T(classical) / T(SA with unrolling s)."""
    t1 = predicted_time(lasso_costs(dims, H, mu, 1, P), machine)
    ts = predicted_time(lasso_costs(dims, H, mu, s, P), machine)
    return t1 / ts


def svm_speedup(dims: ProblemDims, H: int, s: int, P: int,
                machine: Machine, mu: int = 1,
                kernel: str = "linear") -> float:
    t1 = predicted_time(svm_costs(dims, H, 1, P, mu, kernel), machine)
    ts = predicted_time(svm_costs(dims, H, s, P, mu, kernel), machine)
    return t1 / ts


def best_s(dims: ProblemDims, H: int, mu: int, P: int, machine: Machine,
           candidates=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024),
           kind: str = "lasso", kernel: str = "linear"):
    """Sweep s and return (s*, speedup(s*)) — the paper's tuning knob.

    The existence of an interior optimum (speedup rises with s while
    latency dominates, then falls once the s*mu^2 bandwidth/flop terms take
    over) reproduces the qualitative shape of paper Fig. 4e-h.

    kind selects the cost formula: "lasso" (Table I), "svm" (the
    (SA-)(K-)BDCD analogue; ``kernel`` selects the message/flop regime),
    or "logreg" (the CA-logistic-regression regime). Unknown kinds raise
    — historically anything that wasn't "lasso" was silently modeled
    with the SVM formula, so kind="logreg" returned SVM speedups.
    """
    if kind == "lasso":
        def fn(s):
            return lasso_speedup(dims, H, mu, s, P, machine)
    elif kind == "svm":
        def fn(s):
            return svm_speedup(dims, H, s, P, machine, mu, kernel)
    elif kind == "logreg":
        def fn(s):
            return logreg_speedup(dims, H, s, P, machine, mu)
    else:
        raise ValueError(
            f"unknown kind {kind!r}; known: 'lasso', 'svm', 'logreg'")
    best = max(candidates, key=fn)
    return best, fn(best)


# Paper Table II / IV dataset shape regimes (for benchmarks; we generate
# synthetic analogues scaled to CPU-feasible sizes — see repro.data.sparse).
PAPER_DATASETS = {
    "url": ProblemDims(m=2_396_130, n=3_231_961, f=3.6e-5),
    "news20": ProblemDims(m=15_935, n=62_061, f=1.3e-3),
    "covtype": ProblemDims(m=581_012, n=54, f=0.22),
    "epsilon": ProblemDims(m=400_000, n=2_000, f=1.0),
    "leu": ProblemDims(m=38, n=7_129, f=1.0),
    "w1a": ProblemDims(m=300, n=2_477, f=0.04),
    "duke": ProblemDims(m=44, n=7_129, f=1.0),
    "news20.binary": ProblemDims(m=1_355_191, n=19_996, f=3.0e-4),
    "rcv1.binary": ProblemDims(m=47_236, n=20_242, f=1.6e-3),
    "gisette": ProblemDims(m=5_000, n=6_000, f=0.99),
}
