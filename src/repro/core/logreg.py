"""Block coordinate-descent (mini-batch) logistic regression — the
communication structure of CA-logistic-regression (Devarakonda & Demmel,
arXiv:2011.08281), in the repo's solver conventions.

Problem:  min_w  (1/m) sum_i log(1 + exp(-b_i a_i^T w)) + lam/2 ||w||^2

Layout (identical to the kernel SVM): A is 1D-COLUMN-partitioned
(m, n_loc), w in R^n is partitioned alongside; b in R^m, the margin
vector f = A w in R^m, and all scalars are replicated.

Per iteration: sample a block B of mu data points, Allreduce the fused
(m, mu) cross block  A Y^T  (ONE message — the replicated margins make
the block gradient a pure gather), and take the damped stochastic
block-gradient step

    w <- (1 - eta lam) w - (eta/mu) Y^T c,
    c_i = -b_i sigma(-b_i f[i])        (sigma = logistic function),

with eta = 1 / (lambda_max(Y Y^T)/(4 mu) + lam) from the existing power
iteration (the logistic loss has curvature at most 1/4, so
lambda_max/(4 mu) bounds the block-mean Hessian; exact diagonal entry at
mu = 1). The margins and the replicated squared norm ||w||^2 update
locally from the SAME reduced cross block:

    f  <- (1 - eta lam) f - (eta/mu) (A Y^T) c
    sq <- d^2 sq + 2 d (f_B . u) + u^T (Y Y^T) u,   d = 1 - eta lam,
                                                    u = -(eta/mu) c

(f_B gathered BEFORE the update = Y w), so the exact full objective is
tracked after every inner iteration with zero extra communication —
``Y Y^T`` is the B-rows slice of the cross block already in hand.
Derivation in DESIGN.md ("SA logistic regression").

This module exists to prove the ``repro.api`` registry claim: the family
registers itself below and is reachable from ``repro.api.solve``, the
generic sharded backend, the launcher and the benchmarks with ZERO edits
to any of them.

``cfg.accelerated`` is ignored (no accelerated variant, as for SVM);
``cfg.symmetric_gram`` does not apply (the (m, mu) cross block is not
symmetric) and is ignored, as in the kernel SVM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model, linalg
from repro.core.sparse_exec import (cross_block, prep_operand,
                                    row_block_ops, spmm_aux)
from repro.core.types import (LogRegProblem, SolveState, SolverConfig,
                              SolverResult, SparseOperand, operand_matvec,
                              register_family, resume_carry)


def logreg_objective(problem: LogRegProblem, w,
                     axis_name: Optional[object] = None):
    """Direct evaluation  (1/m) sum_i log(1+exp(-b_i a_i^T w))
    + lam/2 ||w||^2.  In distributed (column-partitioned) mode w is the
    local shard and the matvec A w needs one Allreduce."""
    A = problem.A if isinstance(problem.A, SparseOperand) \
        else jnp.asarray(problem.A)
    w = jnp.asarray(w, A.dtype)
    b = jnp.asarray(problem.b, A.dtype)
    margins = linalg.preduce(operand_matvec(A, w), axis_name)  # (m,)
    sq = linalg.preduce(jnp.sum(w * w), axis_name)
    loss = jnp.mean(jnp.logaddexp(0.0, -b * margins))
    return loss + 0.5 * problem.lam * sq


def _tracked_objective(f, sq, b, lam):
    """Objective from the maintained margins f = A w and sq = ||w||^2 —
    replicated data only, no communication."""
    return jnp.mean(jnp.logaddexp(0.0, -b * f)) + 0.5 * lam * sq


def _init_state(problem: LogRegProblem, cfg: SolverConfig, axis_name, x0,
                carry0=None):
    """w (local shard), margins f = A w and sq = ||w||^2 (replicated).
    x0 = None starts at zero, where f and sq are zero without any
    communication; a warm start rebuilds them with one setup Allreduce.
    A restored ``carry0`` (SolveState.carry) restores all three leaves
    verbatim — no matvec, no Allreduce."""
    A = prep_operand(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    if carry0 is not None:
        return (A, b, jnp.asarray(carry0["w"], cfg.dtype),
                jnp.asarray(carry0["margins"], cfg.dtype),
                jnp.asarray(carry0["sq"], cfg.dtype))
    if x0 is None:
        w = jnp.zeros((A.shape[1],), cfg.dtype)
        f = jnp.zeros((A.shape[0],), cfg.dtype)
        sq = jnp.asarray(0.0, cfg.dtype)
        return A, b, w, f, sq
    w = jnp.asarray(x0, cfg.dtype)
    packed = linalg.preduce(
        jnp.concatenate([operand_matvec(A, w), jnp.sum(w * w)[None]]),
        axis_name)
    return A, b, w, packed[:-1], packed[-1]


def _step_size(G, mu: int, lam, power_iters: int):
    """eta = 1 / (lambda_max(Y Y^T)/(4 mu) + lam); the (1, 1) block IS
    the eigenvalue at mu = 1 (skip the power loop, as in BDCD)."""
    v = G[0, 0] if mu == 1 else linalg.power_iteration_max_eig(G, power_iters)
    return 1.0 / (0.25 * v / mu + lam)


def bcd_logreg(problem: LogRegProblem, cfg: SolverConfig,
               axis_name: Optional[object] = None,
               x0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Classical (synchronous) block CD / mini-batch logistic regression:
    ONE fused Allreduce of the (m, mu) cross block per iteration."""
    mu = cfg.block_size
    lam = jnp.asarray(problem.lam, cfg.dtype)
    key = jax.random.key(cfg.seed)
    carry0 = resume_carry(state, x0, "bcd_logreg")
    start = 0 if state is None else int(state.iteration)
    A, b, w, f, sq = _init_state(problem, cfg, axis_name, x0, carry0)
    take, _, densify, apply_t = row_block_ops(A, cfg)
    m = A.shape[0]

    def step(carry, h):
        w, f, sq = carry
        idx = linalg.sample_block(jax.random.fold_in(key, h), m, mu)
        Y = take(idx)                                    # (mu, n_loc) local
        # --- Communication: ONE fused Allreduce of  A Y^T ---
        cross = linalg.preduce(
            cross_block(A, densify(Y), cfg.use_pallas), axis_name)  # (m, mu)
        G = cross[idx]                                   # (mu, mu) = Y Y^T
        fB = f[idx]                                      # = Y w (gather)
        c = -b[idx] * jax.nn.sigmoid(-b[idx] * fB)
        eta = _step_size(G, mu, lam, cfg.power_iters)
        d = 1.0 - eta * lam
        u = -(eta / mu) * c                              # (mu,)
        w = d * w + apply_t(Y, u)                        # local shard
        sq = d * d * sq + 2.0 * d * (fB @ u) + u @ (G @ u)
        f = d * f + cross @ u                            # replicated
        obj = _tracked_objective(f, sq, b, lam) if cfg.track_objective \
            else jnp.asarray(0.0, cfg.dtype)
        return (w, f, sq), obj

    (w, f, sq), objs = jax.lax.scan(
        step, (w, f, sq), jnp.arange(start + 1, start + cfg.iterations + 1))
    return SolverResult(x=w, objective=objs,
                        aux={"margins": f, "w_norm_sq": sq,
                             "state": SolveState(
                                 start + cfg.iterations,
                                 {"w": w, "margins": f, "sq": sq}),
                             **spmm_aux(A, cfg, "cross")})


def _cli_problem(args):
    from repro.data.sparse import make_svm_dataset
    A, b = make_svm_dataset(args.dataset, args.seed)
    return LogRegProblem(A=A, b=b, lam=args.logreg_l2)


def _cli_describe(args, res, elapsed: float) -> str:
    import numpy as np
    obj = np.asarray(res.objective)
    return (f"logreg {args.dataset} s={args.s} mu={args.mu}: "
            f"obj {obj[0]:.5f} -> {obj[-1]:.5f}, {elapsed:.2f}s")


@register_family(
    "logreg",
    problem_cls=LogRegProblem,
    partition="col",
    default_axes="model",
    x0_layout="partition",           # warm start = w, on the feature axis
    aux_out=(("margins", "replicated"),),
    variants={
        "classical": "repro.core.logreg:bcd_logreg",
        "sa": "repro.core.sa_logreg:sa_bcd_logreg",
    },
    objective=logreg_objective,
    costs=lambda dims, H, mu, s, P, kernel="linear": cost_model.logreg_costs(
        dims, H, mu, s, P),
    make_problem=_cli_problem,
    describe=_cli_describe,
    default_mu=4,
    bench_block_size=2,
    bench_problem_kwargs={"lam": 1e-3},
    # same (m, s*mu) cross-block message shape as the kernel SVM.
    tune_space={"s": (1, 2, 4, 8, 16, 32), "mu": (1, 2, 4, 8)},
    state_layout=lambda cfg: (("w", "partition"), ("margins", "replicated"),
                              ("sq", "replicated")),
)
def solve_logreg(problem: LogRegProblem, cfg: SolverConfig,
                 axis_name: Optional[object] = None,
                 x0=None, state=None) -> SolverResult:
    """Dispatch on cfg.s: classical BCD vs the SA s-step unroll.

    ``cfg.accelerated`` is ignored (no accelerated variant, as for SVM).
    """
    if cfg.s > 1:
        from repro.core.sa_logreg import sa_bcd_logreg
        return sa_bcd_logreg(problem, cfg, axis_name, x0, state)
    return bcd_logreg(problem, cfg, axis_name, x0, state)
