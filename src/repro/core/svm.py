"""(Block) dual coordinate descent for linear SVM — paper Algorithm 3
(after Hsieh et al., 2008) and its block generalization BDCD (after
Devarakonda et al., arXiv:1612.04003), for both hinge (SVM-L1) and
squared-hinge (SVM-L2).

Partitioning (paper Sec. V): unlike Lasso, SVM requires 1D-COLUMN
partitioning so the row/primal dot-products parallelize. In distributed
mode A holds the local column shard (m, n_loc); x in R^n is partitioned;
alpha in R^m, b in R^m and all scalars are replicated.

Per-iteration communication: ONE fused Allreduce of the (mu, mu+1)
matrix  Y [Y^T | x]  — the block Gram plus projection (paper
"Communication: lines 7 and 8"; for mu = 1 this is the two scalars
[ ||A_i||^2 , A_i x ]).

The dual objective  f_D(alpha) = 1/2 alpha^T Qbar alpha - e^T alpha  is
tracked *exactly* and incrementally per iteration with local
O(mu^2)-sized data only: for a block update alpha_B += theta,
    delta f_D = theta^T g_B + 1/2 (b_B theta)^T G (b_B theta)
where g_B = (Qbar alpha)_B - 1 is the gradient the step already computes
and G = Y Y^T + gamma I the reduced block; for mu = 1 this collapses to
theta * g + 1/2 theta^2 * eta. (Derivation in DESIGN.md; validated
against the direct quadratic form in tests.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cost_model, linalg
from repro.core.sparse_exec import prep_operand, row_block_ops, spmm_aux
from repro.core.types import (SVMProblem, SolveState, SolverConfig,
                              SolverResult, operand_matvec, operand_rmatvec,
                              register_family, require_unit_block,
                              resume_carry)


def primal_objective(problem: SVMProblem, x, axis_name: Optional[object] = None):
    """P(x) = 1/2 ||x||^2 + lam * sum_i loss(1 - b_i A_i x).

    In distributed (column-partitioned) mode, x is the local shard and the
    matvec A x needs one Allreduce.
    """
    margins = linalg.preduce(operand_matvec(problem.A, x), axis_name)  # (m,)
    xi = jnp.maximum(1.0 - problem.b * margins, 0.0)
    loss = jnp.sum(xi) if problem.loss == "l1" else jnp.sum(xi * xi)
    sq = linalg.preduce(jnp.sum(x * x), axis_name)
    return 0.5 * sq + problem.lam * loss


def dual_objective(problem: SVMProblem, alpha, axis_name: Optional[object] = None):
    """f_D(alpha) = 1/2 alpha^T Qbar alpha - e^T alpha (direct evaluation)."""
    w = operand_rmatvec(problem.A, problem.b * alpha)    # (n_loc,) local
    quad = linalg.preduce(jnp.sum(w * w), axis_name)
    return 0.5 * quad + 0.5 * problem.gamma * jnp.sum(alpha * alpha) \
        - jnp.sum(alpha)


def duality_gap(problem: SVMProblem, x, alpha,
                axis_name: Optional[object] = None):
    """P(x) + f_D(alpha) >= 0, == 0 at the optimum (strong duality)."""
    return primal_objective(problem, x, axis_name) \
        + dual_objective(problem, alpha, axis_name)


def bdcd_svm(problem: SVMProblem, cfg: SolverConfig,
             axis_name: Optional[object] = None,
             alpha0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Block dual coordinate descent (BDCD) for linear SVM.

    Paper Algorithm 3 generalized to block updates of mu = cfg.block_size
    dual coordinates per iteration, following the CA-BDCD derivation of
    Devarakonda et al. (arXiv:1612.04003): sample a block B of mu rows,
    Allreduce the fused (mu, mu+1) matrix  Y [Y^T | x]  (Gram block plus
    projection, ONE message), and take the projected block-gradient step

        alpha_B <- clip(alpha_B - g_B / lambda_max(Q_BB), 0, nu)

    with lambda_max from the existing power-iteration machinery. Because
    b_i in {-1, +1}, diag(b_B) is orthogonal and
    lambda_max(Q_BB) = lambda_max(Y Y^T + gamma I), so the power method
    runs directly on the reduced Gram block. mu = 1 recovers Algorithm 3
    exactly (eta = ||a_i||^2 + gamma, scalar step).

    The dual objective is tracked incrementally (DESIGN.md): for a block
    update alpha_B += theta,
        delta f_D = theta^T g_B + 1/2 (b_B theta)^T G (b_B theta)
    where G = Y Y^T + gamma I is the reduced block the step already holds.
    """
    A = prep_operand(problem.A, cfg.dtype)
    take, gram, _, apply_t = row_block_ops(A, cfg)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    mu = cfg.block_size
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    nu = jnp.asarray(problem.nu, cfg.dtype)
    key = jax.random.key(cfg.seed)
    carry0 = resume_carry(state, alpha0, "bdcd_svm")
    start = 0 if state is None else int(state.iteration)

    if carry0 is not None:
        # resume: alpha, the primal shard x AND the running dual come
        # back from the checkpoint — no matvec, no Allreduce, so the
        # resumed sequence is bit-identical to the uninterrupted one.
        alpha = jnp.asarray(carry0["alpha"], cfg.dtype)
        x = jnp.asarray(carry0["x"], cfg.dtype)
        dual0 = jnp.asarray(carry0["dual"], cfg.dtype)
    else:
        alpha = jnp.zeros((m,), cfg.dtype) if alpha0 is None \
            else jnp.asarray(alpha0, cfg.dtype)
        x = operand_rmatvec(A, b * alpha)                # line 2 (local shard)
        # incremental tracking resumes from f_D(alpha0) on warm start (zero
        # at alpha0 = 0 without any communication), so a warm-started
        # solve's objective trace continues the previous solve's. Reuses
        # the x we just built:
        # f_D(alpha) = 1/2 ||A^T(b a)||^2 + gamma/2 ||a||^2 - e^T a.
        dual0 = jnp.asarray(0.0, cfg.dtype) if alpha0 is None else (
            0.5 * linalg.preduce(jnp.sum(x * x), axis_name)
            + 0.5 * gamma * jnp.sum(alpha * alpha) - jnp.sum(alpha))
    eye_mu = jnp.eye(mu, dtype=cfg.dtype)

    def step(carry, h):
        alpha, x, dual = carry
        idx = linalg.sample_block(jax.random.fold_in(key, h), m, mu)
        Y = take(idx)                                    # (mu, n_loc) local
        b_B = b[idx]
        # --- Communication: ONE fused Allreduce of  Y [Y^T | x] ---
        red = linalg.preduce(gram(Y, x[:, None]), axis_name)
        G = red[:, :mu] + gamma * eye_mu                 # line 7 (block)
        a_B = alpha[idx]
        g = b_B * red[:, mu] - 1.0 + gamma * a_B         # line 8 (block)
        # mu = 1: the (1, 1) Gram "block" IS the eigenvalue (paper
        # Alg. 3's eta = ||a_i||^2 + gamma) — skip the power loop.
        v = G[0, 0] if mu == 1 \
            else linalg.power_iteration_max_eig(G, cfg.power_iters)
        gbar = jnp.abs(jnp.clip(a_B - g, 0.0, nu) - a_B)             # line 9
        theta = jnp.where(
            gbar != 0.0,
            jnp.clip(a_B - g / v, 0.0, nu) - a_B,                    # line 11
            0.0)
        alpha = alpha.at[idx].add(theta)                 # line 13
        bt = b_B * theta
        x = x + apply_t(Y, bt)                           # line 14 (local)
        dual = dual + jnp.sum(theta * g) + 0.5 * bt @ (G @ bt)
        obj = dual if cfg.track_objective else jnp.asarray(0.0, cfg.dtype)
        return (alpha, x, dual), obj

    (alpha, x, dual), objs = jax.lax.scan(
        step, (alpha, x, dual0),
        jnp.arange(start + 1, start + cfg.iterations + 1))
    return SolverResult(x=x, objective=objs,
                        aux={"alpha": alpha, "dual": dual,
                             "state": SolveState(
                                 start + cfg.iterations,
                                 {"alpha": alpha, "x": x, "dual": dual}),
                             **spmm_aux(A, cfg, "row_gram", extra=1)})


def dcd_svm(problem: SVMProblem, cfg: SolverConfig,
            axis_name: Optional[object] = None,
            alpha0=None, state: Optional[SolveState] = None) -> SolverResult:
    """Paper Algorithm 3: the block_size = 1 special case of ``bdcd_svm``."""
    require_unit_block(cfg, "dcd_svm")
    return bdcd_svm(problem, cfg, axis_name, alpha0, state)


def _cli_kernel(args) -> str:
    """--kernel is None when unset; this family defaults to linear."""
    return args.kernel or "linear"


def _cli_problem(args):
    from repro.data.sparse import make_svm_dataset
    from repro.core.types import build_kernel_params
    A, b = make_svm_dataset(args.dataset, args.seed)
    kernel = _cli_kernel(args)
    return SVMProblem(A=A, b=b, lam=1.0, loss=args.svm_loss, kernel=kernel,
                      kernel_params=build_kernel_params(kernel, args))


def _cli_describe(args, res, elapsed: float) -> str:
    import numpy as np
    obj = np.asarray(res.objective)
    return (f"svm-{args.svm_loss}[{_cli_kernel(args)}] {args.dataset} "
            f"s={args.s} mu={args.mu}: "
            f"dual {obj[0]:.5f} -> {obj[-1]:.5f}, {elapsed:.2f}s")


@register_family(
    "svm",
    problem_cls=SVMProblem,
    partition="col",
    default_axes="model",
    x0_layout="replicated",          # warm start = dual alpha in R^m
    aux_out=(("alpha", "replicated"),),
    accepts=lambda p: getattr(p, "kernel", "linear") == "linear",
    variants={
        "classical": "repro.core.svm:bdcd_svm",
        "sa": "repro.core.sa_svm:sa_bdcd_svm",
    },
    objective=dual_objective,
    # this family only accepts kernel="linear" problems; the hook still
    # takes the registry-wide kernel argument and ignores it.
    costs=lambda dims, H, mu, s, P, kernel="linear": cost_model.svm_costs(
        dims, H, s, P, mu=mu),
    make_problem=_cli_problem,
    describe=_cli_describe,
    default_mu=1,
    bench_block_size=1,
    bench_problem_kwargs={"lam": 1.0},
    supports_symmetric_gram=True,
    state_layout=lambda cfg: (("alpha", "replicated"), ("x", "partition"),
                              ("dual", "replicated")),
)
def solve_svm(problem: SVMProblem, cfg: SolverConfig,
              axis_name: Optional[object] = None,
              x0=None, state=None) -> SolverResult:
    """Dispatch on (problem.kernel, cfg.s).

    Linear problems keep the primal-shadowing (SA-)BDCD solvers with
    their O(s^2 mu^2) reduced message; nonlinear kernels route to the
    kernelized (SA-)K-BDCD solvers of ``repro.core.kernel_svm``
    (``kernel="linear"`` there reproduces the same iterates — the
    dispatch is a communication-cost choice, not an algorithmic one).

    x0: optional warm start for the dual vector alpha (replicated (m,)).
    """
    if getattr(problem, "kernel", "linear") != "linear":
        from repro.core.kernel_svm import solve_ksvm
        return solve_ksvm(problem, cfg, axis_name, x0, state)
    if cfg.s > 1:
        from repro.core.sa_svm import sa_bdcd_svm
        return sa_bdcd_svm(problem, cfg, axis_name, x0, state)
    return bdcd_svm(problem, cfg, axis_name, x0, state)
