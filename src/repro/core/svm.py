"""Dual coordinate descent for linear SVM — paper Algorithm 3 (after
Hsieh et al., 2008), for both hinge (SVM-L1) and squared-hinge (SVM-L2).

Partitioning (paper Sec. V): unlike Lasso, SVM requires 1D-COLUMN
partitioning so the row/primal dot-products parallelize. In distributed
mode A holds the local column shard (m, n_loc); x in R^n is partitioned;
alpha in R^m, b in R^m and all scalars are replicated.

Per-iteration communication: ONE fused Allreduce of the two scalars
[ ||A_i||^2 , A_i x ]  (paper "Communication: lines 7 and 8").

The dual objective  f_D(alpha) = 1/2 alpha^T Qbar alpha - e^T alpha  is
tracked *exactly* and incrementally per iteration with local scalars only:
for an update alpha_i += theta,
    delta f_D = theta * g + 1/2 theta^2 * eta
where g = (Qbar alpha)_i - 1 is the gradient the step already computes and
eta = Qbar_ii. (Derivation in DESIGN.md; validated against the direct
quadratic form in tests.)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.types import SVMProblem, SolverConfig, SolverResult


def primal_objective(problem: SVMProblem, x, axis_name: Optional[object] = None):
    """P(x) = 1/2 ||x||^2 + lam * sum_i loss(1 - b_i A_i x).

    In distributed (column-partitioned) mode, x is the local shard and the
    matvec A x needs one Allreduce.
    """
    A = jnp.asarray(problem.A)
    margins = linalg.preduce(A @ x, axis_name)           # (m,)
    xi = jnp.maximum(1.0 - problem.b * margins, 0.0)
    loss = jnp.sum(xi) if problem.loss == "l1" else jnp.sum(xi * xi)
    sq = linalg.preduce(jnp.sum(x * x), axis_name)
    return 0.5 * sq + problem.lam * loss


def dual_objective(problem: SVMProblem, alpha, axis_name: Optional[object] = None):
    """f_D(alpha) = 1/2 alpha^T Qbar alpha - e^T alpha (direct evaluation)."""
    A = jnp.asarray(problem.A)
    w = A.T @ (problem.b * alpha)                        # (n_loc,) local
    quad = linalg.preduce(jnp.sum(w * w), axis_name)
    return 0.5 * quad + 0.5 * problem.gamma * jnp.sum(alpha * alpha) \
        - jnp.sum(alpha)


def duality_gap(problem: SVMProblem, x, alpha,
                axis_name: Optional[object] = None):
    """P(x) + f_D(alpha) >= 0, == 0 at the optimum (strong duality)."""
    return primal_objective(problem, x, axis_name) \
        + dual_objective(problem, alpha, axis_name)


def dcd_svm(problem: SVMProblem, cfg: SolverConfig,
            axis_name: Optional[object] = None,
            alpha0=None) -> SolverResult:
    """Paper Algorithm 3: dual coordinate descent for linear SVM."""
    A = jnp.asarray(problem.A, cfg.dtype)
    b = jnp.asarray(problem.b, cfg.dtype)
    m = A.shape[0]
    gamma = jnp.asarray(problem.gamma, cfg.dtype)
    nu = jnp.asarray(problem.nu, cfg.dtype)
    key = jax.random.key(cfg.seed)

    alpha = jnp.zeros((m,), cfg.dtype) if alpha0 is None \
        else jnp.asarray(alpha0, cfg.dtype)
    x = A.T @ (b * alpha)                                # line 2 (local shard)

    def step(carry, h):
        alpha, x, dual = carry
        i = jax.random.randint(jax.random.fold_in(key, h), (), 0, m)
        a_i = A[i]                                       # (n_loc,) local cols
        # --- Communication: ONE fused Allreduce of [||a_i||^2, a_i . x] ---
        red = linalg.preduce(
            jnp.stack([jnp.sum(a_i * a_i), jnp.sum(a_i * x)]), axis_name)
        eta = red[0] + gamma                             # line 7
        g = b[i] * red[1] - 1.0 + gamma * alpha[i]       # line 8
        gbar = jnp.abs(jnp.clip(alpha[i] - g, 0.0, nu) - alpha[i])  # line 9
        theta = jnp.where(
            gbar != 0.0,
            jnp.clip(alpha[i] - g / eta, 0.0, nu) - alpha[i],        # line 11
            0.0)
        alpha = alpha.at[i].add(theta)                   # line 13
        x = x + theta * b[i] * a_i                       # line 14 (local)
        dual = dual + theta * g + 0.5 * theta * theta * eta
        obj = dual if cfg.track_objective else jnp.asarray(0.0, cfg.dtype)
        return (alpha, x, dual), obj

    dual0 = jnp.asarray(0.0, cfg.dtype)
    (alpha, x, dual), objs = jax.lax.scan(
        step, (alpha, x, dual0), jnp.arange(1, cfg.iterations + 1))
    return SolverResult(x=x, objective=objs,
                        aux={"alpha": alpha, "dual": dual})


def solve_svm(problem: SVMProblem, cfg: SolverConfig,
              axis_name: Optional[object] = None) -> SolverResult:
    if cfg.s > 1:
        from repro.core.sa_svm import sa_svm as sa_svm_fn
        return sa_svm_fn(problem, cfg, axis_name)
    return dcd_svm(problem, cfg, axis_name)
