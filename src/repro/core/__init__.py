"""Core library: the paper's synchronization-avoiding first-order solvers.

Public API:
    LassoProblem, SVMProblem, SolverConfig, SolverResult
    solve_lasso, solve_svm              — single-host (dispatch on cfg.s)
    solve_lasso_sharded, solve_svm_sharded — distributed (shard_map)
"""
from repro.core.types import (LassoProblem, SVMProblem, SolverConfig,
                              SolverResult)
from repro.core.lasso import (acc_bcd_lasso, acc_cd_lasso, bcd_lasso,
                              cd_lasso, solve_lasso)
from repro.core.sa_lasso import (sa_acc_bcd_lasso, sa_acc_cd_lasso,
                                 sa_bcd_lasso, sa_cd_lasso)
from repro.core.svm import bdcd_svm, dcd_svm, duality_gap, \
    dual_objective, primal_objective, solve_svm
from repro.core.sa_svm import sa_bdcd_svm, sa_svm
from repro.core.distributed import solve_lasso_sharded, solve_svm_sharded

__all__ = [
    "LassoProblem", "SVMProblem", "SolverConfig", "SolverResult",
    "acc_bcd_lasso", "acc_cd_lasso", "bcd_lasso", "cd_lasso", "solve_lasso",
    "sa_acc_bcd_lasso", "sa_acc_cd_lasso", "sa_bcd_lasso", "sa_cd_lasso",
    "bdcd_svm", "dcd_svm", "sa_bdcd_svm", "sa_svm", "solve_svm",
    "duality_gap", "dual_objective", "primal_objective",
    "solve_lasso_sharded", "solve_svm_sharded",
]
