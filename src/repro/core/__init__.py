"""Core library: the paper's synchronization-avoiding first-order solvers.

Public API (see also the ``repro.api`` facade, which fronts all of this
through one registry-driven ``solve`` call):
    LassoProblem, SVMProblem, LogRegProblem, SolverConfig, SolverResult
    FAMILIES / register_family            — the problem-family registry
    KERNELS / register_kernel             — the SVM kernel registry
    solve_lasso, solve_svm, solve_ksvm, solve_logreg
                                          — per-family dispatch (cfg.s)
    solve_lasso_sharded, solve_svm_sharded — distributed shims
    plus the individually named solver variants (bcd_lasso, sa_bdcd_svm,
    ...), all of which remain thin shims over the same implementations.
"""
from repro.core.types import (FAMILIES, KERNELS, KernelSpec, LassoProblem,
                              LogRegProblem, ProblemFamily, SVMProblem,
                              SolveState, SolverConfig, SolverResult,
                              SparseOperand, build_kernel_params,
                              register_family, register_kernel,
                              require_unit_block, resume_carry)
from repro.core.lasso import (acc_bcd_lasso, acc_cd_lasso, bcd_lasso,
                              cd_lasso, lasso_objective, solve_lasso)
from repro.core.sa_lasso import (sa_acc_bcd_lasso, sa_acc_cd_lasso,
                                 sa_bcd_lasso, sa_cd_lasso)
from repro.core.svm import bdcd_svm, dcd_svm, duality_gap, \
    dual_objective, primal_objective, solve_svm
from repro.core.sa_svm import sa_bdcd_svm, sa_svm
from repro.core.kernel_svm import (kbdcd_svm, kernel_dual_objective,
                                   sa_kbdcd_svm, solve_ksvm)
from repro.core.logreg import bcd_logreg, logreg_objective, solve_logreg
from repro.core.sa_logreg import sa_bcd_logreg
from repro.core.sfista import (SFISTAProblem, ca_sfista, sfista,
                               sfista_objective, solve_sfista)
from repro.core.engine import FamilyProgram, run_program
from repro.core.distributed import solve_lasso_sharded, solve_svm_sharded

__all__ = [
    "FAMILIES", "ProblemFamily", "register_family",
    "KERNELS", "KernelSpec", "register_kernel", "build_kernel_params",
    "require_unit_block",
    "LassoProblem", "SVMProblem", "LogRegProblem",
    "SolverConfig", "SolverResult", "SolveState", "SparseOperand",
    "resume_carry",
    "acc_bcd_lasso", "acc_cd_lasso", "bcd_lasso", "cd_lasso", "solve_lasso",
    "lasso_objective",
    "sa_acc_bcd_lasso", "sa_acc_cd_lasso", "sa_bcd_lasso", "sa_cd_lasso",
    "bdcd_svm", "dcd_svm", "sa_bdcd_svm", "sa_svm", "solve_svm",
    "kbdcd_svm", "sa_kbdcd_svm", "solve_ksvm", "kernel_dual_objective",
    "duality_gap", "dual_objective", "primal_objective",
    "bcd_logreg", "sa_bcd_logreg", "solve_logreg", "logreg_objective",
    "SFISTAProblem", "sfista", "ca_sfista", "solve_sfista",
    "sfista_objective",
    "FamilyProgram", "run_program",
    "solve_lasso_sharded", "solve_svm_sharded",
]
