"""Core library: the paper's synchronization-avoiding first-order solvers.

Public API:
    LassoProblem, SVMProblem, SolverConfig, SolverResult
    solve_lasso, solve_svm              — single-host (dispatch on cfg.s)
    solve_lasso_sharded, solve_svm_sharded — distributed (shard_map)
"""
from repro.core.types import (KERNELS, KernelSpec, LassoProblem,
                              SVMProblem, SolverConfig, SolverResult,
                              register_kernel)
from repro.core.lasso import (acc_bcd_lasso, acc_cd_lasso, bcd_lasso,
                              cd_lasso, solve_lasso)
from repro.core.sa_lasso import (sa_acc_bcd_lasso, sa_acc_cd_lasso,
                                 sa_bcd_lasso, sa_cd_lasso)
from repro.core.svm import bdcd_svm, dcd_svm, duality_gap, \
    dual_objective, primal_objective, solve_svm
from repro.core.sa_svm import sa_bdcd_svm, sa_svm
from repro.core.kernel_svm import (kbdcd_svm, kernel_dual_objective,
                                   sa_kbdcd_svm, solve_ksvm)
from repro.core.distributed import solve_lasso_sharded, solve_svm_sharded

__all__ = [
    "KERNELS", "KernelSpec", "register_kernel",
    "LassoProblem", "SVMProblem", "SolverConfig", "SolverResult",
    "acc_bcd_lasso", "acc_cd_lasso", "bcd_lasso", "cd_lasso", "solve_lasso",
    "sa_acc_bcd_lasso", "sa_acc_cd_lasso", "sa_bcd_lasso", "sa_cd_lasso",
    "bdcd_svm", "dcd_svm", "sa_bdcd_svm", "sa_svm", "solve_svm",
    "kbdcd_svm", "sa_kbdcd_svm", "solve_ksvm", "kernel_dual_objective",
    "duality_gap", "dual_objective", "primal_objective",
    "solve_lasso_sharded", "solve_svm_sharded",
]
