"""Synchronization-Avoiding logistic regression — the s-step unroll of
``bcd_logreg`` (after Devarakonda & Demmel, arXiv:2011.08281).

The SA trick applies because every update direction lives in the span of
the sampled rows: unrolling s damped steps,

    w_{sk+s} = (prod_j d_j) w_sk + Y^T u,    d_j = 1 - eta_j lam,

where u accumulates the per-step coefficients, each decayed by the
d-factors of the LATER steps. So the solver samples all s blocks up
front, Allreduces the fused (m, s*mu) cross block  A Y^T  ONCE, and runs
the s dependent inner updates redundantly on replicated data:

  * the margins f (replicated R^m) update per inner step as
    f <- d f + (A Y^T)[:, B_j] u_j  — a local slice of the reduced cross
    block, so gathers f[B_t] at later steps are automatically current
    (this also makes same-index collisions across the s blocks exact
    with no special casing: there is only ONE copy of each margin);
  * the coefficient buffer decays, U <- d U then U[j] += u_j, recording
    exactly the d-products the closed form above requires;
  * sq = ||w||^2 updates from gathered margins and the (s*mu, s*mu)
    diagonal slice of the cross block (DESIGN.md).

Deferred per outer group: ONE local GEMV  w <- rho w + Y^T vec(U)  with
rho = prod_j d_j. Identical iterates to ``bcd_logreg`` in exact
arithmetic; ONE Allreduce per s inner iterations. Remainder iterations
(H mod s != 0) run as a tail group via ``run_grouped``, like every other
SA solver.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.logreg import _init_state, _step_size, _tracked_objective
from repro.core.sa_loop import run_grouped
from repro.core.sparse_exec import cross_block, row_block_ops, spmm_aux
from repro.core.types import (LogRegProblem, SolveState, SolverConfig,
                              SolverResult, resume_carry)


def sa_bcd_logreg(problem: LogRegProblem, cfg: SolverConfig,
                  axis_name: Optional[object] = None,
                  x0=None, state: Optional[SolveState] = None
                  ) -> SolverResult:
    """s-step unrolled BCD logistic regression: identical iterates to
    ``bcd_logreg`` in exact arithmetic, ONE Allreduce per s inner
    iterations."""
    mu = cfg.block_size
    lam = jnp.asarray(problem.lam, cfg.dtype)
    key = jax.random.key(cfg.seed)
    s, H = cfg.s, cfg.iterations
    carry0 = resume_carry(state, x0, "sa_bcd_logreg")
    h0 = 0 if state is None else int(state.iteration)
    A, b, w, f, sq = _init_state(problem, cfg, axis_name, x0, carry0)
    take, _, densify, apply_t = row_block_ops(A, cfg)
    m = A.shape[0]

    def group(carry, start, s_grp):
        w, f, sq = carry
        # same fold_in iteration ids as the classical solver -> the SA
        # schedule draws bit-identical blocks.
        hs = start + 1 + jnp.arange(s_grp)
        idxs = jax.vmap(
            lambda h: linalg.sample_block(jax.random.fold_in(key, h),
                                          m, mu))(hs)     # (s_grp, mu)
        flat = idxs.reshape(s_grp * mu)
        Y = take(flat)                                    # (s_grp*mu, n_loc)
        # --- Communication: ONE fused Allreduce of  A Y^T ---
        cross = linalg.preduce(
            cross_block(A, densify(Y), cfg.use_pallas),
            axis_name)                                    # (m, s_grp*mu)
        cross_r = cross.reshape(m, s_grp, mu)
        b_sel = b[flat].reshape(s_grp, mu)

        def inner(inner_carry, j):
            f, sq, rho, U = inner_carry
            idx_j = idxs[j]
            Kj = cross_r[:, j, :]                         # (m, mu) = A Y_j^T
            G = Kj[idx_j]                                 # (mu, mu) = Y_j Y_j^T
            fB = f[idx_j]                                 # current Y_j w
            c = -b_sel[j] * jax.nn.sigmoid(-b_sel[j] * fB)
            eta = _step_size(G, mu, lam, cfg.power_iters)
            d = 1.0 - eta * lam
            u = -(eta / mu) * c                           # (mu,)
            sq = d * d * sq + 2.0 * d * (fB @ u) + u @ (G @ u)
            f = d * f + Kj @ u                            # replicated, local
            rho = d * rho
            U = (d * U).at[j].add(u)                      # decay, then record
            obj = _tracked_objective(f, sq, b, lam) if cfg.track_objective \
                else jnp.asarray(0.0, cfg.dtype)
            return (f, sq, rho, U), obj

        rho0 = jnp.asarray(1.0, cfg.dtype)
        U0 = jnp.zeros((s_grp, mu), cfg.dtype)
        (f, sq, rho, U), objs = jax.lax.scan(
            inner, (f, sq, rho0, U0), jnp.arange(s_grp))

        # Deferred w update (local GEMV): w <- rho w + Y^T vec(U).
        w = rho * w + apply_t(Y, U.reshape(s_grp * mu))
        return (w, f, sq), objs

    (w, f, sq), objs = run_grouped(group, (w, f, sq), H, s, cfg.dtype,
                                   start=h0)
    return SolverResult(x=w, objective=objs,
                        aux={"margins": f, "w_norm_sq": sq,
                             "state": SolveState(
                                 h0 + H, {"w": w, "margins": f, "sq": sq}),
                             **spmm_aux(A, cfg, "cross", H=H)})
