"""Synchronization-Avoiding logistic regression — the s-step unroll of
``bcd_logreg`` (after Devarakonda & Demmel, arXiv:2011.08281), expressed
as a :class:`repro.core.engine` FamilyProgram.

Every update direction lives in the span of the sampled rows: unrolling
s damped steps gives  w_{sk+s} = (prod_j d_j) w_sk + Y^T u,  with
d_j = 1 - eta_j lam and u the per-step coefficients, each decayed by
the d-factors of LATER steps. The solver samples all s blocks up front,
Allreduces the fused (m, s*mu) cross block A Y^T ONCE, and runs the s
dependent inner updates redundantly on replicated data:

  * the margins f (replicated R^m) update per inner step as
    f <- d f + (A Y^T)[:, B_j] u_j — a local slice of the reduced cross
    block, so later gathers f[B_t] are current (same-index collisions
    need no special casing: there is ONE copy of each margin);
  * the coefficient buffer decays, U <- d U then U[j] += u_j, recording
    exactly the d-products the closed form requires;
  * sq = ||w||^2 updates from gathered margins and the diagonal slice
    of the cross block (DESIGN.md).

Deferred per outer group: ONE local GEMV  w <- rho w + Y^T vec(U)  with
rho = prod_j d_j. Identical iterates to ``bcd_logreg`` in exact
arithmetic; ONE Allreduce per s inner iterations.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import linalg
from repro.core.engine import Ctx, FamilyProgram, run_program
from repro.core.logreg import _init_state, _step_size, _tracked_objective
from repro.core.sparse_exec import cross_block, row_block_ops
from repro.core.types import (LogRegProblem, SolveState, SolverConfig,
                              SolverResult)


def _logreg_setup(problem, cfg, axis_name, x0, carry0):
    A, b, w, f, sq = _init_state(problem, cfg, axis_name, x0, carry0)
    take, _, densify, apply_t = row_block_ops(A, cfg)
    ctx = Ctx(A=A, b=b, m=A.shape[0], mu=cfg.block_size,
              lam=jnp.asarray(problem.lam, cfg.dtype), take=take,
              densify=densify, apply_t=apply_t, cfg=cfg,
              axis_name=axis_name)
    return ctx, (w, f, sq)


def _logreg_assemble(ctx, carry, idxs, s_grp):
    flat = idxs.reshape(s_grp * ctx.mu)
    Y = ctx.take(flat)                                # (s_grp*mu, n_loc)
    return Y, cross_block(ctx.A, ctx.densify(Y), ctx.cfg.use_pallas)


def _logreg_inner(ctx, carry, Y, cross, idxs, win, s_grp):
    w, f, sq = carry
    cfg, mu, lam, b = ctx.cfg, ctx.mu, ctx.lam, ctx.b
    cross_r = cross.reshape(ctx.m, s_grp, mu)
    b_sel = b[idxs.reshape(s_grp * mu)].reshape(s_grp, mu)

    def inner(inner_carry, j):
        f, sq, rho, U = inner_carry
        idx_j = idxs[j]
        Kj = cross_r[:, j, :]                         # (m, mu) = A Y_j^T
        G = Kj[idx_j]                                 # (mu, mu) = Y_j Y_j^T
        fB = f[idx_j]                                 # current Y_j w
        c = -b_sel[j] * jax.nn.sigmoid(-b_sel[j] * fB)
        eta = _step_size(G, mu, lam, cfg.power_iters)
        d = 1.0 - eta * lam
        u = -(eta / mu) * c                           # (mu,)
        sq = d * d * sq + 2.0 * d * (fB @ u) + u @ (G @ u)
        f = d * f + Kj @ u                            # replicated, local
        rho = d * rho
        U = (d * U).at[j].add(u)                      # decay, then record
        obj = _tracked_objective(f, sq, b, lam) if cfg.track_objective \
            else jnp.asarray(0.0, cfg.dtype)
        return (f, sq, rho, U), obj

    rho0 = jnp.asarray(1.0, cfg.dtype)
    U0 = jnp.zeros((s_grp, mu), cfg.dtype)
    (f, sq, rho, U), objs = jax.lax.scan(
        inner, (f, sq, rho0, U0), jnp.arange(s_grp))
    return (w, f, sq), (rho, U, objs)


def _logreg_defer(ctx, carry, Y, inner_out, cross, idxs, win, s_grp):
    w, f, sq = carry
    rho, U, objs = inner_out
    w = rho * w + ctx.apply_t(Y, U.reshape(s_grp * ctx.mu))  # local GEMV
    return (w, f, sq), objs


_LOGREG_PROGRAM = FamilyProgram(
    name="sa_bcd_logreg", setup=_logreg_setup,
    sample=lambda ctx, key: linalg.sample_block(key, ctx.m, ctx.mu),
    assemble=_logreg_assemble,
    reduce=lambda ctx, local, *_: linalg.preduce(local, ctx.axis_name),
    inner=_logreg_inner, defer=_logreg_defer,
    finalize=lambda ctx, carry, sched: (
        carry[0], {"margins": carry[1], "w_norm_sq": carry[2]}),
    carry_names=("w", "margins", "sq"), spmm_kind="cross")


def sa_bcd_logreg(problem: LogRegProblem, cfg: SolverConfig,
                  axis_name: Optional[object] = None,
                  x0=None, state: Optional[SolveState] = None
                  ) -> SolverResult:
    """s-step unrolled BCD logreg: identical iterates to ``bcd_logreg``
    in exact arithmetic, ONE Allreduce per s inner iterations."""
    return run_program(_LOGREG_PROGRAM, problem, cfg, axis_name, x0, state)
