"""Small linear-algebra and sampling utilities shared by the solvers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def power_iteration_max_eig(G, iters: int = 32):
    """Largest eigenvalue of a small PSD matrix G (mu x mu).

    Fixed iteration count + deterministic start vector: TPU-friendly (no
    data-dependent control flow) replacement for LAPACK ``eig`` in paper
    Alg. 1 line 10. Exact for mu = 1; validated against eigvalsh in tests.
    """
    mu = G.shape[0]
    if mu == 1:
        return G[0, 0]
    v = jnp.ones((mu,), dtype=G.dtype) / jnp.sqrt(jnp.asarray(mu, G.dtype))

    def body(v, _):
        w = G @ v
        v = w / jnp.maximum(jnp.linalg.norm(w), 1e-30)
        return v, None

    v, _ = jax.lax.scan(body, v, None, length=iters)
    return v @ (G @ v)


def floor_eig(v):
    """Floor a Gram-block eigenvalue (or the step-size denominator built
    from it) at the smallest positive normal of its dtype before it
    becomes a 1/v step size.

    A sampled column block that is all zeros (user-supplied data — the
    synthetic generators guard empty columns, arbitrary dense or sparse
    operands don't) has ``power_iteration_max_eig(G) == 0`` exactly, and
    ``eta = 1/0 = inf`` then meets the equally-zero projection as
    ``inf * 0 = NaN``, poisoning the iterate forever. Flooring keeps eta
    finite, and since the projection of a zero block is exactly 0 the
    prox step stays a no-op for it. For any nonzero eigenvalue
    ``maximum(v, tiny)`` returns v bit-for-bit, so regular solves are
    unchanged. The accelerated solvers floor the whole ``q * theta * v``
    denominator (flooring v alone can still underflow to a subnormal
    whose reciprocal overflows once q * theta < 1); the Pallas
    ``sa_inner`` kernel applies the same floor at f32, its compute
    dtype, to preserve kernel/ref parity.
    """
    return jnp.maximum(v, jnp.finfo(jnp.result_type(v)).tiny)


def theta_schedule(theta0, num: int, q: float):
    """Pre-compute the APPROX acceleration scalars.

    theta_h = (sqrt(theta_{h-1}^4 + 4 theta_{h-1}^2) - theta_{h-1}^2) / 2
    (paper Alg. 1 line 18; Alg. 2 line 9 drops the ``4`` — a typo, see
    DESIGN.md). Returns thetas[0..num] with thetas[0] = theta0.

    ``q`` is unused by the recurrence itself but kept so callers document
    the q = ceil(n / mu) block count alongside the schedule.
    """
    del q

    def body(th, _):
        th2 = th * th
        nxt = (jnp.sqrt(th2 * th2 + 4.0 * th2) - th2) / 2.0
        return nxt, nxt

    _, rest = jax.lax.scan(body, theta0, None, length=num)
    return jnp.concatenate([jnp.asarray(theta0)[None], rest])


def fista_t_schedule(num: int, dtype=jnp.float32):
    """Pre-compute the FISTA momentum scalars (Beck & Teboulle; used by
    CA-SFISTA, arXiv:1710.08883):

        t_0 = 1,    t_h = (1 + sqrt(1 + 4 t_{h-1}^2)) / 2,

    from which iteration h's momentum is beta_h = (t_{h-1} - 1) / t_h
    (so beta_1 = 0: the first step carries no momentum). Returns
    ts[0..num] with ts[0] = 1."""
    t0 = jnp.asarray(1.0, dtype)

    def body(t, _):
        nxt = (1.0 + jnp.sqrt(1.0 + 4.0 * t * t)) / 2.0
        return nxt, nxt

    _, rest = jax.lax.scan(body, t0, None, length=num)
    return jnp.concatenate([t0[None], rest])


def sample_block(key, n: int, mu: int):
    """Sample mu of n coordinates uniformly without replacement.

    Uses the Gumbel top-k trick (argsort of iid noise) — identical draws on
    every shard given the same (replicated) key, which is the paper's
    "initialize the RNG to the same seed on all processors" requirement.
    """
    if mu == n:
        return jnp.arange(n)
    noise = jax.random.uniform(key, (n,))
    _, idx = jax.lax.top_k(noise, mu)
    return idx


def sample_group(key, n_groups: int, group_size: int):
    """Sample one whole group (group-lasso mode): returns its coordinates."""
    g = jax.random.randint(key, (), 0, n_groups)
    return g * group_size + jnp.arange(group_size)


def preduce(x, axis_name: Optional[str]):
    """psum over ``axis_name`` when distributed, identity otherwise.

    ``axis_name`` may be a tuple of axis names for hierarchical meshes
    (e.g. ('pod', 'data')) — jax.lax.psum reduces over all of them.
    """
    if axis_name is None:
        return x
    return jax.lax.psum(x, axis_name)
