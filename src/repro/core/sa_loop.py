"""Shared outer-loop driver for the SA solvers: floor(H/s) full s-step
groups inside one lax.scan, then ONE remainder tail group of H mod s
iterations (the group body is shape-parameterized, so the tail is just a
second trace at a smaller group size). ceil(H/s) Allreduces total,
exactly H inner iterations, same fold_in iteration ids as the classical
solvers. H < s degenerates to a single tail group with zero scan trips.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def run_grouped(group, carry, H: int, s: int, dtype, start: int = 0):
    """Run ``group(carry, start, s_grp) -> (carry, objs (s_grp,))`` over
    the full schedule; returns (carry, objs (H,)).

    ``start`` (a host int) offsets the global iteration ids — a solve
    resumed from a checkpointed :class:`~repro.core.types.SolveState`
    at iteration ``start`` passes it here so the groups keep the
    uninterrupted schedule's ``fold_in`` ids. Checkpoints are taken at
    outer-iteration boundaries, so ``start`` is a multiple of the
    original run's s whenever group alignment matters (DESIGN.md
    "Elastic recovery of SA recurrences")."""
    K, rem = divmod(H, s)
    objs = jnp.zeros((0,), dtype)
    if K:        # full s-step groups
        carry, objs = jax.lax.scan(
            lambda c, k: group(c, start + k * s, s), carry, jnp.arange(K))
        objs = objs.reshape(K * s)
    if rem:      # remainder tail group: the last H mod s iterations
        carry, objs_tail = group(carry, jnp.asarray(start + K * s), rem)
        objs = jnp.concatenate([objs, objs_tail])
    return carry, objs


def grouped_impl_label(impl_fn, H: int, s: int, mu: int,
                       use_pallas: bool, itemsize: int = 4) -> str:
    """The inner-loop implementation(s) the grouped schedule actually
    runs: the tail group dispatches at (H mod s, mu), which can differ
    from the full groups' (s, mu) — e.g. an over-VMEM s falls back to
    "ref" while a small tail still runs "pallas". Mixed runs are
    labeled "main+tail" so benchmarks never mislabel the timings.
    ``itemsize`` is the solve dtype's bytes/element (the VMEM guards are
    dtype-aware)."""
    K, rem = divmod(H, s)
    labels = ([impl_fn(s, mu, use_pallas, itemsize)] if K else []) \
        + ([impl_fn(rem, mu, use_pallas, itemsize)] if rem else [])
    if len(set(labels)) == 1:
        return labels[0]
    return "+".join(labels)
