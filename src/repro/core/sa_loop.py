"""Compatibility shim: the grouped outer-loop driver moved into the
generic SA engine (:mod:`repro.core.engine`), which owns all s-step
scheduling. Import :func:`run_grouped` / :func:`grouped_impl_label`
from there."""
from __future__ import annotations

from repro.core.engine import grouped_impl_label, run_grouped

__all__ = ["run_grouped", "grouped_impl_label"]
