"""Problem and solver configuration types for the SA first-order solvers.

The paper (Devarakonda et al., 2017) studies randomized (block) coordinate
descent for two problem families:

* proximal least-squares:  argmin_x 1/2 ||Ax - b||^2 + g(x)
  with g in {lasso, elastic-net, group-lasso}
* linear SVM (dual):       argmin_a 1/2 a^T Qbar a - e^T a,  0 <= a_i <= nu

Both families share a block-sampling + Gram-matrix structure, and both admit
the synchronization-avoiding (SA) s-step reformulation.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse


# ---------------------------------------------------------------------------
# Kernel registry (kernel SVM, after Shao & Devarakonda, arXiv:2406.18001).
#
# A kernel function maps the *reduced* (post-Allreduce) linear cross-product
# block  C[i, j] = u_i . v_j  — plus the squared row norms when it needs
# them — to the kernel block  K[i, j] = k(u_i, v_j),  as a pure pointwise
# transform. Keeping kernels downstream of the reduction means swapping
# Y Y^T for K(Y, Y) changes NO communication: the solvers still do ONE
# fused Allreduce per (outer) iteration and kernelize the replicated copy.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A registered SVM kernel.

    fn(cross, unorms, vnorms, params) -> K, all element-wise on the reduced
    cross-product block ``cross`` (p, q); ``unorms`` (p,) / ``vnorms`` (q,)
    are the squared row norms (only materialized when ``needs_norms``).

    cli_params maps each hyperparameter the launcher exposes to its
    default value (the flag's type is the default's type): the launcher
    generates a ``--kernel-<name>`` flag per entry and
    :func:`build_kernel_params` forwards every one — keeping the CLI
    registry-driven (a new kernel's flags need no launcher edits, and
    nothing is silently dropped).
    """

    name: str
    fn: Callable
    needs_norms: bool = False
    cli_params: Mapping[str, Any] = dataclasses.field(default_factory=dict)


KERNELS: Dict[str, KernelSpec] = {}


def register_kernel(name: str, needs_norms: bool = False,
                    cli_params: Optional[Mapping[str, Any]] = None):
    """Decorator: add a kernel to the registry (``KERNELS[name]``)."""

    def deco(fn):
        KERNELS[name] = KernelSpec(name=name, fn=fn, needs_norms=needs_norms,
                                   cli_params=dict(cli_params or {}))
        return fn

    return deco


def build_kernel_params(kernel: str, args) -> Optional[Dict[str, Any]]:
    """Collect a registered kernel's hyperparameters from parsed CLI args
    (``--kernel-gamma`` -> ``args.kernel_gamma`` -> ``{"gamma": ...}``).

    Forwards EVERY declared parameter — the historical launcher built
    these dicts by hand and silently dropped poly's ``coef0``.
    """
    spec = KERNELS[kernel]
    if not spec.cli_params:
        return None
    return {p: getattr(args, f"kernel_{p}") for p in spec.cli_params}


@register_kernel("linear")
def _linear_kernel(cross, unorms, vnorms, params):
    return cross


@register_kernel("poly", cli_params={"degree": 3, "coef0": 1.0,
                                     "scale": 1.0})
def _poly_kernel(cross, unorms, vnorms, params):
    p = params or {}
    scale = p.get("scale", 1.0)
    coef0 = p.get("coef0", 1.0)
    degree = p.get("degree", 3)
    return (scale * cross + coef0) ** degree


@register_kernel("rbf", needs_norms=True, cli_params={"gamma": 0.1})
def _rbf_kernel(cross, unorms, vnorms, params):
    p = params or {}
    width = p.get("gamma", 0.1)
    sq = unorms[:, None] + vnorms[None, :] - 2.0 * cross
    return jnp.exp(-width * jnp.maximum(sq, 0.0))


# ---------------------------------------------------------------------------
# Sparse operands.
#
# The paper's Table I costs carry the density factor f, and its 1.2-5.1x
# speedups are measured on sparse LIBSVM data — so the repo executes
# sparse operands instead of merely modeling them. A SparseOperand holds
# TWO coupled forms of the same matrix:
#
#   * a BCOO matrix (``jax.experimental.sparse``) — the interchange /
#     general-matmul form;
#   * a padded blocked-ELL layout, stored BOTH row-major and col-major:
#     per row (resp. column), the nonzero indices and values padded to a
#     common width K that is a multiple of ``ell_block``, plus the
#     per-row/column count of *active* K-blocks. Padded slots hold
#     index 0 / value 0, which makes every gather, scatter and SpMM
#     below exact with no masking.
#
# The double orientation is what makes the solvers' sampling cheap: the
# Lasso family samples COLUMNS of A (gather rows of the col-major
# arrays), the SVM/logreg families sample ROWS (gather rows of the
# row-major arrays) — either way a blocked-ELL sub-operand falls out of
# a plain row gather and feeds ``repro.kernels.spmm.ell_spmm`` directly.
# ---------------------------------------------------------------------------

def ell_width(max_nnz: int, ell_block: int) -> int:
    """The padded ELL width for a max per-row nnz: at least one block,
    rounded up to a multiple of ``ell_block``."""
    return -(-max(int(max_nnz), 1) // ell_block) * ell_block


def _ell_from_coo(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
                  R: int, ell_block: int, width: Optional[int]):
    """Row-major padded ELL arrays from COO triplets (host numpy,
    vectorized — no per-row Python loop): (idx, vals, blocks)."""
    counts = np.bincount(rows, minlength=R) if rows.size \
        else np.zeros(R, np.int64)
    K = ell_width(counts.max() if R else 0, ell_block)
    if width is not None:
        if width < K:
            raise ValueError(
                f"ELL width {width} < required {K} "
                f"(max row nnz {int(counts.max())})")
        K = width
    order = np.lexsort((cols, rows))
    r_s, c_s, v_s = rows[order], cols[order], vals[order]
    starts = np.concatenate([[0], np.cumsum(counts[:-1])]) if R \
        else np.zeros(0, np.int64)
    offsets = np.arange(r_s.size) - starts[r_s]
    idx = np.zeros((R, K), np.int32)
    out = np.zeros((R, K), vals.dtype)
    idx[r_s, offsets] = c_s
    out[r_s, offsets] = v_s
    blocks = ((counts + ell_block - 1) // ell_block).astype(np.int32)
    return jnp.asarray(idx), jnp.asarray(out), jnp.asarray(blocks)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseOperand:
    """A sparse (m, n) data matrix in BCOO + padded blocked-ELL form.

    row_cols/row_vals: (m, Kr) column indices / values per row;
    row_blocks: (m,) active Kr-block count per row (the blocked-ELL nnz
    metadata the Pallas SpMM uses to skip padding). col_rows/col_vals/
    col_blocks: the same, per column. bcoo: the BCOO form (None inside
    ``shard_map`` — the sharded driver rebuilds per-shard ELL arrays and
    drops it). ell_block: the K-padding quantum (static pytree aux).

    Registered as a pytree so operands flow through jit/shard_map like
    arrays; every problem dataclass accepts one in place of its dense
    ``A`` and the solvers detect it with ``isinstance``.
    """

    row_cols: Any
    row_vals: Any
    row_blocks: Any
    col_rows: Any
    col_vals: Any
    col_blocks: Any
    bcoo: Any = None
    ell_block: int = 8

    def tree_flatten(self):
        return ((self.row_cols, self.row_vals, self.row_blocks,
                 self.col_rows, self.col_vals, self.col_blocks,
                 self.bcoo), self.ell_block)

    @classmethod
    def tree_unflatten(cls, ell_block, children):
        return cls(*children, ell_block=ell_block)

    # -- construction -------------------------------------------------

    @classmethod
    def from_coo(cls, rows, cols, vals, shape: Tuple[int, int],
                 ell_block: int = 8,
                 row_width: Optional[int] = None,
                 col_width: Optional[int] = None,
                 bcoo=None) -> "SparseOperand":
        """Build both ELL orientations from COO triplets — O(nnz) host
        work and memory, never materializing the dense matrix. The
        triplets must be duplicate-free (``from_bcoo`` pre-combines)."""
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = np.asarray(vals)
        rc, rv, rb = _ell_from_coo(rows, cols, vals, shape[0], ell_block,
                                   row_width)
        cr, cv, cb = _ell_from_coo(cols, rows, vals, shape[1], ell_block,
                                   col_width)
        return cls(rc, rv, rb, cr, cv, cb, bcoo, ell_block)

    @classmethod
    def from_dense(cls, A, ell_block: int = 8,
                   row_width: Optional[int] = None,
                   col_width: Optional[int] = None,
                   with_bcoo: bool = True) -> "SparseOperand":
        An = np.asarray(A)
        if An.ndim != 2:
            raise ValueError(f"expected a matrix, got shape {An.shape}")
        rows, cols = np.nonzero(An)
        bcoo = jsparse.BCOO.fromdense(jnp.asarray(An)) if with_bcoo \
            else None
        return cls.from_coo(rows, cols, An[rows, cols], An.shape,
                            ell_block=ell_block, row_width=row_width,
                            col_width=col_width, bcoo=bcoo)

    @classmethod
    def from_bcoo(cls, mat, ell_block: int = 8) -> "SparseOperand":
        """O(nnz) — duplicates are summed, the dense matrix is never
        materialized (the whole point at LIBSVM scale)."""
        m, n = mat.shape
        idx = np.asarray(mat.indices)
        data = np.asarray(mat.data)
        keys = idx[:, 0].astype(np.int64) * n + idx[:, 1].astype(np.int64)
        uniq, inverse = np.unique(keys, return_inverse=True)
        vals = np.zeros(uniq.size, data.dtype)
        np.add.at(vals, inverse, data)
        return cls.from_coo(uniq // n, uniq % n, vals, (m, n),
                            ell_block=ell_block, bcoo=mat)

    # -- shape / dtype ------------------------------------------------

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.row_cols.shape[0], self.col_rows.shape[0])

    @property
    def ndim(self) -> int:
        return 2

    @property
    def dtype(self):
        return self.row_vals.dtype

    @property
    def nnz(self) -> int:
        """Stored nonzeros (host-side; padding never stores nonzeros)."""
        return int((np.asarray(self.row_vals) != 0).sum())

    def astype(self, dtype) -> "SparseOperand":
        bcoo = None if self.bcoo is None else jsparse.BCOO(
            (self.bcoo.data.astype(dtype), self.bcoo.indices),
            shape=self.bcoo.shape)
        return dataclasses.replace(
            self, row_vals=self.row_vals.astype(dtype),
            col_vals=self.col_vals.astype(dtype), bcoo=bcoo)

    # -- conversions / products (pure jnp — safe inside jit) ----------

    def todense(self):
        m, n = self.shape
        return jnp.zeros((m, n), self.dtype).at[
            jnp.arange(m)[:, None], self.row_cols].add(self.row_vals)

    def to_bcoo(self):
        return self.bcoo if self.bcoo is not None \
            else jsparse.BCOO.fromdense(self.todense())

    def matvec(self, x):
        """A @ x via the row-major ELL arrays: O(nnz)."""
        return jnp.einsum("mk,mk->m", self.row_vals, x[self.row_cols])

    def rmatvec(self, y):
        """A^T @ y via the col-major ELL arrays: O(nnz)."""
        return jnp.einsum("nk,nk->n", self.col_vals, y[self.col_rows])

    # -- sampled-block gathers (the solvers' hot path) ----------------

    def gather_cols(self, idx):
        """ELL form of the sampled columns A[:, idx]: (rows, vals,
        blocks), each gathered along the leading axis — O(|idx| * Kc)."""
        return (self.col_rows[idx], self.col_vals[idx],
                self.col_blocks[idx])

    def gather_rows(self, idx):
        """ELL form of the sampled rows A[idx]: (cols, vals, blocks)."""
        return (self.row_cols[idx], self.row_vals[idx],
                self.row_blocks[idx])

    def host_coo(self):
        """COO triplets (host numpy) recovered from the row-major ELL
        arrays; stored zeros are dropped (they contribute nothing). The
        sharded driver splits these per shard at O(nnz) cost."""
        vals = np.asarray(self.row_vals)
        cols = np.asarray(self.row_cols)
        mask = vals != 0
        rows = np.broadcast_to(
            np.arange(vals.shape[0])[:, None], vals.shape)
        return rows[mask], cols[mask], vals[mask]

    def squeeze_shard(self) -> "SparseOperand":
        """Drop the leading stacked-shard axis the sharded driver adds
        (each leaf arrives inside ``shard_map`` with leading dim 1)."""
        return SparseOperand(
            self.row_cols[0], self.row_vals[0], self.row_blocks[0],
            self.col_rows[0], self.col_vals[0], self.col_blocks[0],
            None, self.ell_block)


def operand_matvec(A, x):
    """A @ x for a dense array or a SparseOperand."""
    if isinstance(A, SparseOperand):
        return A.matvec(x)
    return jnp.asarray(A) @ x


def operand_rmatvec(A, y):
    """A^T @ y for a dense array or a SparseOperand."""
    if isinstance(A, SparseOperand):
        return A.rmatvec(y)
    return jnp.asarray(A).T @ y


@dataclasses.dataclass(frozen=True)
class LassoProblem:
    """Proximal least-squares problem data.

    A: (m, n) design matrix (m data points, n features) — a dense array
       or a :class:`SparseOperand`. In the distributed solvers A holds
       the *local row shard*.
    b: (m,) labels / targets (row-sharded alongside A when distributed).
    lam: l1 regularization weight (paper uses lam = 100 * sigma_min).
    l2: optional l2 weight -> elastic net (prox changes, loss unchanged).
    groups: optional (n,) int array of group ids -> group lasso. Groups must
       be contiguous, equal-sized blocks; block sampling then samples whole
       groups (see DESIGN.md "group lasso" note).
    """

    A: Any
    b: Any
    lam: float
    l2: float = 0.0
    groups: Optional[Any] = None

    @property
    def shape(self):
        return self.A.shape


@dataclasses.dataclass(frozen=True)
class SVMProblem:
    """Dual linear SVM problem data.

    A: (m, n) data matrix (dense or :class:`SparseOperand`); in the
       distributed solver A holds the *local column shard* (1D-column
       partitioning, as in the paper Sec. V).
    b: (m,) binary labels in {-1, +1} (replicated when distributed).
    lam: SVM penalty parameter (paper: lam = 1).
    loss: "l1" (hinge) or "l2" (squared hinge).
    kernel: name in ``KERNELS`` ("linear", "rbf", "poly"). "linear" routes
       to the primal-shadowing (B)DCD solvers of ``core.svm`` /
       ``core.sa_svm``; anything else routes to the kernelized K-BDCD /
       SA-K-BDCD solvers of ``core.kernel_svm``.
    kernel_params: optional dict of kernel hyperparameters (e.g.
       ``{"gamma": 0.1}`` for rbf, ``{"degree": 3, "coef0": 1.0}`` for
       poly); see the registry functions in this module.
    """

    A: Any
    b: Any
    lam: float = 1.0
    loss: str = "l1"
    kernel: str = "linear"
    kernel_params: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if self.kernel not in KERNELS:
            raise ValueError(
                f"unknown kernel {self.kernel!r}; registered: "
                f"{sorted(KERNELS)}")

    @property
    def kernel_spec(self) -> KernelSpec:
        return KERNELS[self.kernel]

    @property
    def gamma(self) -> float:
        return 0.0 if self.loss == "l1" else 0.5 / self.lam

    @property
    def nu(self) -> float:
        return self.lam if self.loss == "l1" else jnp.inf


@dataclasses.dataclass(frozen=True)
class LogRegProblem:
    """Binary logistic-regression problem data (communication-avoiding
    logistic regression, after Devarakonda & Demmel, arXiv:2011.08281).

    A: (m, n) data matrix (dense or :class:`SparseOperand`); in the
       distributed solver A holds the *local column shard* (1D-column
       partitioning, exactly the SVM layout: w in R^n is partitioned,
       everything in R^m is replicated).
    b: (m,) binary labels in {-1, +1} (replicated when distributed).
    lam: l2 regularization weight — the objective is
         (1/m) sum_i log(1 + exp(-b_i a_i^T w)) + lam/2 ||w||^2.
    """

    A: Any
    b: Any
    lam: float = 0.0

    @property
    def shape(self):
        return self.A.shape


# ---------------------------------------------------------------------------
# Problem-family registry (the ``repro.api`` dispatch axis).
#
# A ProblemFamily self-describes everything the generic machinery needs to
# drive a problem class end-to-end: which solver variants exist, how the
# data matrix is partitioned when sharded (so ONE driver can build the
# shard_map/pad/unpad plumbing for every family), its objective and
# cost-model entries, and how the CLI builds/reports a problem. Families
# register themselves from their own module via ``@register_family`` —
# mirroring the ``KERNELS`` pattern above — so adding a workload is a pure
# registration: no edits to dispatch, the distributed driver, or the CLI.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProblemFamily:
    """A registered problem family.

    solve:      the family's variant-dispatching entry point
                ``fn(problem, cfg, axis_name=None, x0=None) -> SolverResult``
                (the function ``@register_family`` decorates).
    variants:   variant name -> "module.path:function" (resolved lazily via
                :meth:`variant`, so registration never imports the SA
                modules eagerly).
    partition:  which axis of A the sharded backend partitions — "row"
                (Lasso: data points sharded, solutions replicated) or
                "col" (SVM/logreg: features sharded, R^m state replicated).
    default_axes: default mesh axis (or tuple of axes) for the sharded
                backend ("data" for row partition, "model" for column).
    x0_layout:  how a warm start vector is laid out when sharded —
                "replicated" (Lasso x, SVM alpha) or "partition" (logreg
                w, which lives on the partitioned feature axis).
    aux_out:    ``(aux_key, layout)`` pairs the sharded driver returns from
                ``SolverResult.aux``; layout "partition" vectors are
                sharded along the partition axis (and unpadded), layout
                "replicated" vectors pass through.
    accepts:    optional tie-break predicate when several families share a
                problem dataclass (linear vs kernel SVM).
    objective:  direct objective evaluation ``fn(problem, x_or_alpha)``.
    costs:      cost-model entry
                ``fn(dims, H, mu, s, P, kernel="linear") -> dict`` (paper
                Table I analogue). Callers with a problem in hand pass
                its ``problem.kernel`` so kernelized families report the
                ACTUAL kernel's evaluation flops (the ksvm hook used to
                hardcode rbf); families without a kernel axis ignore it.
    make_problem / describe: CLI hooks — build a problem from parsed
                ``argparse`` args; format a one-line result summary.
    default_mu: CLI default block size.
    bench_problem_kwargs / bench_block_size: how benchmarks instantiate a
                representative problem (collective counts, lowering).
    tune_space: the autotuner's candidate grid for this family —
                ``{"s": (...), "mu": (...)}``; ``repro.tune.select``
                sweeps the declared candidates through the ``costs``
                hook (families with structurally constrained blocks,
                e.g. group lasso, are further restricted there).
    supports_symmetric_gram: whether the family's SA solvers honor
                ``cfg.symmetric_gram`` (triangle-packed Gram Allreduce)
                — the tuner only recommends it where it changes the
                executed message.
    state_layout: checkpoint layout hook
                ``fn(cfg) -> ((leaf_name, layout), ...)`` naming the
                recurrence leaves the variant selected by ``cfg``
                carries across outer-iteration boundaries, in the order
                solvers emit them in ``SolverResult.aux["state"]``.
                layout is "replicated" or "partition" (along the
                family's partition axis), exactly the ``x0_layout``
                vocabulary — the sharded driver pads/shards/unpads
                state leaves from this declaration, and the elastic
                checkpointer derives each leaf's logical PartitionSpec
                from it.
    """

    name: str
    problem_cls: type
    solve: Callable
    variants: Mapping[str, str]
    partition: str = "row"
    default_axes: Any = "data"
    x0_layout: str = "replicated"
    aux_out: Tuple[Tuple[str, str], ...] = ()
    accepts: Optional[Callable] = None
    objective: Optional[Callable] = None
    costs: Optional[Callable] = None
    make_problem: Optional[Callable] = None
    describe: Optional[Callable] = None
    default_mu: int = 1
    bench_block_size: int = 1
    bench_problem_kwargs: Mapping[str, Any] = \
        dataclasses.field(default_factory=dict)
    tune_space: Mapping[str, Any] = dataclasses.field(
        default_factory=lambda: {"s": (1, 2, 4, 8, 16, 32, 64),
                                 "mu": (1, 2, 4, 8, 16)})
    supports_symmetric_gram: bool = False
    state_layout: Optional[Callable] = None

    def __post_init__(self):
        if self.partition not in ("row", "col"):
            raise ValueError(
                f"partition must be 'row' or 'col', got {self.partition!r}")
        if self.x0_layout not in ("replicated", "partition"):
            raise ValueError(
                f"x0_layout must be 'replicated' or 'partition', "
                f"got {self.x0_layout!r}")

    def variant(self, name: str) -> Callable:
        """Resolve a registered variant name to its solver function."""
        if name not in self.variants:
            raise ValueError(
                f"unknown variant {name!r} for family {self.name!r}; "
                f"registered: {sorted(self.variants)}")
        module, _, attr = self.variants[name].partition(":")
        return getattr(importlib.import_module(module), attr)

    def matches(self, problem) -> bool:
        """Does this family handle ``problem``? (type + accepts hook)."""
        return isinstance(problem, self.problem_cls) and (
            self.accepts is None or bool(self.accepts(problem)))


FAMILIES: Dict[str, ProblemFamily] = {}


def register_family(name: str, **fields):
    """Decorator: register the decorated variant-dispatch function as the
    ``solve`` entry of a new :class:`ProblemFamily` (``FAMILIES[name]``).

    Mirrors :func:`register_kernel`: families self-register from their own
    module, so a new workload needs zero edits elsewhere.
    """

    def deco(fn):
        if name in FAMILIES:
            raise ValueError(
                f"family {name!r} already registered "
                f"(registered: {sorted(FAMILIES)})")
        FAMILIES[name] = ProblemFamily(name=name, solve=fn, **fields)
        return fn

    return deco


@dataclasses.dataclass
class SolveState:
    """Full solver state at an outer-iteration boundary.

    The SA solvers keep s iterations of recurrences in flight between
    Allreduces; the ONLY points where the complete algorithm state is a
    small set of named vectors are the outer-iteration boundaries (after
    the deferred updates of a group land, before the next group's fused
    Allreduce). A ``SolveState`` captures exactly that cut:

    iteration: global INNER iterations completed (a host int — it offsets
        the ``fold_in`` RNG iteration ids and the theta-schedule index,
        so a resumed solve draws the same blocks and acceleration
        scalars as the uninterrupted one; the RNG key itself is
        reconstructed from ``cfg.seed``, which the elastic checkpoint
        manifest records).
    carry: the named recurrence leaves, in the family's
        ``state_layout(cfg)`` order. Leaves are LOGICAL (unpadded,
        replicated-or-partition per the declared layout), so a state
        saved on one mesh restores onto any other — the sharded driver
        re-pads and re-shards them from the layout alone.

    Every solver returns its final state in ``SolverResult.aux["state"]``
    and accepts one back via ``state=`` (mutually exclusive with ``x0``).
    """

    iteration: int
    carry: Dict[str, Any] = dataclasses.field(default_factory=dict)


def resume_carry(state: Optional["SolveState"], x0, solver_name: str):
    """Shared precondition for the solvers' resume path: ``state`` and
    ``x0`` are mutually exclusive (a state IS the warm start — seeding
    x0 on top of it would silently discard the restored recurrences).
    Returns ``state.carry`` or None."""
    if state is None:
        return None
    if x0 is not None:
        raise ValueError(
            f"{solver_name}: pass either x0= (fresh warm start) or "
            f"state= (resume a checkpointed solve), not both")
    return state.carry


def require_unit_block(cfg: "SolverConfig", solver_name: str) -> None:
    """Raise for the mu = 1 solver aliases when cfg asks for blocks.

    A hard ``ValueError`` (not ``assert``, which silently vanishes under
    ``python -O``): calling a single-coordinate alias with block_size > 1
    would silently solve a different problem than requested.
    """
    if cfg.block_size != 1:
        raise ValueError(
            f"{solver_name} is the block_size == 1 special case "
            f"(got block_size={cfg.block_size}); call the blocked "
            f"variant instead")


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Shared solver configuration.

    block_size: mu, the number of coordinates updated per iteration. For
       Lasso this is a block of mu *columns* (features); for SVM it is a
       block of mu *rows* (dual variables) — BDCD / SA-BDCD, after
       Devarakonda et al. (arXiv:1612.04003). mu = 1 recovers the paper's
       single-coordinate Algorithms 3-4.
    s: recurrence-unrolling parameter. s=1 recovers the classical method
       (one Allreduce per iteration); s>1 defers communication for s
       iterations (one Allreduce per outer iteration, paper Alg. 2 / 4).
    iterations: H, the total number of *inner* iterations. Need not be a
       multiple of s: the SA solvers run floor(H/s) full s-step groups
       followed by one remainder group of H mod s iterations, so every
       configuration executes exactly H inner iterations.
    accelerated: use the Nesterov-accelerated variant (accCD / accBCD).
    power_iters: fixed iteration count for the power method computing the
       largest eigenvalue of the mu x mu Gram block (TPU-friendly
       replacement for LAPACK eig; exact for mu = 1).
    track_objective: record the objective after every inner iteration
       (diagnostic; adds local flops only, plus one reduction per
       evaluation in the distributed Lasso solver).
    symmetric_gram: exploit symmetry of the (s*mu, s*mu) Gram matrix in
       the SA solvers by Allreducing only its lower triangle (paper
       footnote 3): ~2x less W at O(s^2 mu^2) local pack/unpack cost.
       The reduced values are identical, only their layout changes, so
       iterates match the dense path bit-for-bit.
    use_pallas: route the fused Gram + projection GEMM of the SA solvers
       through the ``repro.kernels.gram`` Pallas kernel (TPU). The jnp
       path is used when False (CPU / tests).
    seed: RNG seed. The same seed on every shard reproduces the paper's
       "same random generator seed on all processors" requirement; in JAX
       this replication is structural (the key is part of the replicated
       program state).
    """

    block_size: int = 1
    s: int = 1
    iterations: int = 100
    accelerated: bool = True
    power_iters: int = 32
    track_objective: bool = True
    symmetric_gram: bool = False
    use_pallas: bool = False
    seed: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.s < 1 or self.block_size < 1:
            raise ValueError("s and block_size must be >= 1")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")

    @property
    def outer_iterations(self) -> int:
        """Allreduce rounds: full s-groups plus the remainder group."""
        return -(-self.iterations // self.s)


@dataclasses.dataclass
class SolverResult:
    """Solution + per-iteration diagnostics."""

    x: Any                       # (n,) solution (Lasso) / primal vector (SVM)
    objective: Any               # (H,) objective value after each inner iteration
    aux: dict = dataclasses.field(default_factory=dict)
