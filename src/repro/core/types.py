"""Problem and solver configuration types for the SA first-order solvers.

The paper (Devarakonda et al., 2017) studies randomized (block) coordinate
descent for two problem families:

* proximal least-squares:  argmin_x 1/2 ||Ax - b||^2 + g(x)
  with g in {lasso, elastic-net, group-lasso}
* linear SVM (dual):       argmin_a 1/2 a^T Qbar a - e^T a,  0 <= a_i <= nu

Both families share a block-sampling + Gram-matrix structure, and both admit
the synchronization-avoiding (SA) s-step reformulation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Kernel registry (kernel SVM, after Shao & Devarakonda, arXiv:2406.18001).
#
# A kernel function maps the *reduced* (post-Allreduce) linear cross-product
# block  C[i, j] = u_i . v_j  — plus the squared row norms when it needs
# them — to the kernel block  K[i, j] = k(u_i, v_j),  as a pure pointwise
# transform. Keeping kernels downstream of the reduction means swapping
# Y Y^T for K(Y, Y) changes NO communication: the solvers still do ONE
# fused Allreduce per (outer) iteration and kernelize the replicated copy.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """A registered SVM kernel.

    fn(cross, unorms, vnorms, params) -> K, all element-wise on the reduced
    cross-product block ``cross`` (p, q); ``unorms`` (p,) / ``vnorms`` (q,)
    are the squared row norms (only materialized when ``needs_norms``).
    """

    name: str
    fn: Callable
    needs_norms: bool = False


KERNELS: Dict[str, KernelSpec] = {}


def register_kernel(name: str, needs_norms: bool = False):
    """Decorator: add a kernel to the registry (``KERNELS[name]``)."""

    def deco(fn):
        KERNELS[name] = KernelSpec(name=name, fn=fn, needs_norms=needs_norms)
        return fn

    return deco


@register_kernel("linear")
def _linear_kernel(cross, unorms, vnorms, params):
    return cross


@register_kernel("poly")
def _poly_kernel(cross, unorms, vnorms, params):
    p = params or {}
    scale = p.get("scale", 1.0)
    coef0 = p.get("coef0", 1.0)
    degree = p.get("degree", 3)
    return (scale * cross + coef0) ** degree


@register_kernel("rbf", needs_norms=True)
def _rbf_kernel(cross, unorms, vnorms, params):
    p = params or {}
    width = p.get("gamma", 0.1)
    sq = unorms[:, None] + vnorms[None, :] - 2.0 * cross
    return jnp.exp(-width * jnp.maximum(sq, 0.0))


@dataclasses.dataclass(frozen=True)
class LassoProblem:
    """Proximal least-squares problem data.

    A: (m, n) design matrix (m data points, n features). In the distributed
       solvers A holds the *local row shard*.
    b: (m,) labels / targets (row-sharded alongside A when distributed).
    lam: l1 regularization weight (paper uses lam = 100 * sigma_min).
    l2: optional l2 weight -> elastic net (prox changes, loss unchanged).
    groups: optional (n,) int array of group ids -> group lasso. Groups must
       be contiguous, equal-sized blocks; block sampling then samples whole
       groups (see DESIGN.md "group lasso" note).
    """

    A: Any
    b: Any
    lam: float
    l2: float = 0.0
    groups: Optional[Any] = None

    @property
    def shape(self):
        return self.A.shape


@dataclasses.dataclass(frozen=True)
class SVMProblem:
    """Dual linear SVM problem data.

    A: (m, n) data matrix; in the distributed solver A holds the *local
       column shard* (1D-column partitioning, as in the paper Sec. V).
    b: (m,) binary labels in {-1, +1} (replicated when distributed).
    lam: SVM penalty parameter (paper: lam = 1).
    loss: "l1" (hinge) or "l2" (squared hinge).
    kernel: name in ``KERNELS`` ("linear", "rbf", "poly"). "linear" routes
       to the primal-shadowing (B)DCD solvers of ``core.svm`` /
       ``core.sa_svm``; anything else routes to the kernelized K-BDCD /
       SA-K-BDCD solvers of ``core.kernel_svm``.
    kernel_params: optional dict of kernel hyperparameters (e.g.
       ``{"gamma": 0.1}`` for rbf, ``{"degree": 3, "coef0": 1.0}`` for
       poly); see the registry functions in this module.
    """

    A: Any
    b: Any
    lam: float = 1.0
    loss: str = "l1"
    kernel: str = "linear"
    kernel_params: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if self.kernel not in KERNELS:
            raise ValueError(
                f"unknown kernel {self.kernel!r}; registered: "
                f"{sorted(KERNELS)}")

    @property
    def kernel_spec(self) -> KernelSpec:
        return KERNELS[self.kernel]

    @property
    def gamma(self) -> float:
        return 0.0 if self.loss == "l1" else 0.5 / self.lam

    @property
    def nu(self) -> float:
        return self.lam if self.loss == "l1" else jnp.inf


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Shared solver configuration.

    block_size: mu, the number of coordinates updated per iteration. For
       Lasso this is a block of mu *columns* (features); for SVM it is a
       block of mu *rows* (dual variables) — BDCD / SA-BDCD, after
       Devarakonda et al. (arXiv:1612.04003). mu = 1 recovers the paper's
       single-coordinate Algorithms 3-4.
    s: recurrence-unrolling parameter. s=1 recovers the classical method
       (one Allreduce per iteration); s>1 defers communication for s
       iterations (one Allreduce per outer iteration, paper Alg. 2 / 4).
    iterations: H, the total number of *inner* iterations. Need not be a
       multiple of s: the SA solvers run floor(H/s) full s-step groups
       followed by one remainder group of H mod s iterations, so every
       configuration executes exactly H inner iterations.
    accelerated: use the Nesterov-accelerated variant (accCD / accBCD).
    power_iters: fixed iteration count for the power method computing the
       largest eigenvalue of the mu x mu Gram block (TPU-friendly
       replacement for LAPACK eig; exact for mu = 1).
    track_objective: record the objective after every inner iteration
       (diagnostic; adds local flops only, plus one reduction per
       evaluation in the distributed Lasso solver).
    symmetric_gram: exploit symmetry of the (s*mu, s*mu) Gram matrix in
       the SA solvers by Allreducing only its lower triangle (paper
       footnote 3): ~2x less W at O(s^2 mu^2) local pack/unpack cost.
       The reduced values are identical, only their layout changes, so
       iterates match the dense path bit-for-bit.
    use_pallas: route the fused Gram + projection GEMM of the SA solvers
       through the ``repro.kernels.gram`` Pallas kernel (TPU). The jnp
       path is used when False (CPU / tests).
    seed: RNG seed. The same seed on every shard reproduces the paper's
       "same random generator seed on all processors" requirement; in JAX
       this replication is structural (the key is part of the replicated
       program state).
    """

    block_size: int = 1
    s: int = 1
    iterations: int = 100
    accelerated: bool = True
    power_iters: int = 32
    track_objective: bool = True
    symmetric_gram: bool = False
    use_pallas: bool = False
    seed: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.s < 1 or self.block_size < 1:
            raise ValueError("s and block_size must be >= 1")
        if self.iterations < 1:
            raise ValueError("iterations must be >= 1")

    @property
    def outer_iterations(self) -> int:
        """Allreduce rounds: full s-groups plus the remainder group."""
        return -(-self.iterations // self.s)


@dataclasses.dataclass
class SolverResult:
    """Solution + per-iteration diagnostics."""

    x: Any                       # (n,) solution (Lasso) / primal vector (SVM)
    objective: Any               # (H,) objective value after each inner iteration
    aux: dict = dataclasses.field(default_factory=dict)
