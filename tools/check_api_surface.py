#!/usr/bin/env python
"""Public-API-surface check: diff the exported names of ``repro.core``,
``repro.api`` and ``repro.kernels.spmm`` against the checked-in
``api_surface.txt``.

    PYTHONPATH=src python tools/check_api_surface.py            # verify
    PYTHONPATH=src python tools/check_api_surface.py --update   # regen

Fails (exit 1) on any drift. Removals are the real hazard — a name
vanishing from ``__all__`` silently breaks downstream callers — but
additions also fail so the snapshot stays the reviewed source of truth;
run with ``--update`` and commit the new file to bless a change.
"""
from __future__ import annotations

import argparse
import importlib
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SURFACE_FILE = os.path.join(ROOT, "api_surface.txt")
MODULES = ("repro.core", "repro.core.engine", "repro.api",
           "repro.analysis", "repro.kernels", "repro.kernels.spmm",
           "repro.tune", "repro.runtime.elastic")


def current_surface() -> list[str]:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    lines = []
    for modname in MODULES:
        mod = importlib.import_module(modname)
        exported = getattr(mod, "__all__", None)
        if not exported:
            print(f"ERROR: {modname} defines no __all__", file=sys.stderr)
            raise SystemExit(1)
        missing = [n for n in exported if not hasattr(mod, n)]
        if missing:
            print(f"ERROR: {modname}.__all__ lists undefined names: "
                  f"{missing}", file=sys.stderr)
            raise SystemExit(1)
        lines.extend(f"{modname}:{name}" for name in sorted(set(exported)))
    return lines


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="rewrite api_surface.txt from the live modules")
    args = ap.parse_args()

    lines = current_surface()
    if args.update:
        with open(SURFACE_FILE, "w") as f:
            f.write("\n".join(lines) + "\n")
        print(f"wrote {len(lines)} names to {SURFACE_FILE}")
        return 0

    if not os.path.exists(SURFACE_FILE):
        print(f"ERROR: {SURFACE_FILE} missing; run with --update",
              file=sys.stderr)
        return 1
    with open(SURFACE_FILE) as f:
        recorded = [ln.strip() for ln in f if ln.strip()]

    removed = sorted(set(recorded) - set(lines))
    added = sorted(set(lines) - set(recorded))
    if removed:
        print("ERROR: names REMOVED from the public API surface "
              "(downstream callers would break silently):",
              file=sys.stderr)
        for name in removed:
            print(f"  - {name}", file=sys.stderr)
    if added:
        print("ERROR: names added to the public API surface but not "
              "recorded; bless them with --update and commit:",
              file=sys.stderr)
        for name in added:
            print(f"  + {name}", file=sys.stderr)
    if removed or added:
        return 1
    print(f"api surface OK ({len(lines)} names)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
