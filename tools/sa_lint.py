#!/usr/bin/env python
"""Standalone repo lint: the AST rules of ``repro.analysis.lint``
(raw-collective / ambient-rng / bare-assert) over the library source,
without tracing any solver — fast enough for a pre-commit hook.

    python tools/sa_lint.py [src/repro]

Exits 1 on any finding. The full analyzer (jaxpr passes included) is
``python -m repro.analysis``.
"""
from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.lint import lint_paths  # noqa: E402


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]) if argv else None
    diags, checked = lint_paths(root)
    for d in diags:
        print(d.format())
    print(f"{len(checked)} files linted, {len(diags)} finding(s)")
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())
