"""Quickstart: the ``repro.api`` facade — one ``solve`` call for every
registered problem family, with the paper's SA trick behind ``cfg.s``.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import api
from repro.api import (LassoProblem, LogRegProblem, SFISTAProblem,
                       SolverConfig)
from repro.core.cost_model import Machine, ProblemDims, best_s
from repro.data.sparse import make_lasso_dataset, make_svm_dataset


def main():
    print(f"registered families: {', '.join(api.families())}")

    # 1. a synthetic sparse dataset mirroring LIBSVM news20's regime
    A, b, lam_max = make_lasso_dataset("news20-like", seed=0)
    prob = LassoProblem(A=A, b=b, lam=0.1 * lam_max)
    print(f"dataset: A {A.shape}, density {np.mean(A != 0):.4f}")

    # 2. classical accelerated BCD (paper Alg. 1) vs SA-accBCD (Alg. 2):
    # same problem, same facade — only cfg.s changes. The family is
    # inferred from the problem's type.
    H = 256
    base = api.solve(prob, SolverConfig(block_size=8, iterations=H))
    sa = api.solve(prob, SolverConfig(block_size=8, iterations=H, s=32))
    o1, o2 = np.asarray(base.objective), np.asarray(sa.objective)
    print(f"objective: {o1[0]:.2f} -> {o1[-1]:.2f}")
    print(f"SA-vs-classical max trajectory deviation: "
          f"{np.max(np.abs(o1 - o2) / np.abs(o1)):.2e}  "
          f"(same algorithm, rearranged arithmetic)")
    nnz = int(np.sum(np.abs(np.asarray(sa.x)) > 1e-8))
    print(f"solution sparsity: {nnz}/{A.shape[1]} nonzeros")

    # 3. warm start: solve(..., x0=...) resumes where a solve left off —
    # the second half of the budget continues the first half's trace.
    half = api.solve(prob, SolverConfig(block_size=8, iterations=H // 2,
                                        s=32))
    rest = api.solve(prob, SolverConfig(block_size=8, iterations=H // 2,
                                        s=32), x0=np.asarray(half.x))
    print(f"warm start: {float(half.objective[-1]):.2f} -> resumes at "
          f"{float(rest.objective[0]):.2f}")

    # 4. a different family through the SAME entry point: SA logistic
    # regression (arXiv:2011.08281), registered — not special-cased.
    As, bs = make_svm_dataset("w1a-like", seed=0)
    lres = api.solve(LogRegProblem(A=As, b=bs, lam=1e-3),
                     SolverConfig(block_size=4, iterations=128, s=16))
    lo = np.asarray(lres.objective)
    print(f"logreg (SA, s=16): obj {lo[0]:.4f} -> {lo[-1]:.4f}")

    # 5. families are engine programs (repro.core.engine): CA-SFISTA —
    # sampled FISTA with subspace momentum, arXiv:1710.08883 — is ~150
    # lines of algebra plugged into the generic s-step driver, and
    # registration alone gives it this facade, the sharded driver,
    # checkpointing and the autotuner. See DESIGN.md "The SA engine".
    fres = api.solve(SFISTAProblem(A=A, b=b, lam=0.1 * lam_max),
                     SolverConfig(block_size=8, iterations=128, s=16))
    fo = np.asarray(fres.objective)
    print(f"ca-sfista (s=16): obj {fo[0]:.2f} -> {fo[-1]:.2f}")

    # 6. when does SA win? The paper's Table I cost model:
    dims = ProblemDims(m=2_396_130, n=3_231_961, f=3.6e-5)  # url, at scale
    for P in (1024, 12288):
        s_star, speedup = best_s(dims, H=10_000, mu=1, P=P,
                                 machine=Machine.cray_xc30())
        print(f"url @ P={P:>6}: best s={s_star:<5} "
              f"predicted speedup {speedup:.1f}x "
              f"(paper measured 1.2x-5.1x at up to 12k cores)")

    # 7. ...or stop guessing (s, mu) entirely: tune="auto" calibrates
    # the Table I machine model against short measured pilot solves on
    # THIS host and picks the config (repro.tune; the calibrated
    # machine is cached under results/tuned/, so only the first solve
    # of a regime pays for the measurements).
    tuned = api.solve(prob, SolverConfig(iterations=H,
                                         track_objective=False),
                      tune="auto")
    used = tuned.aux["tuned_config"]
    print(f"autotuned: s={used.s} mu={used.block_size} "
          f"use_pallas={used.use_pallas} "
          f"symmetric_gram={used.symmetric_gram}")

    # 8. the paper's claim is STRUCTURAL — one fused Allreduce per
    # outer iteration — so it can be verified without running anything:
    # repro.analysis traces every registered family x variant and
    # checks the collective budget, replication of declared-replicated
    # outputs, and f64 cleanliness on the jaxpr
    # (same as `python -m repro.analysis`).
    from repro.analysis import check_all
    report = check_all(checks=("collectives",), families=("lasso",))
    print(f"static analysis (lasso collectives): "
          f"{len(report.checked)} variants, "
          f"{'OK' if report.ok else 'VIOLATIONS'}")

    # 9. the Table I cost model itself is certified the same way: count
    # flops/words/messages in the traced jaxpr and ratio them against
    # the registry's cost hook across the s grid (dense here; the
    # analyzer also certifies the SparseOperand path at O(nnz)). The
    # constant-factor F/W ratios stay flat in s and messages fall as
    # ceil(H/s) — the paper's claim, certified without running a solve.
    from repro.analysis import cost_ratio_rows
    from repro.api import FAMILIES
    print("certified cost table (lasso, counted vs modeled):")
    print(f"  {'variant':<16} {'s':>3} {'F ratio':>8} {'W ratio':>8} "
          f"{'msgs':>5}")
    for row in cost_ratio_rows(FAMILIES["lasso"], sparse=False):
        print(f"  {row.variant:<16} {row.s:>3} {row.f_ratio:>8.2f} "
              f"{row.w_ratio:>8.2f} {row.messages:>5.0f}")


if __name__ == "__main__":
    main()
