"""End-to-end driver (the paper's kind of workload): a full SA-accBCD
Lasso solve to a target tolerance on the largest synthetic regime,
distributed over all local devices, with the per-iteration objective
trace and the communication ledger.

    PYTHONPATH=src python examples/e2e_lasso.py [--iterations 2048]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import LassoProblem, SolverConfig, solve_lasso
from repro.data.sparse import make_lasso_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=1024)
    ap.add_argument("--mu", type=int, default=8)
    ap.add_argument("--s", type=int, default=32)
    ap.add_argument("--dataset", default="url-like")
    args = ap.parse_args()

    A, b, lam_max = make_lasso_dataset(args.dataset, seed=0)
    prob = LassoProblem(A=A, b=b, lam=0.1 * lam_max)
    print(f"solving lasso on {args.dataset}: A {A.shape} "
          f"(density {np.mean(A != 0):.4f}), H={args.iterations}, "
          f"mu={args.mu}, s={args.s}")

    t0 = time.perf_counter()
    res = solve_lasso(prob, SolverConfig(
        block_size=args.mu, iterations=args.iterations, s=args.s))
    obj = np.asarray(res.objective)
    dt = time.perf_counter() - t0
    x = np.asarray(res.x)

    # communication ledger (what a cluster run would have sent)
    outer = args.iterations // args.s
    gram_words = (args.s * args.mu) * (args.s * args.mu + 2)
    print(f"done in {dt:.1f}s: objective {obj[0]:.1f} -> {obj[-1]:.1f}")
    print(f"nonzeros: {int(np.sum(np.abs(x) > 1e-8))}/{x.size}")
    print(f"communication: {outer} allreduces of {gram_words} words "
          f"(classical: {args.iterations} allreduces of "
          f"{args.mu * (args.mu + 1)} words) -> "
          f"{args.iterations / outer:.0f}x fewer messages")
    ks = [len(obj) // 4, len(obj) // 2, len(obj) - 1]
    for k in ks:
        print(f"  obj[{k}] = {obj[k]:.3f}")


if __name__ == "__main__":
    main()
