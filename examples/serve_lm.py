"""Batched serving demo: prefill + decode with KV/state caches across
architecture families (dense GQA / SWA+MoE / recurrent xLSTM).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.launch.serve import BatchedServer
from repro.models import lm


def main():
    rng = np.random.default_rng(0)
    for arch_name in ("tinyllama-1.1b", "mixtral-8x7b", "xlstm-350m"):
        arch = get_smoke_config(arch_name)
        params = lm.init_params(arch, jax.random.key(0))
        server = BatchedServer(arch, params, max_seq=48)
        prompts = rng.integers(0, arch.vocab_size, (4, 16)).astype(np.int32)
        t0 = time.perf_counter()
        out = server.generate(prompts, gen_len=16)
        dt = time.perf_counter() - t0
        print(f"{arch_name:16s} ({arch.family:6s}): generated "
              f"{out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
              f"-> {out[0][:6].tolist()}...")


if __name__ == "__main__":
    main()
