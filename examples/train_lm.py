"""Train a ~100M-parameter llama-family model for a few hundred steps on
synthetic data with checkpointing — the LM-framework end-to-end driver.

    PYTHONPATH=src python examples/train_lm.py --steps 300   # full demo
    PYTHONPATH=src python examples/train_lm.py --steps 20    # quick
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import ArchConfig
from repro.data.tokens import TokenPipeline
from repro.models import lm
from repro.optim.adamw import AdamW, cosine_schedule
from repro.runtime.driver import Trainer, TrainerConfig

# ~100M params: 12L x 768d llama-style with a 32k vocab.
ARCH_100M = ArchConfig(
    name="llama-100m", family="dense", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000,
    block_pattern=("attn_mlp",), skip_shapes=("long_500k",),
    source="examples/train_lm.py")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    print(f"params: {lm.param_count(ARCH_100M) / 1e6:.1f}M")
    pipe = TokenPipeline(vocab_size=ARCH_100M.vocab_size,
                         global_batch=args.global_batch,
                         seq_len=args.seq_len, seed=0)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="lm100m_")
    cfg = TrainerConfig(steps=args.steps, ckpt_dir=ckpt,
                        ckpt_every=max(args.steps // 4, 10),
                        model_axis=1, remat="none")
    trainer = Trainer(ARCH_100M, AdamW(
        learning_rate=cosine_schedule(args.lr, 20, args.steps)),
        pipe, cfg)
    out = trainer.run()
    losses = out["losses"]
    k = max(len(losses) // 10, 1)
    for i in range(0, len(losses), k):
        print(f"step {i:4d}  loss {losses[i]:.4f}")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"checkpoints in {ckpt}")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
