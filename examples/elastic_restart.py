"""Fault-tolerance demo: inject a host failure mid-training and watch the
driver re-mesh onto the survivors, restore the checkpoint, and continue.

Must run with placeholder devices (set before jax imports):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_restart.py
"""
import os
import sys

if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import jax

from repro.configs import get_smoke_config
from repro.data.tokens import TokenPipeline
from repro.optim.adamw import AdamW
from repro.runtime.driver import Trainer, TrainerConfig
from repro.runtime.failures import FailureInjector
from repro.runtime.stragglers import StragglerMonitor


def main():
    print(f"devices: {len(jax.devices())}")
    arch = get_smoke_config("tinyllama-1.1b")
    pipe = TokenPipeline(vocab_size=arch.vocab_size, global_batch=8,
                         seq_len=64, seed=0)
    cfg = TrainerConfig(steps=24, ckpt_dir=tempfile.mkdtemp(),
                        ckpt_every=6, model_axis=2)
    injector = FailureInjector(failures={10: [3]})  # host 3 dies @ step 10
    trainer = Trainer(arch, AdamW(learning_rate=1e-3), pipe, cfg,
                      failure_injector=injector,
                      straggler_monitor=StragglerMonitor(n_hosts=4),
                      host_of_device=lambda i: i // 2)  # 2 devices/host
    out = trainer.run()
    print(f"completed {out['final_step']} steps; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
    for e in out["events"]:
        print("event:", e)
    assert any("re-meshed" in e for e in out["events"])
    print("elastic restart: OK")


if __name__ == "__main__":
    main()
