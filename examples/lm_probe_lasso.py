"""The paper technique meeting the LM framework: train a sparse Lasso
probe on frozen transformer features with SA-accBCD.

This is exactly the paper's workload shape — A = feature matrix (rows =
examples, sharded data-parallel), solved by synchronization-avoiding
block coordinate descent. On a pod the probe solve inherits the s-fold
latency reduction.

    PYTHONPATH=src python examples/lm_probe_lasso.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import LassoProblem, SolverConfig, solve_lasso
from repro.models import lm


def main():
    arch = get_smoke_config("tinyllama-1.1b")
    params = lm.init_params(arch, jax.random.key(0))

    # 1. extract features: mean-pooled final hidden states over a corpus.
    rng = np.random.default_rng(0)
    n_examples = 256
    tokens = rng.integers(0, arch.vocab_size, (n_examples, 32)) \
        .astype(np.int32)

    @jax.jit
    def features(tokens):
        # forward up to the final norm; pool over sequence.
        x = params["embed"][tokens].astype(arch.jnp_dtype)

        def fn(slot_params, x, kind):
            return lm._block_forward(slot_params, x, arch, kind)

        x, _ = lm._scan_layers(params, x, arch, fn)
        return jnp.mean(x.astype(jnp.float32), axis=1)

    A = np.asarray(features(tokens))                   # (N, d_model)
    # synthetic probe target: a sparse linear functional of the features.
    w_true = np.zeros(A.shape[1], np.float32)
    w_true[rng.choice(A.shape[1], 6, replace=False)] = \
        rng.standard_normal(6)
    y = A @ w_true + 0.01 * rng.standard_normal(n_examples)

    # 2. solve the probe with the paper's SA-accBCD.
    lam = 0.05 * float(np.abs(A.T @ y).max())
    res = solve_lasso(LassoProblem(A=A, b=y.astype(np.float32), lam=lam),
                      SolverConfig(block_size=4, iterations=256, s=16))
    w = np.asarray(res.x)
    obj = np.asarray(res.objective)
    support = set(np.flatnonzero(np.abs(w) > 1e-3).tolist())
    true_support = set(np.flatnonzero(w_true).tolist())
    print(f"probe objective {obj[0]:.4f} -> {obj[-1]:.4f}")
    print(f"recovered support {sorted(support)}")
    print(f"true support      {sorted(true_support)}")
    print(f"support recall: "
          f"{len(support & true_support)}/{len(true_support)}")


if __name__ == "__main__":
    main()
